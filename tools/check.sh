#!/usr/bin/env bash
# Tier-1 verify — the single command CHANGES.md / ROADMAP.md reference:
#
#   tools/check.sh [extra pytest args]
#
# Installs the optional dev deps best-effort (offline containers still run:
# property-based tests skip via tests/_hypothesis_stub.py) and runs the
# full suite with src/ on PYTHONPATH, then the same `ruff --select F` lint
# as the CI lint job — local tier-1 matches what CI gates.
set -euo pipefail
cd "$(dirname "$0")/.."

DEV_DEPS_OK=1
python -m pip install -q -r requirements-dev.txt 2>/dev/null || {
    DEV_DEPS_OK=0
    echo "[check] dev-dep install failed (offline?) — property tests will skip"
}

# the dep install is best-effort, the test runner is NOT: a missing pytest
# must fail the check loudly, not "succeed" by running nothing
python -c "import pytest" 2>/dev/null || {
    echo "[check] FATAL: pytest is not installed and the best-effort" >&2
    echo "[check] install could not provide it — tier-1 did NOT run" >&2
    exit 1
}

# One process per test FILE, not one for the whole suite: a single
# process accumulating every suite's jitted programs has segfaulted the
# XLA CPU compiler at full-suite scale (observed after PR 8's growth).
# Per-file processes bound each compile cache, isolate any crash to the
# file that triggered it, and keep reported failures identical.  Explicit
# pytest args (a path, -k, ...) bypass sharding and run as given.
if [ "$#" -gt 0 ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q "$@"
else
    FAILED_FILES=()
    for f in tests/test_*.py; do
        echo "[check] pytest $f"
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q "$f" \
            || FAILED_FILES+=("$f")
    done
    if [ "${#FAILED_FILES[@]}" -gt 0 ]; then
        echo "[check] FAILED test files: ${FAILED_FILES[*]}" >&2
        exit 1
    fi
fi

# static analysis: the registry-wide program sweep + host-aliasing audit
# + the scheduled-engine submit-path audit + the paged-pool retention
# audit, exactly what CI's `analysis` job gates (tools/jaxlint.py
# exits non-zero on any violation or coverage hole)
python tools/jaxlint.py --sweep --aliasing --submit --retention
echo "[check] jaxlint clean"

# observability self-check: metrics math, trace-ring semantics, a real
# instrumented micro-serve, and structural validation of the Perfetto
# export (tools/obsdump.py is the same CLI CI's analysis job uses to
# produce its uploaded trace artifacts)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tools/obsdump.py --selftest
echo "[check] obsdump selftest clean"

# lint: pyflakes (F), comparison/lambda/identifier pitfalls (E7), and
# bugbear (B) over src/, exactly what CI's `lint` job runs.  ruff comes
# from the same requirements-dev.txt install as pytest; if that install
# SUCCEEDED yet ruff is still missing, the environment is misconfigured —
# fail loudly rather than silently skipping what CI will gate.  Only a
# failed (offline) install downgrades to a loud skip, since tier-1's tests
# must still run in network-less containers.
if python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check --select F,E7,B --isolated src
    echo "[check] ruff --select F,E7,B clean"
elif [ "$DEV_DEPS_OK" = 1 ]; then
    echo "[check] FATAL: dev-dep install succeeded but ruff is missing —" >&2
    echo "[check] lint did NOT run; CI's lint job WILL run it" >&2
    exit 1
else
    echo "[check] WARNING: ruff unavailable (offline dev-dep install) —" >&2
    echo "[check] lint SKIPPED here; CI's lint job still gates it" >&2
fi
