#!/usr/bin/env bash
# Tier-1 verify — the single command CHANGES.md / ROADMAP.md reference:
#
#   tools/check.sh [extra pytest args]
#
# Installs the optional dev deps best-effort (offline containers still run:
# property-based tests skip via tests/_hypothesis_stub.py) and runs the
# full suite with src/ on PYTHONPATH.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt 2>/dev/null ||
    echo "[check] dev-dep install failed (offline?) — property tests will skip"

# the dep install is best-effort, the test runner is NOT: a missing pytest
# must fail the check loudly, not "succeed" by running nothing
python -c "import pytest" 2>/dev/null || {
    echo "[check] FATAL: pytest is not installed and the best-effort" >&2
    echo "[check] install could not provide it — tier-1 did NOT run" >&2
    exit 1
}

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q "$@"
