"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts."""
import glob
import json
import os
import sys

ART = "artifacts/dryrun"


def recs(mesh):
    out = {}
    for f in sorted(glob.glob(f"{ART}/*__{mesh}__default.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def main():
    single = recs("single")
    multi = recs("multi")

    print("### Dry-run compile matrix (both meshes)\n")
    print("| arch | shape | 16×16 single-pod | 2×16×16 multi-pod | per-chip peak GB (single) |")
    print("|---|---|---|---|---|")
    keys = sorted(set(single) | set(multi))
    for k in keys:
        s, m = single.get(k), multi.get(k)

        def st(r):
            if r is None:
                return "—"
            if r.get("skipped"):
                return "SKIP"
            if "error" in r:
                return "FAIL"
            return f"✅ {r['timings_s']['compile']}s"

        peak = ""
        if s and not s.get("skipped") and "error" not in s:
            peak = f"{(s['memory']['peak_bytes'] or 0) / 1e9:.1f}"
        note = ""
        if s and s.get("skipped"):
            note = s["reason"].split(":")[0]
        print(f"| {k[0]} | {k[1]} | {st(s)} | {st(m)} | {peak} {note} |")

    print("\n### Roofline terms (single-pod 16×16, per chip, seconds/step)\n")
    print("| arch | shape | compute_s | memory_s† | collective_s | dominant | "
          "MFR | collectives seen |")
    print("|---|---|---|---|---|---|---|---|")
    for k in keys:
        r = single.get(k)
        if r is None or r.get("skipped") or "error" in r:
            continue
        rf = r["roofline"]
        cc = r["collectives_raw"]["counts"]
        seen = ",".join(f"{n.split('-')[0]}-{n.split('-')[1][:1]}:{c}" if "-" in n
                        else f"{n}:{c}" for n, c in cc.items() if c)
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
              f"{rf['memory_s']:.2f} | {rf['collective_s']:.4f} | "
              f"{rf['dominant']} | {r.get('model_flops_ratio', 0):.2f} | {seen} |")


if __name__ == "__main__":
    main()
