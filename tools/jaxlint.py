#!/usr/bin/env python
"""jaxlint: static analysis over the repo's serving programs.

    tools/jaxlint.py --sweep        lint every registered backend combo
    tools/jaxlint.py --aliasing     host-aliasing audit of real engines
    tools/jaxlint.py --submit       NoSyncPrefillInSubmit audit of the
                                    scheduled engines (+ positive control)
    tools/jaxlint.py --retention    NoWriteIntoHeldPage audit of the paged
                                    managers (+ positive control)
    tools/jaxlint.py                all four (the CI `analysis` gate)
    tools/jaxlint.py --list-rules   registered rule names + descriptions
    tools/jaxlint.py --json out.json  also write the structured report

Exit status is non-zero iff any error-severity finding fired (or a
registered combo could not be linted — coverage holes are errors too).
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))


def _run_sweep(args):
    from repro.lint import report, sweep
    progress = None
    if args.verbose:
        def progress(key):
            print(f"[jaxlint] trace {key}", flush=True)
    rep = sweep(progress=progress)
    report.render_sweep(rep, verbose=args.verbose)
    return rep


def _run_aliasing(args):
    """Audit one engine per cache kind at reduced shape — the real
    submit/step/preempt path with the aliasing spies armed."""
    import jax
    from repro.configs import get_config, reduce_config
    from repro.lint import aliasing, report
    from repro.models import init_params
    from repro.serving import Engine, ServeConfig

    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    findings = []
    for kind in ("dense", "paged", "paged_q8"):
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                     cache=kind)
        findings += aliasing.audit_engine(eng)
    report.render_findings(
        "aliasing audit (dense + paged + paged_q8 engines)", findings)
    return findings


def _run_submit(args):
    """NoSyncPrefillInSubmit: scheduled engines' submit must enqueue only
    (with a positive control on the synchronous engine)."""
    from repro.lint import report, submitpath

    findings = submitpath.audit_submit_path()
    report.render_findings(
        "submit-path audit (scheduled dense + paged, sync control)",
        findings)
    return findings


def _run_retention(args):
    """NoWriteIntoHeldPage: no write/eviction path may touch a page a
    prefix-sharing peer or the retention tree still holds (with a
    positive control on a sabotaged manager)."""
    from repro.lint import report, retention

    findings = retention.audit_retention()
    report.render_findings(
        "retention audit (paged fp absolute + ring + q8, sabotage "
        "control)", findings)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="jaxlint", description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="lint every registered backend combo")
    ap.add_argument("--aliasing", action="store_true",
                    help="host-aliasing audit of dense+paged+paged_q8 "
                         "engines")
    ap.add_argument("--submit", action="store_true",
                    help="NoSyncPrefillInSubmit audit of scheduled engines")
    ap.add_argument("--retention", action="store_true",
                    help="NoWriteIntoHeldPage audit of the paged managers")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and exit")
    ap.add_argument("--json", metavar="PATH",
                    help="write the structured report to PATH")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.lint import report

    if args.list_rules:
        report.render_rules()
        return 0

    none_picked = not (args.sweep or args.aliasing or args.submit
                       or args.retention)
    run_sweep = args.sweep or none_picked
    run_alias = args.aliasing or none_picked
    run_submit = args.submit or none_picked
    run_retention = args.retention or none_picked

    sweep_rep = _run_sweep(args) if run_sweep else None
    alias_findings = _run_aliasing(args) if run_alias else None
    submit_findings = _run_submit(args) if run_submit else None
    retention_findings = _run_retention(args) if run_retention else None

    doc = report.to_json_dict(sweep=sweep_rep, aliasing=alias_findings,
                              submit=submit_findings,
                              retention=retention_findings)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"[jaxlint] JSON report: {args.json}")

    if not doc["ok"]:
        print("[jaxlint] FAIL: violations above", file=sys.stderr)
        return 1
    print("[jaxlint] clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
