#!/usr/bin/env python
"""obsdump: run a short instrumented serve and dump its observability.

  PYTHONPATH=src python tools/obsdump.py                  # summary table
  PYTHONPATH=src python tools/obsdump.py --json m.json    # metrics JSON
  PYTHONPATH=src python tools/obsdump.py --perfetto t.json  # trace for
                                           https://ui.perfetto.dev
  PYTHONPATH=src python tools/obsdump.py --prometheus -   # text format
  PYTHONPATH=src python tools/obsdump.py --selftest       # CI smoke

The serve is a reduced-shape model (init_params weights — observability
is about the ENGINE's behavior, not the logits) over a mixed-length
prompt batch sized to exercise queueing; ``--cache paged`` (default)
also exercises pool admission.  ``--selftest`` runs a tiny serve and
structurally validates every export path (metrics JSON, Prometheus
text, Perfetto trace_event document, trace invariants) plus the
off-by-default NullObserver contract — the obs smoke ``tools/check.sh``
and CI run.
"""
from __future__ import annotations

import argparse
import json
import sys


def build_engine(args):
    import jax
    from repro.configs import get_config, reduce_config
    from repro.models import init_params
    from repro.serving import (Engine, PagedCacheAdapter,
                               PagedQ8CacheAdapter, ServeConfig)

    cfg = reduce_config(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    sc = ServeConfig(n_slots=args.slots, max_len=args.max_len, obs=True,
                     seed=args.seed)
    cache = "dense"
    if args.cache in ("paged", "paged_q8"):
        cls = PagedCacheAdapter if args.cache == "paged" \
            else PagedQ8CacheAdapter
        cache = cls(block_size=args.block_size,
                    n_blocks=args.n_blocks or None)
    return Engine(cfg, params, sc, cache=cache), cfg


def run_serve(eng, cfg, args):
    import numpy as np
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(0, cfg.vocab_size,
                           size=(int(rng.randint(4, args.max_len // 3)),))
               for _ in range(args.requests)]
    return eng.generate(prompts, max_new_tokens=args.max_new)


def summarize(eng) -> str:
    from repro.obs import serving_obs_doc
    doc = serving_obs_doc(eng)
    lines = ["obs summary (instrumented serve)", "-" * 34]
    for k in sorted(doc["headline"]):
        v = doc["headline"][k]
        lines.append(f"  {k:<22} {v if v is not None else 'n/a'}")
    tr = eng.obs.trace
    lines.append(f"  trace_events           {len(tr)} "
                 f"(dropped {tr.n_dropped}, open {len(tr.open_spans())})")
    return "\n".join(lines)


def selftest() -> int:
    """End-to-end structural validation of every obs surface."""
    import numpy as np
    from repro import obs as O

    # pillar 1/2 units first: no engine needed, fails fast and cheap
    m = O.MetricsRegistry()
    h = m.histogram("h", lo=1e-3, hi=1e3)
    for v in (0.01, 0.1, 0.1, 1.0):
        h.observe(v)
    h.observe(None)  # the excluded single-token marker
    assert h.collect()["n_excluded"] == 1 and h.count == 4
    assert h.vmin <= h.percentile(0.5) <= h.vmax
    assert "h_bucket{le=" in m.to_prometheus()
    tr = O.TraceBuffer(capacity=8)
    tr.begin(O.request_track(0), "decode", t=0.0)
    for i in range(20):  # overflow the ring: open span must survive
        tr.instant(O.engine_track(), f"i{i}", t=float(i))
    assert tr.n_dropped > 0 and tr.open_spans() == [(("request", 0),
                                                    "decode")]
    O.validate_perfetto(tr.to_perfetto())

    # pillar 3: a real (tiny) serve, obs on, then the off contract
    ns = argparse.Namespace(arch="llama3.2-1b", seed=0, slots=2, max_len=64,
                            cache="paged", block_size=8, n_blocks=0,
                            requests=4, max_new=4)
    eng, cfg = build_engine(ns)
    outs = run_serve(eng, cfg, ns)
    assert len(outs) == 4 and all(len(o) > 0 for o in outs)
    doc = O.serving_obs_doc(eng)
    json.loads(json.dumps(doc))
    for key in ("ttft_p50_ms", "ttft_p99_ms", "decode_step_p50_ms",
                "decode_step_p99_ms", "pool_peak_used", "preempted",
                "deferred"):
        assert key in doc["headline"], key
    # the prefix-cache gauges (radix tree + retention) must surface in
    # both the paged pool section and the lazy-gauge metrics
    for g in ("prefix_tree_nodes", "prefix_retained_pages",
              "prefix_hit_tokens", "prefix_evicted"):
        assert g in doc["pool"], (g, sorted(doc["pool"]))
        assert g in doc["metrics"], (g, sorted(doc["metrics"]))
    assert doc["pool"]["request_page_hwm"] == eng.pm.request_page_hwm.max
    counts = O.validate_perfetto(eng.obs.trace.to_perfetto())
    assert counts.get("X", 0) > 0 and counts.get("M", 0) > 0
    for r in range(4):  # exactly one terminal event per request
        evs = O.request_events(eng.obs.trace, r)
        assert sum(e["name"] == "finish" for e in evs) == 1, (r, evs)

    # paged_q8: the quantized pool's lazy gauges must surface in the doc
    ns_q8 = argparse.Namespace(arch="llama3.2-1b", seed=0, slots=2,
                               max_len=64, cache="paged_q8", block_size=8,
                               n_blocks=0, requests=2, max_new=2)
    eng_q8, cfg_q8 = build_engine(ns_q8)
    run_serve(eng_q8, cfg_q8, ns_q8)
    doc_q8 = O.serving_obs_doc(eng_q8)
    for g in ("q8_pool_bytes", "q8_bytes_saved_vs_fp16"):
        assert g in doc_q8["metrics"], (g, sorted(doc_q8["metrics"]))
        assert doc_q8["metrics"][g]["value"] > 0, g

    from repro.serving.engine import Engine  # off mode: NULL observer
    assert O.NULL.enabled is False and O.NULL.clock() == 0.0
    assert O.get_active() is O.NULL
    del Engine
    print("obsdump selftest OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--selftest", action="store_true",
                    help="structural validation of every export (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the serving obs doc (metrics + headline)")
    ap.add_argument("--perfetto", metavar="PATH",
                    help="write the Perfetto trace_event JSON")
    ap.add_argument("--prometheus", metavar="PATH",
                    help="write Prometheus text format ('-' for stdout)")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--cache", default="paged",
                    choices=("dense", "paged", "paged_q8"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--n-blocks", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()

    from repro import obs as O
    eng, cfg = build_engine(args)
    run_serve(eng, cfg, args)
    if args.json:
        O.write_json(args.json, O.serving_obs_doc(eng))
        print(f"wrote {args.json}")
    if args.perfetto:
        doc = eng.obs.trace.to_perfetto()
        O.validate_perfetto(doc)
        O.write_json(args.perfetto, doc)
        print(f"wrote {args.perfetto} (open at https://ui.perfetto.dev)")
    if args.prometheus:
        text = eng.metrics.to_prometheus()
        if args.prometheus == "-":
            sys.stdout.write(text)
        else:
            with open(args.prometheus, "w") as fh:
                fh.write(text)
            print(f"wrote {args.prometheus}")
    print(summarize(eng))
    return 0


if __name__ == "__main__":
    sys.exit(main())
