import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis → change → measure → verdict.

Runs a sequence of configurations for one (arch × shape) cell, records the
three roofline terms + per-chip peak memory per step, and appends a
markdown log to artifacts/hillclimb/<cell>.md.

  PYTHONPATH=src python tools/hillclimb.py --cell qwen_train
"""
import argparse
import json
import sys

sys.path.insert(0, "src")


def fmt(r):
    rf = r["roofline"]
    return (f"compute={rf['compute_s']:.3f}s memory={rf['memory_s']:.3f}s "
            f"collective={rf['collective_s']:.3f}s "
            f"peak={(r['memory']['peak_bytes'] or 0) / 1e9:.1f}GB "
            f"args={(r['memory']['argument_bytes'] or 0) / 1e9:.1f}GB")


CELLS = {
    # (arch, shape, [(step_name, hypothesis, kwargs), ...])
    "qwen_train": ("qwen2.5-32b", "train_4k", [
        ("baseline", "paper-agnostic baseline: fp32 master params+Adam "
         "(model-sharded only), fp32 logits unsharded over vocab", {}),
        ("H1_shard_logits",
         "fp32 (B,S,V) logits buffer ~40GB/chip dominates temp bytes; "
         "constraining logits+loss to (dp, vocab-tp) should cut peak memory "
         "by O(10GB) and memory term accordingly",
         dict(shard_logits=True)),
        ("H2_zero1",
         "params+Adam fp32 are 24.6GB/chip (replicated over data axis). "
         "ZeRO-1 shards mu/nu over dp=16: -15GB args; grad all-reduce "
         "becomes reduce-scatter + small param all-gather: collective "
         "bytes should drop ~40%",
         dict(shard_logits=True, zero1=True)),
        ("H3_bf16_params",
         "bf16 param storage (fp32 Adam master kept): halves param bytes "
         "read per step and halves gradient collective payloads",
         dict(shard_logits=True, zero1=True,
              cfg_overrides={"param_dtype": "bfloat16"})),
        ("H4_seq_parallel",
         "per-layer saved stream carries (64·16·4096·5120·2B ≈ 10.7GB) are "
         "replicated over the model axis; SP-sharding them divides by 16",
         dict(shard_logits=True, zero1=True, shard_stream=True,
              cfg_overrides={"param_dtype": "bfloat16"})),
        ("H5_grad_accum2",
         "remaining peak 30.7GB > 16GB v5e: live activations scale with the "
         "microbatch — 2 accumulation microbatches halve them (and let XLA "
         "overlap microbatch i's grad reduce with i+1's compute)",
         dict(shard_logits=True, zero1=True, shard_stream=True, grad_accum=2,
              cfg_overrides={"param_dtype": "bfloat16"})),
        ("H6_grad_accum4",
         "one more halving: 4 microbatches should land under the 16GB "
         "budget; compute/collective terms should stay ~flat",
         dict(shard_logits=True, zero1=True, shard_stream=True, grad_accum=4,
              cfg_overrides={"param_dtype": "bfloat16"})),
    ]),
    "mamba2_train": ("mamba2-2.7b", "train_4k", [
        ("baseline", "SSD chunked scan, fp32 logits, fp32 params", {}),
        ("H1_shard_logits",
         "padded-vocab (50304) fp32 logits = 13GB/chip of the 15.3GB peak; "
         "sharding over vocab-tp should collapse peak memory",
         dict(shard_logits=True)),
        ("H2_zero1",
         "ZeRO-1 over dp: cuts fp32 Adam args ~2GB/chip and gradient "
         "collective bytes",
         dict(shard_logits=True, zero1=True)),
        ("H5_chunk128",
         "SSD intra-chunk buffers scale O(L·chunk); chunk 256→128 halves "
         "the (L,L) kernel buffer with 2x more inter-chunk steps (cheap: "
         "state is (P,N)=8k elements); expect temp bytes down, flops ~flat",
         dict(shard_logits=True, zero1=True,
              cfg_overrides={"ssm_chunk": 128})),
        ("H6_seq_parallel",
         "remaining peak = per-layer stream carries saved for backward "
         "(64·B·S·d·2B ≈ 21GB replicated over the model axis). Sequence-"
         "sharding the layer-boundary stream (SP) divides that by tp=16",
         dict(shard_logits=True, zero1=True, shard_stream=True,
              cfg_overrides={"ssm_chunk": 128})),
        ("H7_noremat",
         "with SP freeing ~14GB, activation rematerialization is no longer "
         "needed: remat off should cut the compute term ~25% (no recompute) "
         "at an acceptable peak increase",
         dict(shard_logits=True, zero1=True, shard_stream=True, remat=False,
              cfg_overrides={"ssm_chunk": 128})),
        ("H7b_remat_dots",
         "middle ground: keep matmul outputs (dots_saveable), recompute the "
         "elementwise glue — should recover part of the 25% recompute "
         "saving at a bounded peak increase (no SP: it hurt the SSD scan)",
         dict(shard_logits=True, zero1=True, remat="dots",
              cfg_overrides={"ssm_chunk": 128})),
    ]),
    "qwen_decode": ("qwen2.5-32b", "decode_32k", [
        ("baseline", "fp32 serving weights, standard residual blocks", {}),
        ("H7_bf16_weights",
         "decode at batch 128 is weight-streaming bound: bf16 weights halve "
         "the dominant memory term",
         dict(cfg_overrides={"param_dtype": "bfloat16"})),
        ("H8_paper_qp_removal",
         "the paper's technique: skipless_merged removes Q+P = 10.2% of "
         "weights -> weight-streaming bytes down ~10% on top of bf16 "
         "(paper predicts 1.11x for qwen-32B)",
         dict(block_style="skipless_merged",
              cfg_overrides={"param_dtype": "bfloat16"})),
        ("H8b_paper_faithful_fp32",
         "paper-faithful comparison point: QP removal alone on fp32 "
         "weights (isolates the paper's contribution from the bf16 lever)",
         dict(block_style="skipless_merged")),
    ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    args = ap.parse_args()

    from repro.launch.dryrun import roofline_terms, run_cell

    arch, shape, steps = CELLS[args.cell]
    os.makedirs("artifacts/hillclimb", exist_ok=True)
    md_path = f"artifacts/hillclimb/{args.cell}.md"
    json_path = f"artifacts/hillclimb/{args.cell}.json"
    results = []
    lines = [f"### Hillclimb: {arch} × {shape}\n"]
    prev = None
    for name, hypothesis, kw in steps:
        rec = run_cell(arch, shape, **kw)
        rec["roofline"] = roofline_terms(rec)
        rec["step"] = name
        results.append(rec)
        line = f"* **{name}** — _{hypothesis}_\n  * result: {fmt(rec)}"
        if prev is not None:
            d = {k: rec["roofline"][k] - prev["roofline"][k]
                 for k in ("compute_s", "memory_s", "collective_s")}
            dm = ((rec["memory"]["peak_bytes"] or 0)
                  - (prev["memory"]["peak_bytes"] or 0)) / 1e9
            line += (f"\n  * delta vs prev: compute {d['compute_s']:+.3f}s, "
                     f"memory {d['memory_s']:+.3f}s, "
                     f"collective {d['collective_s']:+.3f}s, peak {dm:+.1f}GB")
        lines.append(line)
        print(f"[{name}] {fmt(rec)}", flush=True)
        prev = rec
    with open(md_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"wrote {md_path}")


if __name__ == "__main__":
    main()
