"""Shared int8 (q8) KV quantization helpers for the ``paged_q8`` cache kind.

Layout contract (mirrors the fp paged pool, ``serving.paged_kv_cache``):
  * pools are int8 pages (…, NB, bs, Hkv, Dh);
  * every (page, kv-head) pair owns ONE float32 scale — the scale arrays
    are (…, NB, Hkv) and travel with their page through CoW / recycle;
  * dequant is ``ints * scale`` (symmetric, zero-point-free: RoPE'd K and
    V are zero-centred, and a zero-point would break the "unwritten page
    dequantizes to exactly 0" property the causal mask relies on).

Determinism contract: quantize-on-write runs in plain XLA inside every
impl's program (xla / pallas / pallas_interpret share it), so the pool
BITS are impl-independent; only the dequantizing attention read differs
per impl.  Whole-prompt prefill, chunked prefill, and the in-attention
fake-quant all route through ``q8_quantize_pages`` on identically masked
inputs, which is what makes prefill attention see bit-exactly what decode
later reads back from the pool.

Decode appends use a MONOTONE per-page scale merge (``q8_append_token``):
the page scale only grows while the page is live, so already-stored
tokens are only ever rescaled by a ratio <= 1 and tokens quantized while
the scale was already final are bit-stable.  A page's scale resets when
decode enters it at offset 0 (fresh/recycled pages hold stale garbage —
content AND scale — that the causal mask hides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Q8_MAX = 127.0
# floor for every stored scale: keeps ratio/quantize divisions finite on
# all-zero blocks without changing their (all-zero) quantized content
Q8_EPS = 1e-8


def q8_quantize_pages(blocks: jnp.ndarray):
    """Quantize block-shaped KV: (..., nbk, bs, Hkv, D) float ->
    ((..., nbk, bs, Hkv, D) int8, (..., nbk, Hkv) float32 scales).

    One scale per (block, kv head) = absmax/127 over the block's (bs, D)
    entries — exactly the pool's scale granularity."""
    x = blocks.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(-3, -1))  # (..., nbk, Hkv)
    scale = jnp.maximum(amax / Q8_MAX, Q8_EPS)
    ints = jnp.clip(jnp.round(x / scale[..., :, None, :, None]),
                    -Q8_MAX, Q8_MAX).astype(jnp.int8)
    return ints, scale


def q8_quantize_seq(kv: jnp.ndarray, block_size: int, valid=None):
    """Quantize a sequence-major KV tensor at pool granularity.

    kv (B, S, Hkv, D) float with S % block_size == 0; ``valid`` (B, S)
    bool masks bucket padding / positions >= true_len to zero BEFORE the
    per-block absmax, so padding garbage never inflates a real block's
    scale (and the resulting bits match what ``_finish_paged_q8`` /
    chunked writes store, which mask identically).
    Returns ((B, S, Hkv, D) int8, (B, S//block_size, Hkv) float32)."""
    B, S, Hkv, D = kv.shape
    x = kv.astype(jnp.float32)
    if valid is not None:
        x = jnp.where(valid[..., None, None], x, 0.0)
    nbk = S // block_size
    ints, scale = q8_quantize_pages(x.reshape(B, nbk, block_size, Hkv, D))
    return ints.reshape(B, S, Hkv, D), scale


def q8_dequant_seq(ints: jnp.ndarray, scale: jnp.ndarray, out_dtype):
    """Inverse of ``q8_quantize_seq``: (B, S, Hkv, D) int8 +
    (B, nbk, Hkv) scales -> (B, S, Hkv, D) ``out_dtype``."""
    B, S, Hkv, D = ints.shape
    bs = S // scale.shape[1]
    s = jnp.repeat(scale, bs, axis=1)  # (B, S, Hkv)
    return (ints.astype(jnp.float32) * s[..., None]).astype(out_dtype)


def q8_append_token(pool: jnp.ndarray, scale: jnp.ndarray,
                    new_tok: jnp.ndarray, safe: jnp.ndarray,
                    off: jnp.ndarray):
    """Quantize-on-write of one decode token per batch slot.

    pool (NB, bs, Hkv, D) int8, scale (NB, Hkv) f32, new_tok (B, Hkv, D)
    float, safe (B,) physical page (== NB drops the write — unmapped
    slot), off (B,) in-page offset.  Monotone scale merge: at off == 0
    the page is being (re-)entered — fresh alloc, ring recycle, or
    detach — so its stale scale is ignored and reset from this token;
    at off > 0 the page's live prefix was written by prefill/chunk/
    earlier decode steps under a valid scale, which only GROWS
    (new = max(old, tok)), with the stored ints rescaled by old/new <= 1
    when it does (a no-op round when it does not)."""
    NB = pool.shape[0]
    read = jnp.minimum(safe, NB - 1)  # in-range gather; dropped writes
    newf = new_tok.astype(jnp.float32)  # (B, Hkv, D)
    tok_scale = jnp.maximum(jnp.max(jnp.abs(newf), axis=-1) / Q8_MAX, Q8_EPS)
    old = scale[read]  # (B, Hkv)
    fresh = (off == 0)[:, None]  # (B, 1) — first write of this page
    base = jnp.where(fresh, Q8_EPS, old)
    new_scale = jnp.maximum(base, tok_scale)
    page = pool[read].astype(jnp.float32)  # (B, bs, Hkv, D)
    ratio = jnp.where(fresh, 1.0, base / new_scale)  # <= 1; fresh skips
    page = jnp.clip(jnp.round(page * ratio[:, None, :, None]),
                    -Q8_MAX, Q8_MAX)
    tok_q = jnp.clip(jnp.round(newf / new_scale[..., None]), -Q8_MAX, Q8_MAX)
    page = jax.vmap(
        lambda pg, t, o: jax.lax.dynamic_update_slice(pg, t[None], (o, 0, 0))
    )(page, tok_q, off)
    pool = pool.at[safe].set(page.astype(jnp.int8), mode="drop")
    scale = scale.at[safe].set(new_scale, mode="drop")
    return pool, scale
