"""jit'd wrappers exposing the Pallas kernels in model-layer layouts.

These adapt (B, S, H, D) model tensors to the kernels' head-major layouts,
enforce blocking constraints, and fall back loudly (assert) rather than
silently when an unsupported configuration is requested.  ``interpret=True``
runs the kernel bodies in Python on CPU (how this container validates them);
on TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (flash_attention_bhsd,
                                           flash_attention_merged_bsd,
                                           flash_attention_merged_q8_bsd)
from repro.kernels.decode_attention import (decode_attention_bhsd,
                                            decode_attention_merged_bsd,
                                            decode_attention_paged_bhsd,
                                            decode_attention_paged_merged_bsd,
                                            decode_attention_paged_q8_bhsd,
                                            decode_attention_paged_q8_merged_bsd)
from repro.kernels.paging import paged_ring_active
from repro.kernels.ssd_scan import ssd_scan_pallas


def _pick_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (prefers multiples of 128)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


@partial(jax.jit, static_argnames=("causal", "sliding_window", "interpret",
                                   "block_q", "block_k"))
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    q_positions=None,  # accepted for API parity; kernel assumes arange
    kv_positions=None,
    causal: bool = True,
    sliding_window: int = 0,
    kv_valid=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    assert kv_valid is None, "flash kernel: use the decode kernel for padded caches"
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, sliding_window=sliding_window,
        block_q=bq, block_k=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)  # back to (B, Sq, Hq, D)


@partial(jax.jit, static_argnames=("n_kv_heads", "causal", "sliding_window",
                                   "interpret", "block_q", "block_k"))
def flash_attention_merged(
    u: jnp.ndarray,  # (B, Sq, d_model) — RoPE'd residual stream = merged query
    k: jnp.ndarray,  # (B, Sk, Hkv, D) — K*, native layout
    v: jnp.ndarray,  # (B, Sk, Hkv, D) — V*, native layout
    *,
    n_kv_heads: int,
    q_positions=None,  # accepted for API parity; kernel assumes arange
    kv_positions=None,
    causal: bool = True,
    sliding_window: int = 0,
    kv_valid=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) flash PREFILL -> (B, Sq, d_model) FFN-input
    stream.

    No q projection exists in merged configs, so the stream is handed to
    the kernel directly — the (B, Sq, Hq, D) view is a bitcast — and
    K*/V* are consumed in their native sequence-major layout: none of the
    four head-major transposes of the generic ``flash_attention`` wrapper
    appear in the program.
    """
    assert kv_valid is None, "flash kernel: use the decode kernel for padded caches"
    B, Sq, d = u.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hkv == n_kv_heads, (Hkv, n_kv_heads)
    D = k.shape[3]
    assert d % D == 0 and (d // D) % Hkv == 0, (d, D, Hkv)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    out = flash_attention_merged_bsd(
        u.reshape(B, Sq, d // D, D), k, v,
        causal=causal, sliding_window=sliding_window,
        block_q=bq, block_k=bk, interpret=interpret)
    return out.reshape(B, Sq, d)


@partial(jax.jit, static_argnames=("sliding_window", "interpret", "block_k"))
def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    *,
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    bk = _pick_block(S, block_k)
    out = decode_attention_bhsd(
        q.reshape(B, Hkv, G, D),
        k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
        kv_positions.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, block_k=bk, interpret=interpret)
    return out.reshape(B, Hq, D)


@partial(jax.jit, static_argnames=("n_kv_heads", "sliding_window", "interpret",
                                   "block_k"))
def decode_attention_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k_cache: jnp.ndarray,  # (B, S, Hkv, D) — K*, native serving layout
    v_cache: jnp.ndarray,  # (B, S, Hkv, D) — V*, native layout
    *,
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) decode fast path -> (B, d_model) FFN-input stream.

    No q projection exists in merged configs, so the stream is handed to
    the kernel directly — the (B, Hq, D) view is a bitcast, and the cache
    is consumed untransposed.
    """
    B, d = u.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert Hkv == n_kv_heads, (Hkv, n_kv_heads)
    D = k_cache.shape[3]
    assert d % D == 0 and (d // D) % Hkv == 0, (d, D, Hkv)
    bk = _pick_block(S, block_k)
    out = decode_attention_merged_bsd(
        u.reshape(B, d // D, D), k_cache, v_cache,
        kv_positions.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, block_k=bk, interpret=interpret)
    return out.reshape(B, d)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, a, Bm, Cm, chunk, interpret):
    return ssd_scan_pallas(x, dt, a, Bm, Cm, chunk=chunk, interpret=interpret)


def ssd_scan(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    A: jnp.ndarray,  # (H,) negative
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
    *,
    chunk: int,
    D: Optional[jnp.ndarray] = None,
    init_state=None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    assert init_state is None, (
        "pallas ssd kernel starts from zero state; use impl='xla' for "
        "mid-sequence continuation")
    a = (dt.astype(jnp.float32) * A.astype(jnp.float32)).astype(jnp.float32)
    y, fin = _ssd_jit(x, dt.astype(jnp.float32), a, Bm, Cm,
                      chunk=min(chunk, x.shape[1]), interpret=interpret)
    if D is not None:
        y = y + D[None, None, :, None].astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(x.dtype), fin


@partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def decode_attention_paged(
    q: jnp.ndarray,  # (B, Hq, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — physical page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Generic decode attention over a paged KV pool (block-table gather).

    Ring addressing (windowed tables bounded at ceil(window/bs)+1 recycled
    slots) is derived from the static window and the table width — see
    ``kernels.paging`` — so callers never thread a ring flag."""
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    ring = paged_ring_active(sliding_window, k_pool.shape[1],
                             block_tables.shape[1])
    out = decode_attention_paged_bhsd(
        q.reshape(B, Hkv, G, D), k_pool, v_pool,
        block_tables.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, ring_blocks=ring, interpret=interpret)
    return out.reshape(B, Hq, D)


@partial(jax.jit, static_argnames=("n_kv_heads", "sliding_window",
                                   "interpret"))
def decode_attention_paged_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — K* page pool, native layout
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — V* page pool
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) decode fast path over a paged KV pool.  Ring
    addressing derived as in ``decode_attention_paged``."""
    B, d = u.shape
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    assert Hkv == n_kv_heads, (Hkv, n_kv_heads)
    assert d % D == 0 and (d // D) % Hkv == 0, (d, D, Hkv)
    ring = paged_ring_active(sliding_window, k_pool.shape[1],
                             block_tables.shape[1])
    out = decode_attention_paged_merged_bsd(
        u.reshape(B, d // D, D), k_pool, v_pool,
        block_tables.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, ring_blocks=ring, interpret=interpret)
    return out.reshape(B, d)


# ---------------------------------------------------------------------------
# quantized (paged_q8) wrappers: int8 pools + per-(page, head) scales
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def decode_attention_paged_q8(
    q: jnp.ndarray,  # (B, Hq, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    *,
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Generic decode attention over an int8 paged pool — the q8 face of
    ``decode_attention_paged``: same block-table gather and ring
    derivation, with the gathered page dequantized inside the kernel from
    its scalar-prefetched scale."""
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    ring = paged_ring_active(sliding_window, k_pool.shape[1],
                             block_tables.shape[1])
    out = decode_attention_paged_q8_bhsd(
        q.reshape(B, Hkv, G, D), k_pool, v_pool, k_scale, v_scale,
        block_tables.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, ring_blocks=ring, interpret=interpret)
    return out.reshape(B, Hq, D)


@partial(jax.jit, static_argnames=("n_kv_heads", "sliding_window",
                                   "interpret"))
def decode_attention_paged_q8_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 K* page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 V* page pool
    *,
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) decode fast path over an int8 paged pool."""
    B, d = u.shape
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    assert Hkv == n_kv_heads, (Hkv, n_kv_heads)
    assert d % D == 0 and (d // D) % Hkv == 0, (d, D, Hkv)
    ring = paged_ring_active(sliding_window, k_pool.shape[1],
                             block_tables.shape[1])
    out = decode_attention_paged_q8_merged_bsd(
        u.reshape(B, d // D, D), k_pool, v_pool, k_scale, v_scale,
        block_tables.astype(jnp.int32), q_position.astype(jnp.int32)[:, None],
        sliding_window=sliding_window, ring_blocks=ring, interpret=interpret)
    return out.reshape(B, d)


@partial(jax.jit, static_argnames=("n_kv_heads", "causal", "sliding_window",
                                   "interpret", "block_q", "block_k"))
def flash_attention_merged_q8(
    u: jnp.ndarray,  # (B, Sq, d_model) — RoPE'd residual stream = merged query
    k: jnp.ndarray,  # (B, Sk, Hkv, D) int8 — K* at pool quantization
    v: jnp.ndarray,  # (B, Sk, Hkv, D) int8 — V*
    *,
    k_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32 per-(page, head)
    v_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32
    n_kv_heads: int,
    q_positions=None,  # accepted for API parity; kernel assumes arange
    kv_positions=None,
    causal: bool = True,
    sliding_window: int = 0,
    kv_valid=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) flash PREFILL over int8 K*/V* — the q8 face of
    ``flash_attention_merged``; dequant happens tile-by-tile inside the
    kernel (no full-precision K/V buffer in the program).  The kv block is
    sized in whole serving pages, so ``block_k`` is a cap, not exact."""
    assert kv_valid is None, "flash kernel: use the decode kernel for padded caches"
    B, Sq, d = u.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hkv == n_kv_heads, (Hkv, n_kv_heads)
    D = k.shape[3]
    assert d % D == 0 and (d // D) % Hkv == 0, (d, D, Hkv)
    bq = _pick_block(Sq, block_q)
    out = flash_attention_merged_q8_bsd(
        u.reshape(B, Sq, d // D, D), k, v, k_scale, v_scale,
        causal=causal, sliding_window=sliding_window,
        block_q=bq, block_k=block_k, interpret=interpret)
    return out.reshape(B, Sq, d)


# ---------------------------------------------------------------------------
# attention-kernel table: the kernel-layer face of the serving backend
# registries (models.backends' AttentionBackend AND PrefillBackend)
# ---------------------------------------------------------------------------

# keyed (phase, cache_kind, style) — like models.backends plus the phase
# axis, minus the impl axis (every wrapper here IS the pallas route;
# ``interpret=True`` is the CPU-validation mode of the same kernel).
# models.attention's cores fetch their pallas path here, so "which (phase ×
# cache layout × projection style) combos have a fused kernel" is read off
# one table instead of eight call sites.  Prefill COMPUTE is cache-kind-
# independent — paging changes where the collected KV is written (see
# ``models.transformer``'s paged prefill backend), not the attention math —
# so both prefill cache kinds map to the same flash wrapper.
ATTENTION_KERNELS = {
    ("decode", "dense", "generic"): decode_attention,
    ("decode", "dense", "merged"): decode_attention_merged,
    ("decode", "paged", "generic"): decode_attention_paged,
    ("decode", "paged", "merged"): decode_attention_paged_merged,
    ("prefill", "dense", "generic"): flash_attention,
    ("prefill", "dense", "merged"): flash_attention_merged,
    ("prefill", "paged", "generic"): flash_attention,
    ("prefill", "paged", "merged"): flash_attention_merged,
    # q8: decode dequantizes pool pages in-kernel; merged prefill
    # dequantizes fake-quantized kv tiles in-kernel; the generic q8
    # prefill dequantizes upstream (models.transformer) and rides the
    # plain flash kernel.
    ("decode", "paged_q8", "generic"): decode_attention_paged_q8,
    ("decode", "paged_q8", "merged"): decode_attention_paged_q8_merged,
    ("prefill", "paged_q8", "generic"): flash_attention,
    ("prefill", "paged_q8", "merged"): flash_attention_merged_q8,
}


def attention_kernel(phase: str, cache_kind: str, style: str):
    """Pallas attention kernel wrapper for one (phase, cache_kind, style)
    combo; unknown combos raise KeyError naming the registered ones."""
    try:
        return ATTENTION_KERNELS[(phase, cache_kind, style)]
    except KeyError:
        raise KeyError(
            f"no Pallas attention kernel for (phase={phase!r}, "
            f"cache_kind={cache_kind!r}, style={style!r}); available: "
            f"{sorted(ATTENTION_KERNELS)}") from None


# backward-compatible decode view of the unified table
DECODE_KERNELS = {(ck, st): fn for (ph, ck, st), fn in ATTENTION_KERNELS.items()
                  if ph == "decode"}


def decode_kernel(cache_kind: str, style: str):
    """Pallas decode kernel wrapper for one (cache_kind, style) combo;
    unknown combos raise KeyError naming the registered ones.  (The decode
    face of ``attention_kernel`` — kept for existing callers.)"""
    try:
        return DECODE_KERNELS[(cache_kind, style)]
    except KeyError:
        raise KeyError(
            f"no Pallas decode kernel for (cache_kind={cache_kind!r}, "
            f"style={style!r}); available: {sorted(DECODE_KERNELS)}") from None
