"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically transparent O(S²)/sequential version;
kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def ref_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    sliding_window: int = 0,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= cols <= rows
    if sliding_window > 0:
        mask &= rows - cols < sliding_window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def ref_flash_attention_merged(
    u: jnp.ndarray,  # (B, Sq, d_model) — RoPE'd residual stream = merged query
    k: jnp.ndarray,  # (B, Sk, Hkv, D) — native (sequence-major) layout
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    n_kv_heads: int,
    causal: bool = True,
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged flash PREFILL kernel: view the stream as
    grouped heads, defer to the generic attention oracle, return in the
    stream (FFN-input) basis."""
    B, Sq, d = u.shape
    D = k.shape[3]
    o = ref_attention(
        u.reshape(B, Sq, d // D, D).transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, sliding_window=sliding_window)
    return o.transpose(0, 2, 1, 3).reshape(B, Sq, d)


def ref_decode_attention(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
) -> jnp.ndarray:
    B, Hkv, G, D = q.shape
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qpos = q_position[:, 0][:, None]
    ok = (kv_positions >= 0) & (kv_positions <= qpos)
    if sliding_window > 0:
        ok &= qpos - kv_positions < sliding_window
    s = jnp.where(ok[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_decode_attention_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k: jnp.ndarray,  # (B, S, Hkv, D) — native serving cache layout
    v: jnp.ndarray,  # (B, S, Hkv, D)
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    n_kv_heads: int,
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged decode kernel: reshape the stream to grouped
    heads and defer to the generic decode oracle; output back in stream
    (FFN-input) basis."""
    B, d = u.shape
    D = k.shape[3]
    G = d // D // n_kv_heads
    o = ref_decode_attention(
        u.reshape(B, n_kv_heads, G, D), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), kv_positions, q_position,
        sliding_window=sliding_window)
    return o.reshape(B, d)


def ref_ssd(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    a: jnp.ndarray,  # (B, S, H) = dt * A
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential (step-by-step) SSD recurrence — the ground truth.
    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xs, dts, as_, bs, cs = inp  # (B,H,P), (B,H), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(as_)[..., None, None]  # (B,H,1,1)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dts, bs, xs)
        state = state * decay + dBx
        y = jnp.einsum("bhn,bhpn->bhp", cs, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    fin, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), fin


# ---------------------------------------------------------------------------
# paged decode oracles (block-table gather over a physical page pool)
# ---------------------------------------------------------------------------

def ref_paged_gather(
    pool: jnp.ndarray,  # (NB, bs, Hkv, D) physical page pool
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
) -> jnp.ndarray:
    """Densify a slot's logical view: -> (B, MB*bs, Hkv, D).

    Unmapped blocks gather page 0; callers must mask them via
    ``ref_paged_positions`` (-1 there)."""
    B, MB = block_tables.shape
    bs = pool.shape[1]
    k = pool[jnp.maximum(block_tables, 0)]  # (B, MB, bs, Hkv, D)
    return k.reshape(B, MB * bs, *pool.shape[2:])


def ref_paged_positions(block_tables: jnp.ndarray, block_size: int,
                        q_position: jnp.ndarray = None, ring_blocks: int = 0
                        ) -> jnp.ndarray:
    """kv positions of the densified view; unmapped blocks are -1
    (empty-slot convention).

    Absolute addressing (``ring_blocks`` = 0): logical block j covers
    [j*bs, (j+1)*bs).  Ring addressing (windowed tables bounded at
    ceil(window/bs)+1 recycled slots — ``kernels.paging``): slot j holds
    the latest absolute block ≡ j (mod ring) not beyond the query's block,
    reconstructed from ``q_position``; never-entered slots (b < 0) are -1.
    """
    B, MB = block_tables.shape
    if ring_blocks:
        j = jnp.arange(MB, dtype=jnp.int32)[None, :]
        lb = (jnp.asarray(q_position, jnp.int32) // block_size)
        lb = lb.reshape(B, 1)
        b = lb - ((lb + ring_blocks - j) % ring_blocks)
        pos = jnp.repeat(b * block_size, block_size, axis=1) + \
            jnp.tile(jnp.arange(block_size, dtype=jnp.int32), MB)[None, :]
        mapped = jnp.repeat((block_tables >= 0) & (b >= 0), block_size,
                            axis=1)
        return jnp.where(mapped, pos, -1)
    pos = jnp.arange(MB * block_size, dtype=jnp.int32)[None, :]
    mapped = jnp.repeat(block_tables >= 0, block_size, axis=1)
    return jnp.where(mapped, pos, -1)


def ref_decode_attention_paged(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    block_tables: jnp.ndarray,  # (B, MB) int32, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
) -> jnp.ndarray:
    """Oracle for the paged decode kernel: gather the slot's pages into a
    dense (B, S, Hkv, D) view and defer to the dense decode oracle.
    ``ring_blocks`` > 0 reconstructs ring-addressed slot positions from
    the query position (``ref_paged_positions``)."""
    bs = k_pool.shape[1]
    k = ref_paged_gather(k_pool, block_tables).transpose(0, 2, 1, 3)
    v = ref_paged_gather(v_pool, block_tables).transpose(0, 2, 1, 3)
    kv_pos = ref_paged_positions(block_tables, bs, q_position, ring_blocks)
    return ref_decode_attention(q, k, v, kv_pos, q_position[:, None],
                                sliding_window=sliding_window)


def ref_decode_attention_paged_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    block_tables: jnp.ndarray,  # (B, MB) int32, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    *,
    n_kv_heads: int,
    sliding_window: int = 0,
    ring_blocks: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged paged kernel: stream reshaped to grouped heads,
    pages densified, output back in the stream (FFN-input) basis."""
    B, d = u.shape
    D = k_pool.shape[3]
    G = d // D // n_kv_heads
    o = ref_decode_attention_paged(
        u.reshape(B, n_kv_heads, G, D), k_pool, v_pool, block_tables,
        q_position, sliding_window=sliding_window, ring_blocks=ring_blocks)
    return o.reshape(B, d)


# ---------------------------------------------------------------------------
# quantized (paged_q8) oracles: dequantize, defer to the fp oracles
# ---------------------------------------------------------------------------

def ref_q8_dequant_pool(pool: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """(NB, bs, Hkv, D) int8 + (NB, Hkv) f32 -> (NB, bs, Hkv, D) f32."""
    return pool.astype(jnp.float32) * scale[:, None, :, None]


def ref_decode_attention_paged_q8(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    k_scale: jnp.ndarray,  # (NB, Hkv) float32
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
) -> jnp.ndarray:
    """Oracle for the q8 paged decode kernel: dequantize the whole pool in
    float32 (the transparency the kernel explicitly avoids) and defer to
    the fp paged oracle."""
    return ref_decode_attention_paged(
        q, ref_q8_dequant_pool(k_pool, k_scale),
        ref_q8_dequant_pool(v_pool, v_scale), block_tables, q_position,
        sliding_window=sliding_window, ring_blocks=ring_blocks)


def ref_decode_attention_paged_q8_merged(
    u: jnp.ndarray,  # (B, d_model)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    k_scale: jnp.ndarray,  # (NB, Hkv) float32
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    *,
    n_kv_heads: int,
    sliding_window: int = 0,
    ring_blocks: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged q8 paged decode kernel."""
    return ref_decode_attention_paged_merged(
        u, ref_q8_dequant_pool(k_pool, k_scale),
        ref_q8_dequant_pool(v_pool, v_scale), block_tables, q_position,
        n_kv_heads=n_kv_heads, sliding_window=sliding_window,
        ring_blocks=ring_blocks)


def ref_flash_attention_merged_q8(
    u: jnp.ndarray,  # (B, Sq, d_model)
    k: jnp.ndarray,  # (B, Sk, Hkv, D) int8
    v: jnp.ndarray,  # (B, Sk, Hkv, D) int8
    k_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32
    v_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32
    *,
    n_kv_heads: int,
    causal: bool = True,
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged q8 flash PREFILL kernel: expand the per-page
    scales across their rows, dequantize, defer to the fp merged oracle."""
    Sk = k.shape[1]
    sg = Sk // k_scale.shape[1]
    ks = jnp.repeat(k_scale, sg, axis=1)  # (B, Sk, Hkv)
    vs = jnp.repeat(v_scale, sg, axis=1)
    return ref_flash_attention_merged(
        u, k.astype(jnp.float32) * ks[..., None],
        v.astype(jnp.float32) * vs[..., None],
        n_kv_heads=n_kv_heads, causal=causal, sliding_window=sliding_window)
