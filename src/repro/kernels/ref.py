"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically transparent O(S²)/sequential version;
kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def ref_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    sliding_window: int = 0,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= cols <= rows
    if sliding_window > 0:
        mask &= rows - cols < sliding_window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def ref_decode_attention(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
) -> jnp.ndarray:
    B, Hkv, G, D = q.shape
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qpos = q_position[:, 0][:, None]
    ok = (kv_positions >= 0) & (kv_positions <= qpos)
    if sliding_window > 0:
        ok &= qpos - kv_positions < sliding_window
    s = jnp.where(ok[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_decode_attention_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream = merged query
    k: jnp.ndarray,  # (B, S, Hkv, D) — native serving cache layout
    v: jnp.ndarray,  # (B, S, Hkv, D)
    kv_positions: jnp.ndarray,  # (B, S) int32, -1 empty
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    n_kv_heads: int,
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Oracle for the merged decode kernel: reshape the stream to grouped
    heads and defer to the generic decode oracle; output back in stream
    (FFN-input) basis."""
    B, d = u.shape
    D = k.shape[3]
    G = d // D // n_kv_heads
    o = ref_decode_attention(
        u.reshape(B, n_kv_heads, G, D), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), kv_positions, q_position,
        sliding_window=sliding_window)
    return o.reshape(B, d)


def ref_ssd(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    a: jnp.ndarray,  # (B, S, H) = dt * A
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential (step-by-step) SSD recurrence — the ground truth.
    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xs, dts, as_, bs, cs = inp  # (B,H,P), (B,H), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(as_)[..., None, None]  # (B,H,1,1)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dts, bs, xs)
        state = state * decay + dBx
        y = jnp.einsum("bhn,bhpn->bhp", cs, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    fin, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), fin
