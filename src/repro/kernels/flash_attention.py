"""Blockwise causal/sliding-window GQA flash attention (TPU Pallas).

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); kv is the innermost,
    "arbitrary" (sequential) dimension — the online-softmax row state
    (m, l, acc) lives in VMEM scratch and is carried across kv blocks.
  * BlockSpecs tile q/k/v/o into VMEM with MXU-aligned (multiple-of-128)
    block shapes on the matmul dims; d_head is kept whole (<= 256).
  * GQA: the kv BlockSpec index_map folds the q-head -> kv-head mapping
    (h // group), so KV blocks are fetched once per kv head group without
    materializing repeated heads in HBM.
  * masking is two-level: scores are masked to a large-negative BEFORE the
    row max, and probabilities are explicitly zeroed, so fully-masked rows
    stay exactly zero (no NaN rescue needed); fully-masked kv blocks are
    skipped via pl.when on block-level bounds.

Two variants (mirroring ``decode_attention``'s generic/merged pair):
  * ``flash_attention_bhsd`` — generic: q is a separately-projected
    head-major (B, Hq, Sq, D) tensor, k/v arrive head-major too.
  * ``flash_attention_merged_bsd`` — the paper's merged (Q/P-removed)
    PREFILL fast path: there is NO q projection, the RoPE'd residual
    stream (B, Sq, d_model) *is* the query (d_model = Hq·D for merged
    configs, paper Fig 1b).  The kernel takes the stream reshaped
    (bitcast, no copy) to (B, Sq, Hq, D) and reads K*/V* tiles in their
    NATIVE (B, Sk, Hkv, D) layout — no head-major transpose of q/k/v/o
    bracketing the kernel — then writes the attention output straight
    back into the stream (FFN-input) basis.

Accumulation is float32 regardless of input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG = -1e30


def _flash_body(iq, ik, load_q, load_k, load_v, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int, bq: int, bk: int):
    """Shared online-softmax state update for one (bq, bk) block pair.

    ``load_q``/``load_k``/``load_v`` are thunks returning (bq, D)/(bk, D)
    tiles — the generic and merged kernels slice their differently-shaped
    VMEM refs there, and the loads stay INSIDE the fully-masked-block skip
    (pl.when below) either way.
    """
    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    rows_max = iq * bq + bq - 1
    cols_min = ik * bk
    cols_max = ik * bk + bk - 1
    rows_min = iq * bq

    run = True
    if causal:
        run = jnp.logical_and(run, cols_min <= rows_max)
    if window > 0:
        run = jnp.logical_and(run, rows_min - cols_max < window)

    @pl.when(run)
    def _body():
        q = load_q().astype(jnp.float32) * scale  # (bq, D)
        k = load_k().astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)

        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)  # (bq, 1)
        p = jnp.where(mask, jnp.exp(s - m_next), 0.0)  # (bq, bk)

        v = load_v().astype(jnp.float32)  # (bk, D)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        l_scr[:, :1] = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)


def _flash_finish(l_scr, acc_scr):
    denom = l_scr[:, :1]
    denom = jnp.where(denom == 0.0, 1.0, denom)
    return acc_scr[...] / denom


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    _flash_body(iq, ik, lambda: q_ref[0, 0], lambda: k_ref[0, 0],
                lambda: v_ref[0, 0], m_scr, l_scr, acc_scr,
                scale=scale, causal=causal, window=window, bq=bq, bk=bk)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = _flash_finish(l_scr, acc_scr).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=sliding_window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def _flash_kernel_merged(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                         scale: float, causal: bool, window: int,
                         bq: int, bk: int, nk: int):
    """Same online-softmax recurrence as ``_flash_kernel`` (shared
    ``_flash_body``); the refs are tiles of the NATIVE sequence-major
    layouts (q (1, bq, 1, D) from the stream-as-heads view, k/v
    (1, bk, 1, D) from the serving cache layout), so the only difference
    is the slicing."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    _flash_body(iq, ik, lambda: q_ref[0, :, 0], lambda: k_ref[0, :, 0],
                lambda: v_ref[0, :, 0], m_scr, l_scr, acc_scr,
                scale=scale, causal=causal, window=window, bq=bq, bk=bk)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, :, 0] = _flash_finish(l_scr, acc_scr).astype(o_ref.dtype)


def flash_attention_merged_bsd(
    u: jnp.ndarray,  # (B, Sq, Hq, D) — RoPE'd residual stream viewed as heads
    k: jnp.ndarray,  # (B, Sk, Hkv, D) — K*, NATIVE (sequence-major) layout
    v: jnp.ndarray,  # (B, Sk, Hkv, D) — V*, native layout
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged-weight (Q/P-removed) flash PREFILL: stream-as-query.

    Grid and softmax state as in ``flash_attention_bhsd``; the BlockSpecs
    differ so that q tiles come straight from the (B, Sq, Hq, D) bitcast
    of the residual stream and K*/V* tiles come from the serving cache's
    native (B, Sk, Hkv, D) layout — the head-major transposes of q, k, v
    AND o that bracket the generic kernel are simply not in the program.
    The output lands as (B, Sq, Hq, D), a bitcast of the (B, Sq, d_model)
    FFN-input stream the merged block consumes next.
    """
    B, Sq, Hq, D = u.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(_flash_kernel_merged, scale=scale,
                               causal=causal, window=sliding_window,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            # kv head h // G owns query head h of the stream view
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, G=G: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, G=G: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_merged",
    )(u, k, v)


def _flash_kernel_merged_q8(q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                            m_scr, l_scr, acc_scr, *, scale: float,
                            causal: bool, window: int, bq: int, bk: int,
                            nk: int, sg: int):
    """Merged flash kernel over int8 K*/V* tiles: each kv tile spans
    ``bk // sg`` whole serving pages (``sg`` = page size), and the tile's
    per-(page, head) scales ride in as (1, bk//sg, 1) float32 blocks of the
    (B, Sk//sg, Hkv) scale arrays.  The load thunks dequantize in VMEM —
    expand the page scales across their ``sg`` rows and multiply — so the
    shared ``_flash_body`` recurrence is unchanged and no full-precision
    K/V buffer exists outside the tile."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    def dq(ref, s_ref):
        s = s_ref[0, :, 0]  # (bk // sg,) — one scale per page in the tile
        s = jnp.broadcast_to(s[:, None], (bk // sg, sg)).reshape(bk, 1)
        return ref[0, :, 0].astype(jnp.float32) * s

    _flash_body(iq, ik, lambda: q_ref[0, :, 0], lambda: dq(k_ref, ks_ref),
                lambda: dq(v_ref, vs_ref), m_scr, l_scr, acc_scr,
                scale=scale, causal=causal, window=window, bq=bq, bk=bk)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, :, 0] = _flash_finish(l_scr, acc_scr).astype(o_ref.dtype)


def flash_attention_merged_q8_bsd(
    u: jnp.ndarray,  # (B, Sq, Hq, D) — RoPE'd residual stream viewed as heads
    k: jnp.ndarray,  # (B, Sk, Hkv, D) int8 — K* at pool quantization
    v: jnp.ndarray,  # (B, Sk, Hkv, D) int8 — V*
    k_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32 per-(page, head)
    v_scale: jnp.ndarray,  # (B, Sk // sg, Hkv) float32
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged-weight flash PREFILL over int8 K*/V* (the ``paged_q8`` pool's
    quantization applied to the in-flight sequence layout).

    Grid/BlockSpecs as in ``flash_attention_merged_bsd`` plus two scale
    operands tiled in lockstep with their kv tiles; the kv block size is
    rounded to a whole number of serving pages (``sg`` = Sk // n_scale
    blocks) so a tile never splits a page's scale.  Output dtype follows
    ``u`` (the stream), since the int8 inputs carry no float dtype.
    """
    B, Sq, Hq, D = u.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    nsb = k_scale.shape[1]
    assert Sk % nsb == 0, (Sk, nsb)
    sg = Sk // nsb  # serving page size — scale granularity along Sk
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    assert Sq % bq == 0, (Sq, bq)
    # kv tile = whole pages: largest page-count divisor of nsb <= target
    bg = max(1, min(block_k // sg, nsb))
    while nsb % bg:
        bg -= 1
    bk = bg * sg
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(_flash_kernel_merged_q8, scale=scale,
                               causal=causal, window=sliding_window,
                               bq=bq, bk=bk, nk=nk, sg=sg)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            # kv head h // G owns query head h of the stream view
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, G=G: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, G=G: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk // sg, 1), lambda b, h, i, j, G=G: (b, j, h // G)),
            pl.BlockSpec((1, bk // sg, 1), lambda b, h, i, j, G=G: (b, j, h // G)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_merged_q8",
    )(u, k, v, k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
