"""Single-token GQA decode attention vs a (ring-buffer) KV cache.

Flash-decoding adapted to TPU: grid = (batch, kv_heads, kv_blocks); the kv
block axis is sequential ("arbitrary") and carries the online-softmax state
for the G = Hq/Hkv query heads of this kv head in VMEM scratch.  Cache
validity/causality/sliding-window are evaluated from an explicit per-slot
position array (−1 = empty slot), which is what the serving layer's ring
buffer maintains — the kernel itself is layout-agnostic.

The (G, bk) score matmul is small on the M dimension by nature of decode;
the kernel keeps D and bk MXU-aligned which is where the FLOPs are.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)

    kpos = kpos_ref[0]  # (bk,) int32
    qpos = qpos_ref[0, 0]  # scalar int32
    ok = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        ok &= qpos - kpos < window
    mask = jnp.broadcast_to(ok[None, :], s.shape)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.where(mask, jnp.exp(s - m_next), 0.0)

    v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    l_scr[:, :1] = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_bhsd(
    q: jnp.ndarray,  # (B, Hkv, G, D) — grouped query heads
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    kv_positions: jnp.ndarray,  # (B, S) int32; -1 marks empty slots
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hkv, G, D = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               window=sliding_window, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(q, k, v, kv_positions, q_position)
