"""Single-token GQA decode attention vs a (ring-buffer) KV cache.

Flash-decoding adapted to TPU: grid = (batch, kv_heads, kv_blocks); the kv
block axis is sequential ("arbitrary") and carries the online-softmax state
for the G = Hq/Hkv query heads of this kv head in VMEM scratch.  Cache
validity/causality/sliding-window are evaluated from an explicit per-slot
position array (−1 = empty slot), which is what the serving layer's ring
buffer maintains — the kernel itself is layout-agnostic.

The (G, bk) score matmul is small on the M dimension by nature of decode;
the kernel keeps D and bk MXU-aligned which is where the FLOPs are.

Two variants:
  * ``decode_attention_bhsd`` — generic: q is a separately-projected
    (B, Hkv, G, D) tensor, the cache arrives transposed to head-major
    (B, Hkv, S, D).
  * ``decode_attention_merged_bsd`` — the paper's merged (Q/P-removed)
    serving fast path: there is NO q projection, the RoPE'd residual
    stream (B, d_model) *is* the query (d_model = Hq·D for merged
    configs, paper Fig 1b).  The kernel takes the stream reshaped
    (bitcast, no copy) to (B, Hq, D) and reads K*/V* in the serving
    cache's NATIVE (B, S, Hkv, D) layout — no per-step head-major
    transpose of the whole cache — then writes the attention output
    straight into the FFN-input basis (no P projection exists).

Paged variants (``decode_attention_paged_bhsd`` /
``decode_attention_paged_merged_bsd``): the cache is a POOL of physical
pages (n_blocks, block_size, Hkv, D) shared by all slots, and each slot
owns a per-request block table (B, MB) of physical page ids (-1 =
unmapped).  The sequential kv axis of the grid walks LOGICAL blocks; the
block table is a scalar-prefetch operand so the k/v BlockSpec index_maps
gather the mapped physical page (clamped to page 0 when unmapped — the
in-kernel mask zeroes those scores).  kv positions are not stored: with
absolute addressing logical block j covers positions [j·bs, (j+1)·bs);
with ring addressing (``ring_blocks`` > 0 — sliding-window tables bounded
at ceil(window/bs)+1 recycled slots, see ``kernels.paging``) slot j holds
the latest absolute block ≡ j (mod ring) not beyond the query's block, so
the kernel reconstructs positions from the grid index and the query
position.  Either way the online-softmax update is shared with the dense
variants unchanged.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG = -1e30


def _online_softmax_block(ik, q, k, v, kpos, qpos, m_scr, l_scr, acc_scr,
                          *, scale: float, window: int):
    """Shared flash-decoding state update for one (G, bk) kv block.

    ``q`` (G, D) and ``k``/``v`` (bk, D) are already sliced from the
    variant-specific block layout; the m/l/acc scratch carries the
    online-softmax state across the sequential kv-block axis.
    """
    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qf = q.astype(jnp.float32) * scale  # (G, D)
    s = jax.lax.dot_general(qf, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)

    ok = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        ok &= qpos - kpos < window
    mask = jnp.broadcast_to(ok[None, :], s.shape)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.where(mask, jnp.exp(s - m_next), 0.0)

    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    l_scr[:, :1] = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)


def _finish_output(l_scr, acc_scr):
    denom = l_scr[:, :1]
    denom = jnp.where(denom == 0.0, 1.0, denom)
    return acc_scr[...] / denom


def _decode_kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   nk: int):
    ik = pl.program_id(2)
    _online_softmax_block(ik, q_ref[0, 0], k_ref[0, 0], v_ref[0, 0],
                          kpos_ref[0], qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_bhsd(
    q: jnp.ndarray,  # (B, Hkv, G, D) — grouped query heads
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    kv_positions: jnp.ndarray,  # (B, S) int32; -1 marks empty slots
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hkv, G, D = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               window=sliding_window, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(q, k, v, kv_positions, q_position)


def _decode_kernel_merged(u_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
                          m_scr, l_scr, acc_scr, *, scale: float, window: int,
                          nk: int):
    ik = pl.program_id(2)
    # the stream block holds this kv head's G query heads contiguously;
    # k/v blocks are sliced from the NATIVE (B, S, Hkv, D) cache layout
    _online_softmax_block(ik, u_ref[0], k_ref[0, :, 0], v_ref[0, :, 0],
                          kpos_ref[0], qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_merged_bsd(
    u: jnp.ndarray,  # (B, Hq, D) — RoPE'd residual stream viewed as heads
    k: jnp.ndarray,  # (B, S, Hkv, D) — K* cache, NATIVE serving layout
    v: jnp.ndarray,  # (B, S, Hkv, D) — V* cache, native layout
    kv_positions: jnp.ndarray,  # (B, S) int32; -1 marks empty slots
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged-weight decode: stream-as-query, no q operand to project.

    Grid and softmax state as in ``decode_attention_bhsd``; the blocking
    differs so K*/V* stream from the cache without a head-major transpose
    (the transpose would rewrite the whole cache every decoded token) and
    the output lands as (B, Hq, D) — a bitcast of the (B, d_model)
    FFN-input stream the merged block consumes next.
    """
    B, Hq, D = u.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel_merged, scale=scale,
                               window=sliding_window, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            # kv head h owns query heads [h*G, (h+1)*G) of the stream
            pl.BlockSpec((1, G, D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_merged",
    )(u, k, v, kv_positions, q_position)


# ---------------------------------------------------------------------------
# paged variants: block-table gather over a physical page pool
# ---------------------------------------------------------------------------

def _paged_kpos(block_id, j, bs, qpos, ring):
    """Positions covered by table slot ``j`` (-1 everywhere if unmapped).

    Absolute addressing (``ring`` = 0): slot j IS logical block j.  Ring
    addressing: slot j holds the latest absolute block ≡ j (mod ring) the
    request has entered — reconstructed from the query's block ``lb``;
    never-entered slots (b < 0) are unmapped anyway but masked for safety.
    2D iota then rank-reduce: TPU vector units have no 1D iota."""
    off = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    if ring:
        lb = qpos // bs
        b = lb - ((lb + ring - j) % ring)
        return jnp.where((block_id >= 0) & (b >= 0), b * bs + off, -1)
    return jnp.where(block_id >= 0, j * bs + off, -1)


def _decode_kernel_paged(bt_ref, q_ref, k_ref, v_ref, qpos_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float, window: int,
                         bs: int, nb: int, ring: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    kpos = _paged_kpos(bt_ref[b, j], j, bs, qpos_ref[0, 0], ring)
    _online_softmax_block(j, q_ref[0, 0], k_ref[0, :, 0], v_ref[0, :, 0],
                          kpos, qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_paged_bhsd(
    q: jnp.ndarray,  # (B, Hkv, G, D) — grouped query heads
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — physical page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    block_tables: jnp.ndarray,  # (B, MB) int32 physical page ids; -1 unmapped
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Generic paged decode: like ``decode_attention_bhsd`` but the kv-block
    axis walks the slot's block table and gathers physical pages.  The pool
    keeps the serving cache's native (…, bs, Hkv, D) page layout — pages are
    written once at append time and never transposed.  ``ring_blocks`` > 0
    means the table is ring-addressed (windowed requests recycle pages; see
    ``kernels.paging``) and slot positions are reconstructed from the query
    position."""
    B, Hkv, G, D = q.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel_paged, scale=scale,
                               window=sliding_window, bs=bs, nb=MB,
                               ring=ring_blocks)

    def page(b, h, j, bt):  # physical page for logical block j of slot b
        return (jnp.maximum(bt[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, 1), lambda b, h, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_paged",
    )(block_tables.astype(jnp.int32), q, k_pool, v_pool, q_position)


def _decode_kernel_paged_merged(bt_ref, u_ref, k_ref, v_ref, qpos_ref, o_ref,
                                m_scr, l_scr, acc_scr, *, scale: float,
                                window: int, bs: int, nb: int, ring: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    kpos = _paged_kpos(bt_ref[b, j], j, bs, qpos_ref[0, 0], ring)
    _online_softmax_block(j, u_ref[0], k_ref[0, :, 0], v_ref[0, :, 0],
                          kpos, qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_paged_merged_bsd(
    u: jnp.ndarray,  # (B, Hq, D) — RoPE'd residual stream viewed as heads
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — K* page pool, native layout
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — V* page pool
    block_tables: jnp.ndarray,  # (B, MB) int32 physical page ids; -1 unmapped
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) paged decode: stream-as-query over a page pool.

    Combines the paper's serving fast path (no Q projection to read, output
    straight into the FFN-input basis) with vLLM-style paging — per token
    the only HBM traffic besides the stream is K*/V* weight reads and the
    slot's mapped pages.  ``ring_blocks`` as in
    ``decode_attention_paged_bhsd``."""
    B, Hq, D = u.shape
    NB, bs, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel_paged_merged, scale=scale,
                               window=sliding_window, bs=bs, nb=MB,
                               ring=ring_blocks)

    def page(b, h, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, MB),
        in_specs=[
            # kv head h owns query heads [h*G, (h+1)*G) of the stream
            pl.BlockSpec((1, G, D), lambda b, h, j, bt: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, 1), lambda b, h, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, h, j, bt: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), u.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_paged_merged",
    )(block_tables.astype(jnp.int32), u, k_pool, v_pool, q_position)


# ---------------------------------------------------------------------------
# quantized (paged_q8) variants: int8 page pools, in-kernel dequant
# ---------------------------------------------------------------------------
#
# The pools are the same physical pages quantized to int8 with one float32
# scale per (page, kv head) (see ``kernels.quant``).  The scale arrays ride
# along as EXTRA scalar-prefetch operands next to the block table — (NB,
# Hkv) is tiny, lives in SMEM, and the kernel looks up the gathered page's
# scale with the same ``max(bt[b, j], 0)`` clamp the BlockSpec gather uses
# (unmapped slots read page 0's scale; the position mask zeroes those
# scores regardless).  Dequant happens on the (bs, D) tile already in
# VMEM — `ints.astype(f32) * scale` — so no full-precision pool is ever
# materialized in HBM; everything downstream of the per-tile dequant is
# the fp kernels' shared online-softmax update, unchanged.

def _decode_kernel_paged_q8(bt_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
                            qpos_ref, o_ref, m_scr, l_scr, acc_scr, *,
                            scale: float, window: int, bs: int, nb: int,
                            ring: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    pg = jnp.maximum(bt_ref[b, j], 0)
    kd = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[pg, h]
    vd = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[pg, h]
    kpos = _paged_kpos(bt_ref[b, j], j, bs, qpos_ref[0, 0], ring)
    _online_softmax_block(j, q_ref[0, 0], kd, vd,
                          kpos, qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_paged_q8_bhsd(
    q: jnp.ndarray,  # (B, Hkv, G, D) — grouped query heads
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32 physical page ids; -1 unmapped
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Generic paged decode over an int8 pool: ``decode_attention_paged_bhsd``
    with the gathered page dequantized in VMEM from its scalar-prefetched
    (page, head) scale."""
    B, Hkv, G, D = q.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel_paged_q8, scale=scale,
                               window=sliding_window, bs=bs, nb=MB,
                               ring=ring_blocks)

    def page(b, h, j, bt, ks, vs):  # physical page for logical block j
        return (jnp.maximum(bt[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, ks, vs: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, 1), lambda b, h, j, bt, ks, vs: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, bt, ks, vs: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_paged_q8",
    )(block_tables.astype(jnp.int32), k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), q, k_pool, v_pool, q_position)


def _decode_kernel_paged_q8_merged(bt_ref, ks_ref, vs_ref, u_ref, k_ref,
                                   v_ref, qpos_ref, o_ref, m_scr, l_scr,
                                   acc_scr, *, scale: float, window: int,
                                   bs: int, nb: int, ring: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    pg = jnp.maximum(bt_ref[b, j], 0)
    kd = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[pg, h]
    vd = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[pg, h]
    kpos = _paged_kpos(bt_ref[b, j], j, bs, qpos_ref[0, 0], ring)
    _online_softmax_block(j, u_ref[0], kd, vd,
                          kpos, qpos_ref[0, 0], m_scr, l_scr, acc_scr,
                          scale=scale, window=window)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = _finish_output(l_scr, acc_scr).astype(o_ref.dtype)


def decode_attention_paged_q8_merged_bsd(
    u: jnp.ndarray,  # (B, Hq, D) — RoPE'd residual stream viewed as heads
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 K* page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 V* page pool
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    block_tables: jnp.ndarray,  # (B, MB) int32 physical page ids; -1 unmapped
    q_position: jnp.ndarray,  # (B, 1) int32
    *,
    sliding_window: int = 0,
    ring_blocks: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Merged (Q/P-removed) paged decode over an int8 pool: the paper's
    stream-as-query fast path with the page-pool HBM traffic quartered
    (int8 vs f32 pages; the per-page scales are noise).  Dequant as in
    ``decode_attention_paged_q8_bhsd``."""
    B, Hq, D = u.shape
    NB, bs, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    MB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel_paged_q8_merged, scale=scale,
                               window=sliding_window, bs=bs, nb=MB,
                               ring=ring_blocks)

    def page(b, h, j, bt, ks, vs):
        return (jnp.maximum(bt[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, MB),
        in_specs=[
            # kv head h owns query heads [h*G, (h+1)*G) of the stream
            pl.BlockSpec((1, G, D), lambda b, h, j, bt, ks, vs: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, bs, 1, D), page),
            pl.BlockSpec((1, 1), lambda b, h, j, bt, ks, vs: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D),
                               lambda b, h, j, bt, ks, vs: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), u.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_paged_q8_merged",
    )(block_tables.astype(jnp.int32), k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), u, k_pool, v_pool, q_position)
