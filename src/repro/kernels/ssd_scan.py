"""Mamba2 SSD chunked scan (TPU Pallas).

grid = (batch, heads, chunks); the chunk axis is sequential ("arbitrary")
and carries the per-(batch, head) SSM state (P, N) in VMEM scratch.  Within
a chunk the SSD "duality" turns the recurrence into two MXU matmuls:

  y_intra = (tril(exp(Acum_i − Acum_j)) ∘ (C Bᵀ) ∘ dt_j) X        (L,L)@(L,P)
  y_inter = (C Sᵀ) ∘ exp(Acum)                                    (L,N)@(N,P)
  S'      = exp(a_sum) S + Xᵀ (B ∘ dt ∘ exp(a_sum − Acum))        (P,L)@(L,N)

All decay exponents are ≤ 0 (dt > 0, A < 0), so the exps are stable.
Inputs are pre-activated: dt is post-softplus, a = dt·A.  The D-skip and
gating/norm live in the ops wrapper / mamba2 module.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, s_scr,
                *, L: int, nc: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0:1].astype(jnp.float32)  # (L, 1) — squeezed head dim
    a = a_ref[0, :, 0:1].astype(jnp.float32)  # (L, 1)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)

    A_cum = jnp.cumsum(a, axis=0)  # (L, 1)
    a_sum = A_cum[L - 1:L, :]  # (1, 1)
    decay_out = jnp.exp(A_cum)  # (L, 1)
    decay_end = jnp.exp(a_sum - A_cum)  # (L, 1)

    # intra-chunk
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    seg = A_cum - A_cum.reshape(1, L)  # (L, L): Acum_i − Acum_j
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    kern = jnp.where(rows >= cols, jnp.exp(seg), 0.0) * CB * dt.reshape(1, L)
    y = jax.lax.dot(kern, x, preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk (state entering this chunk)
    state = s_scr[...]  # (P, N)
    y += jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * decay_out

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    wB = Bm * decay_end * dt  # (L, N)
    s_new = state * jnp.exp(a_sum) + jax.lax.dot_general(
        x, wB, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(c_idx == nc - 1)
    def _finish():
        fin_ref[0, 0] = s_new.astype(fin_ref.dtype)


def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    a: jnp.ndarray,  # (B, S, H) = dt * A  (<= 0)
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
    *,
    chunk: int,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    L = chunk if S % chunk == 0 else S
    nc = S // L

    kernel = functools.partial(_ssd_kernel, L=L, nc=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_scan",
    )(x, dt, a, Bm, Cm)
    return y, fin
