"""TPU Pallas kernels for the framework's compute hot-spots.

Layout: <name>.py (pl.pallas_call + BlockSpec) / ops.py (jit wrappers) /
ref.py (pure-jnp oracles).  Validated under interpret=True on CPU; the
model layer selects them via ``impl="pallas"`` (TPU) or
``impl="pallas_interpret"`` (tests).
"""
from repro.kernels import ops, ref  # noqa: F401
