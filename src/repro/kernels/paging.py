"""Ring-of-pages addressing for windowed paged KV caches (pure math).

A sliding window of ``W`` tokens means a decode query at position ``L``
attends only positions ``> L - W``, so at most

    R = ceil(W / block_size) + 1

physical pages per request are ever live: the window spans at most
``ceil(W/bs)`` whole blocks plus the block currently being written.  A
windowed paged request therefore keeps a BOUNDED block table of exactly
``R`` slots, addressed as a ring — absolute block ``b`` lives at table
slot ``b % R`` — and the serving layer recycles the stale page in place
when the window rolls past it (``serving.paged_kv_cache``).

Because table slots no longer encode absolute order, readers reconstruct
each slot's absolute block from the query position:

    lb = q_position // block_size            # block being written
    b  = lb - ((lb + R - j) % R)             # latest block ≡ j (mod R)

which is exact for every live slot (the manager recycles eagerly on
entering each new block, so slot ``j`` always holds the most recent
absolute block congruent to ``j``); slots holding ``b < 0`` (never
entered) are unmapped (-1 in the table) and masked.  Offsets of the
current block that have not been overwritten yet reconstruct to positions
``> q_position`` and are hidden by the causal mask — the exact invariant
the dense ring buffer relies on.

Ring addressing is DERIVED, never flagged: a paged block table is a ring
iff its width equals ``R`` (``paged_ring_active``).  The manager sizes
windowed tables to exactly ``R`` slots; every wider table (windowed
configs whose window covers ``max_len``, manually built absolute tables
in tests) keeps absolute addressing.  The two schemes agree bit-for-bit
whenever no wrap has happened (``lb < R``), so the rule is safe even for
absolute tables that happen to be ``R`` wide.
"""
from __future__ import annotations


def paged_ring_blocks(sliding_window: int, block_size: int) -> int:
    """Ring size (table slots) bounding a windowed paged request:
    ``ceil(window / block_size) + 1`` — the window's blocks plus the block
    being written while the oldest is still partially in-window.  0 when
    there is no window (absolute addressing)."""
    if sliding_window <= 0:
        return 0
    return -(-sliding_window // block_size) + 1


def paged_ring_active(sliding_window: int, block_size: int,
                      n_table_blocks: int) -> int:
    """Ring size iff the given block table is ring-addressed (its width
    equals ``paged_ring_blocks``), else 0 (absolute addressing).  This is
    the single rule every layer derives ring mode from — manager, write
    path, XLA cores, Pallas wrappers, oracles — so a table can never be
    written in one scheme and read in the other."""
    r = paged_ring_blocks(sliding_window, block_size)
    return r if 0 < r == n_table_blocks else 0
