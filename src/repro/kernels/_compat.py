"""JAX-version compatibility shims shared by the kernel modules.

Keep every cross-version rename in this one file so the rule is updated
in exactly one place.
"""
from jax.experimental.pallas import tpu as pltpu

# renamed CompilerParams -> TPUCompilerParams and back across JAX releases
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
