"""Mistral-7B — the paper's §3 GQA example (serial block, SwiGLU).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, sliding window 4096.
Used by benchmarks/bench_weight_table.py to reproduce the paper's table.
"""
from repro.configs.base import ModelConfig, register


@register("mistral-7b")
def mistral_7b() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b",
        family="dense",
        source="[paper §3; arXiv:2310.06825]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        sliding_window=4096,
        ffn_type="swiglu",
    )
