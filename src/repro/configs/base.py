"""Model configuration system.

Every architecture in the framework is described by a single frozen
``ModelConfig``. Configs are registered by id (``--arch <id>``) in
``REGISTRY`` via the ``@register`` decorator; each assigned architecture
lives in its own module ``repro.configs.<id>`` and is imported eagerly by
``repro.configs.__init__`` so the registry is always fully populated.

The paper's technique (QP removal for skipless transformers) is selected
per-config via ``block_style``:

  standard         residual + RMSNorm blocks (the public-literature form)
  skipless         no residuals / no norms, full Q,K,V,P present (Fig 1a)
  skipless_merged  no residuals / no norms, Q and P removed (Fig 1b) —
                   mathematically identical to ``skipless`` under the
                   core.merge transform
  residual_qpfree  paper Fig 4: Q/P-free blocks *with* norms and skips
                   (a trainable architecture, not an exact rewrite)

``parallel_block`` selects the GPT-J-style attention-parallel-to-FFN layout
(paper Fig 3); the serial layout is paper Fig 1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

BLOCK_STYLES = ("standard", "skipless", "skipless_merged", "residual_qpfree")
FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # one of FAMILIES
    source: str = ""  # provenance note "[hf:...; tier]"

    # trunk
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0  # 0 => attention-free (ssm)
    n_kv_heads: int = 0
    d_head: int = 0  # defaults to d_model // n_heads
    d_ff: int = 0  # 0 => no FFN (mamba2)
    vocab_size: int = 0

    # attention
    rope_style: str = "half"  # "half" | "chatglm2d" | "none"
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # fraction of d_head that is rotated
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 = full attention
    causal: bool = True  # False => encoder-only (no decode shapes)

    # ffn
    ffn_type: str = "swiglu"  # "swiglu" | "geglu" | "gelu_mlp"

    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    router_jitter: float = 0.0
    capacity_factor: float = 1.25

    # ssm / hybrid (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # vlm cross-attention
    cross_attn_every: int = 0  # every Nth layer is cross-attn (0 = none)
    n_vision_tokens: int = 0

    # paper technique
    block_style: str = "standard"
    merged_variant: str = "qp"  # which pair is removed: "qp" | "kp" | "vp" (Table 1)
    parallel_block: bool = False  # attention parallel to FFN (paper Fig 3)

    # lowering/analysis knobs (loop tiling; analysis mode unrolls these)
    query_chunk: int = 1024  # attention query-block tiling (0 = unchunked)
    moe_group: int = 2048  # MoE dispatch group size (0 = single group)
    moe_impl: str = "scatter"  # "scatter" (linear dispatch) | "einsum" (GShard ref)
    init_style: str = "auto"  # "auto": orthogonal for skipless styles, else normal
    ffn_out_gain: float = 1.0  # skipless signal-prop compensation on w_down/w_out

    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = False

    # positional fallback for rope_style == "none" (encoder)
    conv_pos_width: int = 0  # hubert-style depthwise conv positional embed

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.block_style not in BLOCK_STYLES:
            raise ValueError(f"unknown block_style {self.block_style!r}")
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads and not self.n_kv_heads:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ---- derived quantities used across the framework -------------------

    @property
    def padded_vocab(self) -> int:
        """Physical embedding rows: vocab padded to a multiple of 128 so the
        vocab dim shards evenly over any TP degree up to 128 (production
        practice; logits for padded ids are masked in loss/sampling).
        The LOGICAL ``vocab_size`` is unchanged (paper tables use it)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def attn_dim(self) -> int:
        """Output dim of the Q projection / attention concat (n_heads*d_head)."""
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        """Paper's ``e``: output dim of K and V (n_kv_heads * d_head)."""
        return self.n_kv_heads * self.d_head

    @property
    def is_glu(self) -> bool:
        return self.ffn_type in ("swiglu", "geglu")

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ffn(self) -> bool:
        return self.d_ff > 0

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def qp_removal_applicable(self) -> bool:
        """Paper Fig 1(b)/3(a): needs attention with a square (d x d) Q.

        True for every attention-bearing arch (MHA/MQA/GQA alike); False for
        attention-free SSMs.  KP/VP variants additionally need kv_dim == d.
        """
        return self.has_attention and self.attn_dim == self.d_model

    @property
    def kp_vp_removal_applicable(self) -> bool:
        """Paper Fig 1(c)/(d): MHA only (e == d)."""
        return self.has_attention and self.kv_dim == self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate_style(self) -> None:
        if self.block_style in ("skipless_merged", "residual_qpfree") and (
            not self.qp_removal_applicable
        ):
            raise ValueError(
                f"{self.name}: block_style={self.block_style} requires a "
                "square Q projection (attention-bearing arch)"
            )
        if self.merged_variant not in ("qp", "kp", "vp"):
            raise ValueError(f"unknown merged_variant {self.merged_variant!r}")
        if (self.block_style == "skipless_merged"
                and self.merged_variant in ("kp", "vp")
                and not self.kp_vp_removal_applicable):
            raise ValueError(
                f"{self.name}: merged_variant={self.merged_variant} requires "
                "MHA (e == d, paper Fig 1c/d); use 'qp' for MQA/GQA"
            )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        if arch_id in REGISTRY:
            raise ValueError(f"duplicate arch id {arch_id}")
        REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str, **overrides) -> ModelConfig:
    # accept both dashes and underscores
    key = arch_id.replace("_", "-")
    aliases = {k.replace("_", "-"): k for k in REGISTRY}
    if key not in aliases:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(aliases)}")
    cfg = REGISTRY[aliases[key]]()
    if overrides:
        cfg = cfg.with_(**overrides)
    cfg.validate_style()
    return cfg


def list_archs() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


# ---------------------------------------------------------------------------
# reduced ("smoke") configs — same family & code paths, tiny sizes.
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to CPU-smoke size while preserving its family,
    attention grouping ratio, FFN type, block style and special layers."""
    kv_ratio = max(cfg.n_kv_heads, 1) / max(cfg.n_heads, 1)
    n_heads = 4 if cfg.n_heads else 0
    n_kv = max(1, int(round(n_heads * kv_ratio))) if n_heads else 0
    if n_kv and n_heads % n_kv:
        n_kv = 2 if n_heads % 2 == 0 else 1
    small = dict(
        n_layers=4 if cfg.cross_attn_every else 2,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16 if n_heads else 0,
        d_ff=96 if cfg.has_ffn else 0,
        vocab_size=128,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        ssm_state=8 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8 if cfg.ssm_state else 256,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        n_vision_tokens=8 if cfg.cross_attn_every else 0,
        conv_pos_width=min(cfg.conv_pos_width, 5) if cfg.conv_pos_width else 0,
        dtype="float32",
        param_dtype="float32",
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return cfg.with_(**small)
