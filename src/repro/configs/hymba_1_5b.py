"""Hymba-1.5B — hybrid: parallel attention + mamba(SSM) heads in each layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676; hf]

Each block runs attention heads and SSD (mamba2-style) heads in parallel on
the same input and mean-fuses their outputs (simplified from the paper's
learned per-head fusion).  Attention uses a sliding window so the KV cache is
bounded -> ``long_500k`` decode is sub-quadratic and applicable.
"""
from repro.configs.base import ModelConfig, register


@register("hymba-1.5b")
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        source="[arXiv:2411.13676; hf]",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=1024,
        ffn_type="swiglu",
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
    )
