"""Mamba2-2.7B — attention-free SSD (state-space duality) stack.

64L d_model=2560 (attn-free) d_ff=0 vocab=50280 ssm_state=128
[arXiv:2405.21060; unverified]

The paper's QP-removal technique is INAPPLICABLE here (no Q/K/V/P exist);
built without it per the assignment, see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register


@register("mamba2-2.7b")
def mamba2_2_7b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        source="[arXiv:2405.21060; unverified]",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        rope_style="none",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_n_groups=1,
        ssm_conv_width=4,
        ssm_chunk=256,
        tie_embeddings=True,
    )
