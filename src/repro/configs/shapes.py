"""Assigned input-shape sets and per-arch applicability.

Each LM-family cell is (seq_len, global_batch).  ``train_4k`` lowers
``train_step``; ``prefill_32k`` lowers the serving prefill; ``decode_32k`` and
``long_500k`` lower ``serve_step`` (one new token against a KV/state cache of
``seq_len``), NOT train_step.

Skip rules (recorded in DESIGN.md §4):
  * encoder-only archs (hubert) have no decode step -> skip decode shapes;
  * ``long_500k`` needs sub-quadratic attention -> runs only for ssm/hybrid
    archs (mamba2, hymba); pure full-attention archs skip it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Returns (applicable, reason_if_not)."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only arch: no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or (
            cfg.sliding_window > 0 and cfg.has_attention
        )
        if not sub_quadratic:
            return False, (
                "pure full-attention arch: 512k decode needs sub-quadratic "
                "attention / bounded state (see DESIGN.md §4)"
            )
    return True, ""


def applicable_shapes(cfg: ModelConfig):
    for s in SHAPES.values():
        ok, _ = shape_applicable(cfg, s)
        if ok:
            yield s
