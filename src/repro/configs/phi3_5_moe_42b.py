"""Phi-3.5-MoE-42B (6.6B active) — GQA attention + 16-expert top-2 MoE FFN.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 (per expert) vocab=32064
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.configs.base import ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi3_5_moe_42b() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        ffn_type="swiglu",
        n_experts=16,
        experts_per_token=2,
    )
