"""Moonshot-v1-16B-A3B (Moonlight) — 64-expert top-6 MoE, MHA attention.

48L d_model=2048 16H (kv=16 => MHA) d_ff=1408 (per expert) vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]

n_kv_heads == n_heads => e == d, so the paper's KP/VP removal variants
(Fig 1c/d) are additionally legal for this arch, not just QP removal.
"""
from repro.configs.base import ModelConfig, register


@register("moonshot-v1-16b-a3b")
def moonshot_v1_16b() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        ffn_type="swiglu",
        n_experts=64,
        experts_per_token=6,
    )
