"""Config registry. Importing this package registers all architectures."""
from repro.configs.base import (
    ModelConfig,
    REGISTRY,
    get_config,
    list_archs,
    reduce_config,
    register,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable_shapes, shape_applicable

# eagerly import every arch module so REGISTRY is complete
from repro.configs import (  # noqa: F401
    qwen2_5_32b,
    phi3_medium_14b,
    chatglm3_6b,
    llama3_2_1b,
    llama3_2_vision_11b,
    hymba_1_5b,
    mamba2_2_7b,
    phi3_5_moe_42b,
    moonshot_v1_16b,
    hubert_xlarge,
    pythia_6_9b,
    mistral_7b,
)

ASSIGNED_ARCHS = (
    "qwen2.5-32b",
    "phi3-medium-14b",
    "chatglm3-6b",
    "llama3.2-1b",
    "llama3.2-vision-11b",
    "hymba-1.5b",
    "mamba2-2.7b",
    "phi3.5-moe-42b-a6.6b",
    "moonshot-v1-16b-a3b",
    "hubert-xlarge",
)

__all__ = [
    "ModelConfig",
    "REGISTRY",
    "get_config",
    "list_archs",
    "reduce_config",
    "register",
    "SHAPES",
    "ShapeSpec",
    "applicable_shapes",
    "shape_applicable",
    "ASSIGNED_ARCHS",
]
