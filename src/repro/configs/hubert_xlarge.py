"""HuBERT-XLarge — encoder-only audio transformer (wav2vec2 arch).

48L d_model=1280 16H (kv=16 => MHA) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

Encoder-only: ``causal=False`` -> decode shapes are skipped.  The audio
frontend (conv feature extractor) is a STUB; ``input_specs()`` supplies
precomputed frame embeddings.  Positional information comes from a
depthwise-conv positional embedding (wav2vec2-style), not RoPE.  MHA (e==d)
means all three paper removal variants (QP/KP/VP) are legal.
"""
from repro.configs.base import ModelConfig, register


@register("hubert-xlarge")
def hubert_xlarge() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        source="[arXiv:2106.07447; unverified]",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        rope_style="none",
        conv_pos_width=128,
        ffn_type="gelu_mlp",
    )
