"""Pythia-6.9B — the paper's §3 MHA example (parallel attention/FFN).

32L d_model=4096 32H MHA d_ff=16384 vocab=50400, parallel block, GeLU MLP.
Used by benchmarks/bench_weight_table.py to reproduce the paper's table.
"""
from repro.configs.base import ModelConfig, register


@register("pythia-6.9b")
def pythia_6_9b() -> ModelConfig:
    return ModelConfig(
        name="pythia-6.9b",
        family="dense",
        source="[paper §3; EleutherAI/pythia-6.9b]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=16384,
        vocab_size=50400,
        ffn_type="gelu_mlp",
        parallel_block=True,
    )
