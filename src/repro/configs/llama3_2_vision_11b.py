"""Llama-3.2-Vision-11B — VLM: llama3 trunk with cross-attention image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Every 5th layer is a cross-attention layer attending to vision tokens.  Per
the assignment the modality frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings of shape (batch, n_vision_tokens, d_model).
"""
from repro.configs.base import ModelConfig, register


@register("llama3.2-vision-11b")
def llama3_2_vision_11b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-vision-11b",
        family="vlm",
        source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        ffn_type="swiglu",
        cross_attn_every=5,
        n_vision_tokens=1600,
    )
