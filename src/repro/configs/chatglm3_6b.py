"""ChatGLM3-6B — dense GQA (kv=2) decoder with 2D (partial/interleaved) RoPE.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
[arXiv:2406.12793; hf]

ChatGLM applies rotary embedding to only half of each head dimension in the
interleaved-pair layout ("chatglm2d"); the other half is passed through.
"""
from repro.configs.base import ModelConfig, register


@register("chatglm3-6b")
def chatglm3_6b() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        source="[arXiv:2406.12793; hf]",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rope_style="chatglm2d",
        rope_fraction=0.5,
        qkv_bias=True,  # chatglm uses add_qkv_bias
        ffn_type="swiglu",
    )
