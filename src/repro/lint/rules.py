"""Lint rule framework: targets, findings, and the rule registry.

Mirrors the ``models.backends`` registry design: rules register under a
name (latest wins, so a downstream repo can swap a tuned rule in without
forking), lookups of unknown rules fail loudly with the registered list,
and the sweep driver (``repro.lint.sweep``) enumerates every registered
rule against every registered backend combo — a new rule or a new backend
is linted with zero new test code.

A rule checks ONE invariant of a :class:`LintTarget` — a lowered serving
program plus the metadata needed to judge it (its registry key, the cache
spec it serves, the unmerged source program for merged targets, the
declared donations for jit-boundary checks).  ``applies(target)`` scopes
the rule (e.g. ``NoOversizedBuffer`` only judges paged prefill);
``check(target)`` returns :class:`Finding`s, empty when clean.

Registering a custom rule::

    from repro.lint import LintRule, register_rule

    class NoGiantConstant(LintRule):
        name = "NoGiantConstant"
        description = "no >1MiB constant baked into a serving program"

        def applies(self, t):
            return True

        def check(self, t):
            big = [a for a in walker.iter_avals(t.jaxpr)
                   if getattr(a, "size", 0) > 1 << 18]
            return [self.finding(t, f"{len(big)} oversized consts")] \\
                if big else []

    register_rule(NoGiantConstant())
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: which rule, on which program, what went wrong.

    ``severity`` is "error" (the CLI exits non-zero) or "warning"
    (reported, not gating).  ``detail`` carries structured context for the
    JSON report (offending shapes, counts, primitive names, …)."""
    rule: str
    target: str
    message: str
    severity: str = "error"
    detail: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "target": self.target,
                "message": self.message, "severity": self.severity,
                "detail": self.detail or {}}

    def __str__(self) -> str:
        return f"[{self.severity}] {self.target}: {self.rule}: {self.message}"


@dataclasses.dataclass
class LintTarget:
    """One serving program under analysis.

    ``phase`` is "decode" or "prefill"; (``cache_kind``, ``style``,
    ``impl``) is the backend-registry key the program was built from.
    ``jaxpr`` is the traced program; merged-style targets also carry
    ``source_jaxpr`` — the SAME phase/cache/impl program of the unmerged
    source model, the baseline ``NoForbiddenMatmul`` diffs against.
    ``lowered`` (when the impl lowers on this backend) is the jitted
    program lowered WITH its production donation declaration;
    ``donated_flat`` are the flat argument positions declared donated.
    ``max_len`` / ``cache_shapes`` / ``cache_dtype`` describe the cache
    the program serves, for buffer-shape rules.  ``instrumented_jaxpr``
    is the SAME program re-traced with the repro.obs observer ACTIVE
    (``obs.activated(...)``) — ``NoHostTransferInObsHooks`` diffs it
    against ``jaxpr`` to prove instrumentation stages nothing into the
    compiled program."""
    phase: str
    cache_kind: str
    style: str
    impl: str
    jaxpr: Any
    cfg: Any = None
    source_jaxpr: Any = None
    lowered: Any = None
    donated_flat: Tuple[int, ...] = ()
    max_len: Optional[int] = None
    cache_shapes: Tuple[Tuple[int, ...], ...] = ()
    cache_dtype: Any = None
    instrumented_jaxpr: Any = None

    @property
    def key(self) -> str:
        return f"{self.phase}:{self.cache_kind}/{self.style}/{self.impl}"


class LintRule:
    """Base class: subclass, set ``name``/``description``, implement
    ``applies`` and ``check``."""

    name: str = "?"
    description: str = "?"

    def applies(self, target: LintTarget) -> bool:
        raise NotImplementedError

    def check(self, target: LintTarget) -> List[Finding]:
        raise NotImplementedError

    def finding(self, target: LintTarget, message: str, *,
                severity: str = "error",
                detail: Optional[Dict[str, Any]] = None) -> Finding:
        return Finding(rule=self.name, target=target.key, message=message,
                       severity=severity, detail=detail)


_RULES: Dict[str, LintRule] = {}


def register_rule(rule: LintRule) -> None:
    """Register ``rule`` under ``rule.name`` (latest wins, exactly like
    the backend registries — swap, don't fork)."""
    _RULES[rule.name] = rule


def get_rule(name: str) -> LintRule:
    try:
        return _RULES[name]
    except KeyError:
        raise KeyError(
            f"no lint rule registered under {name!r}; registered rules: "
            f"{registered_rules()}") from None


def registered_rules() -> List[str]:
    return sorted(_RULES)


def all_rules() -> List[LintRule]:
    return [_RULES[n] for n in registered_rules()]


def run_rules(target: LintTarget,
              rules: Optional[List[LintRule]] = None
              ) -> Tuple[List[str], List[Finding]]:
    """Run every applicable rule on ``target``.  Returns (names of rules
    that ran, findings)."""
    ran: List[str] = []
    findings: List[Finding] = []
    for rule in (all_rules() if rules is None else rules):
        if not rule.applies(target):
            continue
        ran.append(rule.name)
        findings.extend(rule.check(target))
    return ran, findings
