"""repro.lint: static analysis over the repo's lowered jax programs and
host serving state.

The paper's claim is structural — merged programs must CONTAIN no Q/P
matmul — and the serving stack's worst shipped bugs (zero-copy numpy
aliasing, worst-case buffer regressions, silently-dropped donation) are
all properties of the program or the host/device boundary, checkable
before a single token is decoded.  This package checks them:

  walker      shared jaxpr IR traversal (scan/cond/pjit/pallas bodies)
  rules       Finding / LintTarget / LintRule + the rule registry
  builtin     the built-in rules (NoForbiddenMatmul, NoOversizedBuffer,
              DonationEffective, NoDtypePromotionDrift,
              NoHostTransferInStepLoop, NoDequantizedPoolBuffer)
  sweep       sweep() — lint EVERY registered (cache_kind, style, impl)
              decode/prefill/chunk backend combo, zero per-combo code
  aliasing    audit_engine() — the host-aliasing race detector
  submitpath  audit_submit_path() — NoSyncPrefillInSubmit: the scheduled
              engine's submit must enqueue only (with positive control)
  retention   audit_retention() — NoWriteIntoHeldPage: no write path may
              mutate a page a peer or the prefix tree still holds
  report      human/JSON rendering (tools/jaxlint.py is the CLI)
"""
from repro.lint import aliasing, report, retention, submitpath, walker  # noqa: F401,E501
from repro.lint.builtin import (BUILTIN_RULES, DonationEffective,  # noqa: F401
                                NoDequantizedPoolBuffer,
                                NoDtypePromotionDrift, NoForbiddenMatmul,
                                NoHostTransferInObsHooks,
                                NoHostTransferInStepLoop, NoOversizedBuffer)
from repro.lint.rules import (Finding, LintRule, LintTarget,  # noqa: F401
                              all_rules, get_rule, register_rule,
                              registered_rules, run_rules)
from repro.lint.sweep import (SweepReport, TargetReport,  # noqa: F401
                              register_sweep_builders, sweep, sweep_models)
from repro.lint.aliasing import audit_engine  # noqa: F401
from repro.lint.retention import audit_retention  # noqa: F401
from repro.lint.submitpath import audit_submit_path  # noqa: F401
