"""Built-in lint rules: the paper's structural claims plus the bug
classes this repo has actually shipped, each as a checkable invariant.

  NoForbiddenMatmul       merged (qp) programs compile with EXACTLY two
                          fewer ``dot_general``s than their unmerged
                          source — the Q and P projections are gone from
                          the program, not just from the param tree
                          ("KV-weights are all you need", the paper's
                          whole claim, per registered combo)
  NoOversizedBuffer       paged prefill materializes NO max_len-sized
                          intermediate (the PR 3 direct-to-page win,
                          protected against regression)
  DonationEffective       declared donations really alias an output in
                          the lowered module — an aval mismatch silently
                          downgrades donation to a full pool copy per
                          step, the kind of perf regression nothing
                          functional ever catches
  NoDtypePromotionDrift   no cache-sized buffer appears at a float dtype
                          wider than the cache dtype — an accidental
                          fp32 shadow of a bf16 pool doubles the HBM the
                          paged pool exists to save (kernels' explicit
                          f32 TILE accumulators are by design and pass)
  NoHostTransferInStepLoop  the decode step program contains no host
                          callback / infeed primitive — one host
                          round-trip in the per-token loop serializes
                          every stream in the batch
  NoHostTransferInObsHooks  re-tracing a serving program with the
                          repro.obs observer ACTIVE adds zero
                          host-transfer/callback primitives — the obs
                          subsystem's zero-overhead-on-device guarantee
                          (hooks are host-side; nothing may stage a
                          callback into the compiled program)
  NoDequantizedPoolBuffer paged_q8 programs never materialize a
                          pool-shaped buffer wider than int8 — dequant
                          happens per attention TILE (inside the kernel
                          loop) or per gathered table row, never as a
                          full-precision shadow of the int8 pool, which
                          would spend the exact HBM the quantized pool
                          exists to save
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.lint import walker
from repro.lint.rules import Finding, LintRule, LintTarget, register_rule


class NoForbiddenMatmul(LintRule):
    """Merged programs must drop exactly the wq and wp matmuls.

    The qp-merged rewrite of a model differs from its unmerged source by
    the Q and P projections per (scanned) layer body and nothing else, so
    the merged program must count exactly TWO fewer ``dot_general``
    equations than the same-phase/cache/impl program of the source model.
    Counting the delta (not absolute counts) keeps the rule valid as
    layers gain matmuls; requiring equality (not <=) catches a "merged"
    route that silently re-projects Q somewhere else."""

    name = "NoForbiddenMatmul"
    description = ("merged program has exactly two fewer dot_generals "
                   "than its unmerged source")

    def applies(self, t: LintTarget) -> bool:
        return t.style == "merged" and t.source_jaxpr is not None

    def check(self, t: LintTarget) -> List[Finding]:
        n_src = walker.count_primitive(t.source_jaxpr, "dot_general")
        n_merged = walker.count_primitive(t.jaxpr, "dot_general")
        if n_merged != n_src - 2:
            return [self.finding(
                t, f"merged program has {n_merged} dot_generals, unmerged "
                   f"source has {n_src}; expected exactly {n_src - 2} "
                   f"(wq and wp eliminated, nothing else)",
                detail={"merged": n_merged, "source": n_src})]
        return []


class NoOversizedBuffer(LintRule):
    """Paged prefill must not materialize a max_len-sized buffer.

    Direct-to-page prefill's point is that the program's sequence extents
    are bounded by the prompt bucket, never by the serving max_len; one
    max_len-sized intermediate resurrects the worst-case allocation the
    paged pool exists to delete.  The sweep picks a ``max_len`` that
    collides with no model/pool dimension, so any hit is real."""

    name = "NoOversizedBuffer"
    description = "no max_len-sized intermediate in paged prefill"

    def applies(self, t: LintTarget) -> bool:
        return t.phase == "prefill" \
            and t.cache_kind in ("paged", "paged_q8") \
            and t.max_len is not None

    def check(self, t: LintTarget) -> List[Finding]:
        offending = walker.avals_with_dim(t.jaxpr, t.max_len)
        if offending:
            shapes = sorted({tuple(a.shape) for a in offending})
            return [self.finding(
                t, f"{len(offending)} max_len({t.max_len})-sized buffers "
                   f"in the program, e.g. {shapes[:3]}",
                detail={"max_len": t.max_len,
                        "shapes": [list(s) for s in shapes[:10]]})]
        return []


class DonationEffective(LintRule):
    """Declared donations must be USED in the lowered module.

    ``donate_argnums`` is a request, not a guarantee: when no output
    matches a donated input's aval, jax silently drops the donation and
    the step copies the whole pool every token.  Effective donation shows
    up as a ``tf.aliasing_output`` attribute on the argument in the
    lowered StableHLO — this rule demands it for every donated leaf."""

    name = "DonationEffective"
    description = "every donated arg aliases an output in the lowered module"

    def applies(self, t: LintTarget) -> bool:
        return t.lowered is not None and bool(t.donated_flat)

    def check(self, t: LintTarget) -> List[Finding]:
        attrs = walker.stablehlo_arg_attrs(t.lowered)
        dead = [i for i in t.donated_flat
                if i >= len(attrs) or attrs[i] is None
                or "tf.aliasing_output" not in attrs[i]]
        if dead:
            return [self.finding(
                t, f"{len(dead)}/{len(t.donated_flat)} donated args are NOT "
                   f"aliased to an output (flat positions {dead[:8]}) — the "
                   f"donation silently became a copy",
                detail={"dead_flat_positions": dead,
                        "declared": list(t.donated_flat)})]
        return []


def _wider_float(a, than) -> bool:
    try:
        return (jnp.issubdtype(a, jnp.floating)
                and jnp.issubdtype(than, jnp.floating)
                and jnp.finfo(a).bits > jnp.finfo(than).bits)
    except TypeError:
        return False


class NoDtypePromotionDrift(LintRule):
    """No cache-sized buffer at a float dtype wider than the cache dtype.

    The kernels deliberately accumulate f32 over TILES (explicit
    ``preferred_element_type`` / scratch refs) — that is not drift.  Drift
    is a whole cache/pool-shaped array appearing at fp32 when the cache is
    bf16: a silent 2x of exactly the HBM the merged layout and the paged
    pool are engineered to save.  The rule scans every aval (kernel bodies
    included) for cache-leaf shapes at a wider float dtype.  Only live at
    sub-fp32 cache dtypes, which is why the sweep traces at bfloat16."""

    name = "NoDtypePromotionDrift"
    description = "no cache-shaped buffer wider than the cache dtype"

    def applies(self, t: LintTarget) -> bool:
        return bool(t.cache_shapes) and t.cache_dtype is not None

    def check(self, t: LintTarget) -> List[Finding]:
        shapes = {tuple(s) for s in t.cache_shapes}
        hits = [a for a in walker.iter_avals(t.jaxpr)
                if hasattr(a, "shape") and hasattr(a, "dtype")
                and tuple(a.shape) in shapes
                and _wider_float(a.dtype, t.cache_dtype)]
        if hits:
            seen = sorted({(tuple(a.shape), str(a.dtype)) for a in hits})
            return [self.finding(
                t, f"{len(hits)} cache-shaped buffers wider than the "
                   f"{jnp.dtype(t.cache_dtype).name} cache, e.g. {seen[:3]}",
                detail={"cache_dtype": jnp.dtype(t.cache_dtype).name,
                        "hits": [[list(s), d] for s, d in seen[:10]]})]
        return []


#: primitives whose presence in a decode step means a host round-trip
#: (or an effect pinned to the host) inside the per-token loop
HOST_TRANSFER_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "callback", "infeed", "outfeed", "host_local_array_to_global_array",
})


class NoHostTransferInStepLoop(LintRule):
    """The decode step program must be host-silent.

    Every serving stream in the batch shares one jitted step; a callback
    or infeed primitive anywhere in it (including a kernel body or a
    debug print left behind) forces a device->host->device round-trip per
    decoded token, serializing the whole batch on host latency."""

    name = "NoHostTransferInStepLoop"
    description = "no callback/infeed primitive in the decode step program"

    def applies(self, t: LintTarget) -> bool:
        return t.phase == "decode"

    def check(self, t: LintTarget) -> List[Finding]:
        bad = sorted({eqn.primitive.name for eqn in walker.iter_eqns(t.jaxpr)
                      if eqn.primitive.name in HOST_TRANSFER_PRIMITIVES})
        if bad:
            return [self.finding(
                t, f"host-transfer primitives in the step program: {bad}",
                detail={"primitives": bad})]
        return []


def _host_transfer_counts(jaxpr) -> dict:
    counts: dict = {}
    for eqn in walker.iter_eqns(jaxpr):
        n = eqn.primitive.name
        if n in HOST_TRANSFER_PRIMITIVES:
            counts[n] = counts.get(n, 0) + 1
    return counts


class NoHostTransferInObsHooks(LintRule):
    """Instrumentation must never reach into the compiled program.

    The obs subsystem's discipline is host-side-only hooks at the
    engine's python seams; the temptation it guards against is a kernel
    or forward path consulting ``repro.obs.get_active()`` and staging a
    ``debug_print``/callback when observability is on — which would turn
    "obs on" into a per-token host round-trip.  The sweep re-traces every
    serving program with an ACTIVE observer (``obs.activated(...)``) into
    ``instrumented_jaxpr``; this rule diffs host-transfer primitive
    counts against the uninstrumented trace and demands ZERO new ones.
    (Count-diff, not absence: a program legitimately carrying such a
    primitive is ``NoHostTransferInStepLoop``'s business, not ours.)"""

    name = "NoHostTransferInObsHooks"
    description = ("active-observer re-trace adds zero host-transfer "
                   "primitives to the serving program")

    def applies(self, t: LintTarget) -> bool:
        return t.instrumented_jaxpr is not None

    def check(self, t: LintTarget) -> List[Finding]:
        base = _host_transfer_counts(t.jaxpr)
        instr = _host_transfer_counts(t.instrumented_jaxpr)
        new = {n: c - base.get(n, 0) for n, c in instr.items()
               if c > base.get(n, 0)}
        if new:
            return [self.finding(
                t, f"instrumented program stages host-transfer primitives "
                   f"the plain program does not: {new} — obs hooks must "
                   f"stay host-side",
                detail={"new": new, "base": base, "instrumented": instr})]
        return []


class NoDequantizedPoolBuffer(LintRule):
    """paged_q8 programs must never hold a full-precision pool shadow.

    The int8 pool's whole point is 2x (vs bf16) / 4x (vs fp32) HBM on
    exactly the largest buffers in a serve; the tempting bug is a
    convenience ``pool.astype(f32)`` somewhere in a forward path, which
    silently materializes the very buffer the format deleted.  Dequant
    is only legal at TILE granularity (inside the kernel grid loop) or
    on table-GATHERED rows (bounded by the request's live pages, not the
    pool) — so no aval in a paged_q8 program may have a pool shape
    (layer-stacked OR per-layer sliced, both are in ``cache_shapes``) at
    any dtype wider than one byte.  Itemsize — not float-ness — is the
    test: an int32 shadow would be just as fatal."""

    name = "NoDequantizedPoolBuffer"
    description = ("no pool-shaped buffer wider than int8 in a paged_q8 "
                   "program")

    def applies(self, t: LintTarget) -> bool:
        return t.cache_kind == "paged_q8" and bool(t.cache_shapes)

    def check(self, t: LintTarget) -> List[Finding]:
        shapes = {tuple(s) for s in t.cache_shapes}
        hits = [a for a in walker.iter_avals(t.jaxpr)
                if hasattr(a, "shape") and hasattr(a, "dtype")
                and tuple(a.shape) in shapes
                and jnp.dtype(a.dtype).itemsize > 1]
        if hits:
            seen = sorted({(tuple(a.shape), str(a.dtype)) for a in hits})
            return [self.finding(
                t, f"{len(hits)} pool-shaped buffers wider than int8 in a "
                   f"paged_q8 program, e.g. {seen[:3]} — a dequantized "
                   f"shadow of the quantized pool",
                detail={"hits": [[list(s), d] for s, d in seen[:10]]})]
        return []


BUILTIN_RULES = (NoForbiddenMatmul(), NoOversizedBuffer(),
                 DonationEffective(), NoDtypePromotionDrift(),
                 NoHostTransferInStepLoop(), NoHostTransferInObsHooks(),
                 NoDequantizedPoolBuffer())

for _rule in BUILTIN_RULES:
    register_rule(_rule)
