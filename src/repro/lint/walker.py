"""Shared jaxpr IR walker: ONE recursion over nested jax programs.

Every structural assertion this repo makes about its lowered programs —
"the merged program has no Q matmul", "paged prefill allocates no
max_len-sized buffer", "the step loop hides no host callback" — needs the
same traversal: walk a (closed) jaxpr's equations and recurse into every
inner jaxpr a higher-order primitive carries in its params (``scan``
bodies, ``cond`` branches, ``pjit``/``custom_vjp`` calls, ``pallas_call``
kernel bodies, …).  PR 3 and PR 4 each hand-wrote that recursion inside a
test; this module is the single copy the rule framework (and those tests)
walk with.

The traversal treats ANY ``jax.core.Jaxpr``/``ClosedJaxpr`` leaf found in
an equation's params as an inner program — it doesn't enumerate primitive
names, so new higher-order primitives are covered automatically.
"""
from __future__ import annotations

from typing import Any, Iterator, List, Optional, Tuple

import jax
from jax import core as jcore


def as_jaxpr(program) -> jcore.Jaxpr:
    """Accept a ``Jaxpr``, a ``ClosedJaxpr``, or anything carrying a
    ``.jaxpr`` attribute (``jax.make_jaxpr`` output) and return the bare
    ``Jaxpr``."""
    if isinstance(program, jcore.Jaxpr):
        return program
    inner = getattr(program, "jaxpr", None)
    if inner is not None:
        return as_jaxpr(inner)
    raise TypeError(f"not a jaxpr-like program: {type(program)!r}")


def _inner_jaxprs(eqn: jcore.JaxprEqn) -> Iterator[jcore.Jaxpr]:
    """Inner programs carried by one equation's params (scan/cond/pjit/
    pallas_call/…), each as a bare ``Jaxpr``."""
    for p in eqn.params.values():
        for sub in jax.tree.leaves(
                p, is_leaf=lambda x: isinstance(
                    x, (jcore.Jaxpr, jcore.ClosedJaxpr))):
            if isinstance(sub, jcore.ClosedJaxpr):
                yield sub.jaxpr
            elif isinstance(sub, jcore.Jaxpr):
                yield sub


def iter_jaxprs(program) -> Iterator[jcore.Jaxpr]:
    """The program and every (transitively) inner jaxpr, outer-first."""
    stack = [as_jaxpr(program)]
    while stack:
        jx = stack.pop()
        yield jx
        for eqn in jx.eqns:
            stack.extend(_inner_jaxprs(eqn))


def iter_eqns(program) -> Iterator[jcore.JaxprEqn]:
    """Every equation of the program, recursing into inner jaxprs."""
    for jx in iter_jaxprs(program):
        yield from jx.eqns


def iter_avals(program) -> Iterator[Any]:
    """Every abstract value the program touches: in/out/const vars of each
    (inner) jaxpr plus each equation's operand and result avals — the
    stream ``NoOversizedBuffer``-style rules scan for forbidden shapes."""
    for jx in iter_jaxprs(program):
        for v in (*jx.invars, *jx.outvars, *jx.constvars):
            if hasattr(v, "aval"):
                yield v.aval
        for eqn in jx.eqns:
            for v in (*eqn.invars, *eqn.outvars):
                if hasattr(v, "aval"):
                    yield v.aval


def count_primitive(program, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in the program."""
    return sum(1 for eqn in iter_eqns(program) if eqn.primitive.name == name)


def primitive_names(program) -> List[str]:
    """Sorted distinct primitive names anywhere in the program."""
    return sorted({eqn.primitive.name for eqn in iter_eqns(program)})


def kernel_jaxprs(program) -> List[jcore.Jaxpr]:
    """The ``pallas_call`` kernel-body jaxprs of the program (possibly
    nested inside scans etc.) — the scope of kernel-local rules."""
    out: List[jcore.Jaxpr] = []
    for eqn in iter_eqns(program):
        if eqn.primitive.name == "pallas_call":
            for sub in _inner_jaxprs(eqn):
                out.append(sub)
    return out


def avals_with_dim(program, size: int) -> List[Any]:
    """Avals with ``size`` as one of their dimensions — e.g. every
    max_len-sized buffer a paged prefill was supposed to have deleted."""
    return [a for a in iter_avals(program)
            if hasattr(a, "shape") and size in tuple(a.shape)]


def donated_flat_indices(example_args: Tuple, donate_argnums) -> List[int]:
    """Map top-level ``donate_argnums`` of a jitted call to FLAT argument
    positions (one per pytree leaf) — the numbering StableHLO's
    ``%argN`` uses, which ``DonationEffective`` matches aliasing
    attributes against."""
    donate = set(donate_argnums)
    flat: List[int] = []
    pos = 0
    for i, arg in enumerate(example_args):
        n = len(jax.tree.leaves(arg))
        if i in donate:
            flat.extend(range(pos, pos + n))
        pos += n
    return flat


def stablehlo_arg_attrs(lowered) -> List[Optional[str]]:
    """Per-argument attribute blobs of the lowered module's public
    ``main`` — index k holds the ``{...}`` attribute text of ``%argk``
    (None when the argument carries no attributes).  This is where jax
    records effective buffer donation (``tf.aliasing_output``)."""
    import re
    txt = lowered.as_text() if hasattr(lowered, "as_text") else str(lowered)
    m = re.search(r"func\.func public @main\((.*?)\)\s*->", txt, re.S)
    if m is None:  # fall back: some versions print non-public main
        m = re.search(r"func\.func @main\((.*?)\)\s*->", txt, re.S)
    if m is None:
        raise ValueError("could not find @main signature in lowered module")
    sig = m.group(1)
    attrs: List[Optional[str]] = []
    for am in re.finditer(r"%arg(\d+):\s*[^,{]*(\{[^}]*\})?", sig):
        idx = int(am.group(1))
        while len(attrs) <= idx:
            attrs.append(None)
        attrs[idx] = am.group(2)
    return attrs
