"""NoSyncPrefillInSubmit: the admission-stall class as a check.

The pre-scheduler ``Engine.submit`` ran a whole-prompt, batch-of-1
prefill SYNCHRONOUSLY at admission — every arrival froze all in-flight
decode streams for a bucket-compiled prefill (the stall
``repro.serving.sched`` exists to remove).  This audit makes the class
un-shippable, the way ``repro.lint.aliasing`` did for zero-copy races:

``audit_submit_path()`` builds reduced-shape ``ScheduledEngine``s (dense
and paged), wraps every device-dispatching callable the adapter owns
(prefill program, chunk program, decode step) with a call-counting spy,
drives ``submit`` for fresh prompts, and asserts ZERO dispatches — the
scheduled submit path must only enqueue.  A POSITIVE CONTROL then runs
the same spy over the synchronous ``Engine.submit``, which MUST fire the
prefill program: if it doesn't, the spy is not observing the seam and
the audit fails itself rather than passing vacuously.

Each hit is a :class:`repro.lint.rules.Finding` (rule
``NoSyncPrefillInSubmit``), the same currency as the jaxpr rules, so
``tools/jaxlint.py --submit`` reports it in the one sweep.
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

import numpy as np

from repro.lint.rules import Finding

RULE_SUBMIT = "NoSyncPrefillInSubmit"

# every adapter attribute that, when called, dispatches a device program
_DISPATCH_ATTRS = ("_prefill", "_chunk")


@contextlib.contextmanager
def _counting_spies(engine, counts: Dict[str, int]):
    """Wrap the engine's device-dispatching callables with counters.
    ``counts`` maps seam name -> calls observed while armed."""
    holders = []  # (obj, attr, original)

    def arm(obj, attr, name):
        if not hasattr(obj, attr):
            return
        orig = getattr(obj, attr)
        counts.setdefault(name, 0)

        def wrapped(*args, __name=name, __orig=orig, **kwargs):
            counts[__name] += 1
            return __orig(*args, **kwargs)

        holders.append((obj, attr, orig))
        setattr(obj, attr, wrapped)

    for attr in _DISPATCH_ATTRS:
        arm(engine.kv, attr, f"kv.{attr}")
    arm(engine, "_decode", "engine._decode")
    try:
        yield
    finally:
        for obj, attr, orig in holders:
            setattr(obj, attr, orig)


def _prompts(engine, n: int = 3) -> List[np.ndarray]:
    vocab = engine.cfg.vocab_size
    return [(np.arange(8, dtype=np.int32) * (i + 3)) % vocab
            for i in range(n)]


def audit_submit(engine, context: str) -> List[Finding]:
    """Drive ``engine.submit`` with the spies armed; any device dispatch
    on the submit path is a finding.  The engine is expected to be a
    ScheduledEngine (or anything whose submit only enqueues)."""
    from repro.serving.engine import Request  # local: lint imports stay light

    counts: Dict[str, int] = {}
    with _counting_spies(engine, counts):
        for p in _prompts(engine):
            engine.submit(Request(prompt=p, max_new_tokens=4))
    findings = []
    for name, n in sorted(counts.items()):
        if n:
            findings.append(Finding(
                rule=RULE_SUBMIT, target=context,
                message=f"submit dispatched {name} {n}x — admission must "
                        f"only enqueue; a synchronous prefill at submit "
                        f"freezes every in-flight decode stream for a "
                        f"whole-prompt program (the stall class "
                        f"repro.serving.sched removes)",
                detail={"seam": name, "calls": n}))
    return findings


def positive_control(engine, context: str) -> List[Finding]:
    """The synchronous ``Engine.submit`` MUST fire its prefill program
    under the same spies — otherwise the audit observes nothing and a
    clean report would be vacuous."""
    from repro.serving.engine import Request

    counts: Dict[str, int] = {}
    with _counting_spies(engine, counts):
        engine.submit(Request(prompt=_prompts(engine, 1)[0],
                              max_new_tokens=4))
    if not counts.get("kv._prefill"):
        return [Finding(
            rule=RULE_SUBMIT, target=context,
            message="positive control FAILED: the synchronous engine's "
                    "submit fired no prefill through the spied seam — the "
                    "audit is not observing dispatches and cannot certify "
                    "the scheduled path",
            detail={"counts": dict(counts)})]
    return []


def audit_submit_path(cfg=None, params=None) -> List[Finding]:
    """Build reduced dense + paged ScheduledEngines and one synchronous
    Engine; returns every confirmed finding (empty == clean)."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import init_params
    from repro.serving import Engine, ServeConfig
    from repro.serving.sched import SchedConfig, ScheduledEngine

    if cfg is None:
        cfg = reduce_config(get_config("llama3.2-1b"))
    if params is None:
        params = init_params(jax.random.PRNGKey(0), cfg)
    findings: List[Finding] = []
    scfg = SchedConfig(token_budget=32, chunk_tokens=16)
    for kind in ("dense", "paged"):
        eng = ScheduledEngine(cfg, params,
                              ServeConfig(n_slots=2, max_len=48),
                              scfg=scfg, cache=kind)
        findings += audit_submit(eng, f"ScheduledEngine[{kind}].submit")
    sync = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                  cache="dense")
    findings += positive_control(sync, "Engine[dense].submit")
    return findings
