"""NoWriteIntoHeldPage: the shared-page-mutation class as a check.

A paged pool page with refcount > 1 is held by someone besides the
writer — a prefix-sharing peer, or (since the radix prefix cache) the
TREE itself, retaining a released request's prefix for future hits.
Writing such a page in place corrupts another request's live KV (the
PR 5-era detach-on-shared bug class) or silently rewrites bytes the
prefix cache will later serve as a "hit".  The manager's rule is: every
write path detaches first (``_cow``), eviction only ever reclaims pages
whose ONLY holder is the tree, and a retained page is never recycled in
place.  This audit makes the class un-shippable, the way
``repro.lint.aliasing`` did for zero-copy races:

``audit_manager(pm)`` arms spies on the manager's write-authorization
seams and drives a scripted lifecycle — prefix-sharing admits, decode
appends across block boundaries, release-time adoption, warm re-admits,
pool-pressure eviction, and (windowed) ring rollovers:

  1. **append seam** — after ``ensure_appendable`` / ``ensure_chunk``
     authorizes a write, the target page must have refcount exactly 1
     (the writing slot) and must not be tree-retained;
  2. **CoW seam** — ``_copy_block_device`` must copy into a page no one
     else holds (ref 1, unknown to the tree) and never onto its source;
  3. **eviction seam** — every page ``tree.evict`` returns must be
     tree-only (ref 1) and mapped by NO live slot;
  4. **retention ledger** — after every op, each retained page holds a
     reference and is absent from the free list.

``audit_retention()`` runs the audit over reduced fp (absolute +
sliding-window) and q8 managers, then runs a POSITIVE CONTROL: a
sabotaged manager whose ``ensure_appendable`` skips detach-on-shared
MUST fire the append seam — if it doesn't, the audit is not observing
the seam and fails itself rather than passing vacuously.

Each hit is a :class:`repro.lint.rules.Finding`, the same currency as
the jaxpr rules, so ``tools/jaxlint.py --retention`` reports it in the
one sweep.
"""
from __future__ import annotations

import contextlib
from typing import List

import numpy as np

from repro.lint.rules import Finding

RULE_RETENTION = "NoWriteIntoHeldPage"


def _check_write_target(pm, slot: int, findings: List[Finding],
                        context: str, seam: str) -> None:
    """The page a just-authorized write will land in must be exclusively
    the writer's: ref == 1 and not tree-retained."""
    info = pm._slots[slot]
    li = int(pm.lengths[slot]) // pm.bs
    bid = info.blocks[li % pm.ring] if pm.ring else info.blocks[li]
    if bid < 0:
        return
    ref = int(pm.allocator.ref[bid])
    if ref != 1:
        findings.append(Finding(
            rule=RULE_RETENTION, target=context,
            message=f"{seam} authorized a write into page {bid} with "
                    f"refcount {ref} — a prefix-sharing peer or the "
                    f"retention tree still holds its bytes; the write "
                    f"path must detach (CoW) first",
            detail={"seam": seam, "page": bid, "ref": ref}))
    if bid in pm.tree.retained:
        findings.append(Finding(
            rule=RULE_RETENTION, target=context,
            message=f"{seam} authorized a write into TREE-RETAINED page "
                    f"{bid} — the prefix cache would later serve the "
                    f"overwritten bytes as a hit",
            detail={"seam": seam, "page": bid}))


@contextlib.contextmanager
def _armed(pm, findings: List[Finding], context: str):
    """Arm the write-authorization / CoW / eviction spies on ``pm``."""
    orig_append = pm.ensure_appendable
    orig_chunk = pm.ensure_chunk
    orig_copy = pm._copy_block_device
    orig_evict = pm.tree.evict

    def spy_append(slot):
        ok = orig_append(slot)
        if ok:
            _check_write_target(pm, slot, findings, context,
                                "ensure_appendable")
        return ok

    def spy_chunk(slot, start, end):
        ok = orig_chunk(slot, start, end)
        if ok:
            info = pm._slots[slot]
            for b in range(start // pm.bs, -(-end // pm.bs)):
                bid = (info.blocks[b % pm.ring]
                       if pm.ring and info.abs_blocks[b % pm.ring] == b
                       else (info.blocks[b] if not pm.ring
                             and b < len(info.blocks) else -1))
                if bid < 0 or (not pm.ring and b < info.first_owned):
                    continue  # shared / unmapped: the scatter drops it
                ref = int(pm.allocator.ref[bid])
                if ref != 1 or bid in pm.tree.retained:
                    findings.append(Finding(
                        rule=RULE_RETENTION, target=context,
                        message=f"ensure_chunk authorized a write into "
                                f"held page {bid} (ref {ref}, retained="
                                f"{bid in pm.tree.retained})",
                        detail={"seam": "ensure_chunk", "page": bid,
                                "ref": ref}))
        return ok

    def spy_copy(src, dst):
        ref = int(pm.allocator.ref[dst])
        if src == dst or ref != 1 or pm.tree.references(dst):
            findings.append(Finding(
                rule=RULE_RETENTION, target=context,
                message=f"CoW copies into page {dst} (src {src}, ref "
                        f"{ref}, in-tree={pm.tree.references(dst)}) — "
                        f"the destination must be a fresh page nobody "
                        f"else holds",
                detail={"seam": "_copy_block_device", "src": src,
                        "dst": dst, "ref": ref}))
        return orig_copy(src, dst)

    def spy_evict(need, evictable):
        mapped = {p for info in pm._slots.values()
                  for p in info.blocks if p >= 0}
        out = orig_evict(need, evictable)
        for bid in out:
            ref = int(pm.allocator.ref[bid])
            if ref != 1 or bid in mapped:
                findings.append(Finding(
                    rule=RULE_RETENTION, target=context,
                    message=f"eviction reclaimed page {bid} that is not "
                            f"tree-only (ref {ref}, live-mapped="
                            f"{bid in mapped}) — evicting under a live "
                            f"sharer frees KV a request still reads",
                    detail={"seam": "tree.evict", "page": bid,
                            "ref": ref}))
        return out

    pm.ensure_appendable = spy_append
    pm.ensure_chunk = spy_chunk
    pm._copy_block_device = spy_copy
    pm.tree.evict = spy_evict
    try:
        yield
    finally:
        pm.ensure_appendable = orig_append
        pm.ensure_chunk = orig_chunk
        pm._copy_block_device = orig_copy
        pm.tree.evict = orig_evict


def _check_ledger(pm, findings: List[Finding], context: str) -> None:
    """Every retained page holds a reference and is not on the free
    list — the adoption bookkeeping the append/evict seams rely on."""
    free = set(pm.allocator._free)
    for bid in pm.tree.retained:
        ref = int(pm.allocator.ref[bid])
        if ref < 1 or bid in free:
            findings.append(Finding(
                rule=RULE_RETENTION, target=context,
                message=f"retained page {bid} has ref {ref} and "
                        f"free={bid in free} — the tree's reference was "
                        f"lost; its next reuse double-books the page",
                detail={"seam": "ledger", "page": bid, "ref": ref}))


def _drive(pm, findings: List[Finding], context: str) -> None:
    """The scripted lifecycle: every policy path the rule governs."""
    vocab = pm.cfg.vocab_size
    # windowed: keep the prompt inside the window so it registers (a
    # longer prompt's block 0 is dead at admit and shares nothing)
    n_tok = pm.bs + 4 if pm.ring else 3 * pm.bs + 3
    prompt = (np.arange(n_tok, dtype=np.int32) * 3 + 1) % vocab

    def step(slot):
        if pm.ensure_appendable(slot):
            pm.advance(slot)
        _check_ledger(pm, findings, context)

    with _armed(pm, findings, context):
        assert pm.admit(0, prompt) is not None
        for _ in range(2):          # owner decodes into its tail first
            step(0)
        assert pm.admit(1, prompt.copy()) is not None  # prefix sharer
        # both decode across a block boundary: tail CoW for the sharer,
        # (windowed) ring rollovers past the shared pages for both
        for _ in range(2 * pm.bs):
            step(0)
            step(1)
        pm.release(1)
        pm.release(0)               # last sharer out: tree adopts
        _check_ledger(pm, findings, context)
        if pm.admit(2, prompt.copy()) is not None:  # warm hit on retained
            for _ in range(2):
                step(2)
            pm.release(2)
        # pool pressure: a distinct prompt too big for the free list
        # alone — _alloc must evict retained pages, never live ones
        big = (np.arange(7 * pm.bs, dtype=np.int32) * 7 + 2) % vocab
        if pm.admit(3, big) is not None:
            step(3)
            pm.release(3)
        _check_ledger(pm, findings, context)
        pm.drop_prefix_cache()
        _check_ledger(pm, findings, context)


def audit_manager(pm, context: str) -> List[Finding]:
    """Drive ``pm`` through the scripted lifecycle with the spies armed;
    returns every confirmed finding (empty == clean)."""
    findings: List[Finding] = []
    _drive(pm, findings, context)
    return findings


def _positive_control(cfg, context: str) -> List[Finding]:
    """A manager with detach-on-shared removed MUST fire the append
    seam; a silent pass means the audit observes nothing."""
    from repro.serving.paged_kv_cache import PagedCacheManager

    class _UncheckedWriteManager(PagedCacheManager):
        # the sabotage: append in place even when the page is held
        def ensure_appendable(self, slot):
            info = self._slots[slot]
            li = int(self.lengths[slot]) // self.bs
            if self.ring or li >= len(info.blocks):
                return super().ensure_appendable(slot)
            return True

    pm = _UncheckedWriteManager(cfg, n_slots=4, max_len=64,
                                block_size=8, n_blocks=24)
    fired = audit_manager(pm, context)
    if not fired:
        return [Finding(
            rule=RULE_RETENTION, target=context,
            message="positive control FAILED: a manager stripped of "
                    "detach-on-shared produced no finding — the audit "
                    "is not observing the write seams and cannot "
                    "certify the real managers",
            detail={})]
    return []


def audit_retention(cfg=None) -> List[Finding]:
    """Audit reduced fp (absolute + sliding-window) and q8 managers,
    plus the positive control; returns every confirmed finding."""
    from repro.configs import get_config, reduce_config
    from repro.serving.paged_kv_cache import (PagedCacheManager,
                                              PagedQ8CacheManager)

    if cfg is None:
        cfg = reduce_config(get_config("llama3.2-1b"))
    wcfg = cfg.with_(sliding_window=16)
    findings: List[Finding] = []
    # n_blocks=10 < the workload's footprint, so _drive's pressure admit
    # really evicts; 24 gives the windowed/q8 variants headroom
    for pm, name in (
            (PagedCacheManager(cfg, n_slots=4, max_len=64,
                               block_size=8, n_blocks=10),
             "PagedCacheManager[absolute]"),
            (PagedCacheManager(wcfg, n_slots=4, max_len=64,
                               block_size=8, n_blocks=10),
             "PagedCacheManager[ring]"),
            (PagedQ8CacheManager(cfg, n_slots=4, max_len=64,
                                 block_size=8, n_blocks=10),
             "PagedQ8CacheManager[absolute]")):
        findings += audit_manager(pm, name)
    findings += _positive_control(cfg, "UncheckedWriteManager[control]")
    return findings
