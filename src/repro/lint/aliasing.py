"""Host-aliasing race detector: the PR 5 bug class as a check.

jax's CPU backend ZERO-COPIES an aligned, dtype-matching numpy array into
a device array (``np.shares_memory(np.asarray(jnp.asarray(x)), x)`` is
True), and dispatch is async — so a numpy buffer that an ``Engine`` /
``PagedCacheManager`` keeps mutating (block tables, lengths, last-token
row) can be read by an in-flight step AFTER the host has already moved on
to the next step's state.  PR 5 shipped exactly that bug (a step decoding
against the *next* step's block table); PR 5's fix was a ``.copy()`` and
a postmortem.  This module is the check that makes the class un-shippable:

``audit_engine(engine)`` drives a real serve loop and applies three
deterministic sub-checks — no timing, no sleeps:

  1. **jit-boundary spy** — wraps the engine's decode and prefill jitted
     callables and, at every call, tests each array argument for shared
     memory with every buffer the serving stack declares host-mutable
     (``host_mutable_buffers()`` hooks on ``Engine`` / adapters /
     ``PagedCacheManager``) and with the caller-owned prompt buffers.
  2. **ingestion seam** — the engine funnels every host→device transfer
     through ``Engine.host_to_device``; the audit verifies that seam
     actually copies (an alias here races the caller's own buffer
     against the async prefill that reads it).
  3. **host-held device views** — after exercising preemption, every
     numpy buffer the engine handed back to a request (``key_state``)
     must OWN its memory; a read-only ``np.asarray`` view of a device
     array pins a live device buffer into host state (and breaks
     callers that mutate it).

Each hit is a :class:`repro.lint.rules.Finding`, same currency as the
jaxpr rules, so ``tools/jaxlint.py`` reports both in one sweep.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.lint.rules import Finding

RULE_JIT_INPUT = "NoAliasedJitInput"
RULE_INGEST = "HostToDeviceCopies"
RULE_HOST_VIEW = "NoHostViewOfDeviceBuffer"


def _np_view(x) -> Optional[np.ndarray]:
    """A numpy view of ``x`` WITHOUT copying, or None.

    For a CPU jax array, ``np.asarray`` is a zero-copy export whenever
    one is possible — exactly the window through which aliasing with host
    state is observable.  (When jax must copy, the result trivially
    shares nothing and the check is a clean no-op.)"""
    if isinstance(x, np.ndarray):
        return x
    if isinstance(x, jax.Array):
        try:
            return np.asarray(x)
        except Exception:  # non-exportable layout/sharding: nothing shared
            return None
    return None


def check_shared(args: Any, named_buffers: Dict[str, np.ndarray],
                 context: str) -> List[Finding]:
    """Flag every array leaf of ``args`` sharing memory with any named
    host-mutable buffer."""
    findings: List[Finding] = []
    leaves = jax.tree_util.tree_flatten_with_path(args)[0]
    for path, leaf in leaves:
        view = _np_view(leaf)
        if view is None or view.size == 0:
            continue
        for name, buf in named_buffers.items():
            if buf is None or not isinstance(buf, np.ndarray):
                continue
            if np.shares_memory(view, buf):
                findings.append(Finding(
                    rule=RULE_JIT_INPUT, target=context,
                    message=f"jit input arg{jax.tree_util.keystr(path)} "
                            f"shares memory with host-mutable buffer "
                            f"{name!r} — an async step can read state the "
                            f"host has already advanced (the PR 5 race)",
                    detail={"arg": jax.tree_util.keystr(path),
                            "buffer": name}))
    return findings


def check_ingestion(host_to_device: Callable, context: str) -> List[Finding]:
    """Verify the host→device seam copies: its output must not share
    memory with its input.  The probe is 64-byte ALIGNED (jax's CPU
    zero-copy import requirement, cf. ``serving.hostbufs``) so a
    non-copying seam aliases it deterministically, not per malloc's
    mood."""
    from repro.serving import hostbufs
    probe = hostbufs.aligned_empty((64,), np.int32)
    probe[:] = np.arange(64)
    out = _np_view(host_to_device(probe, np.int32))
    if out is not None and np.shares_memory(out, probe):
        return [Finding(
            rule=RULE_INGEST, target=context,
            message="host_to_device zero-copies its input: the async "
                    "prefill/step reads the CALLER's buffer after submit "
                    "returns — callers reusing their prompt buffer corrupt "
                    "an in-flight program")]
    return []


def check_host_views(named: Dict[str, Any], context: str) -> List[Finding]:
    """Flag host-side numpy state that does not own its memory — e.g. a
    ``np.asarray`` view of a device array (read-only, memoryview-backed)
    stashed into a request's ``key_state``."""
    findings: List[Finding] = []
    for name, arr in named.items():
        if not isinstance(arr, np.ndarray):
            continue
        owned = arr.base is None and arr.flags.writeable
        if not owned:
            why = ("read-only" if not arr.flags.writeable else
                   f"view of {type(arr.base).__name__}")
            findings.append(Finding(
                rule=RULE_HOST_VIEW, target=context,
                message=f"{name} is a {why} numpy buffer — host state "
                        f"holding a view of (and pinning) a device buffer "
                        f"instead of owning a copy",
                detail={"buffer": name, "why": why}))
    return findings


@contextlib.contextmanager
def _spy(obj, attr: str, buffers_fn: Callable[[], Dict[str, np.ndarray]],
         findings: List[Finding], context: str):
    """Temporarily wrap callable ``obj.attr`` with a shared-memory check
    on every call's arguments."""
    orig = getattr(obj, attr)

    def wrapped(*args, **kwargs):
        findings.extend(check_shared((args, kwargs), buffers_fn(), context))
        return orig(*args, **kwargs)

    setattr(obj, attr, wrapped)
    try:
        yield
    finally:
        setattr(obj, attr, orig)


def _default_prompts(engine, n: int = 3) -> List[np.ndarray]:
    # bucket-exact int32 ALIGNED prompts: the exact shape/dtype/alignment
    # for which numpy padding is a no-op and jax zero-copy ingestion is
    # certain — the worst case, made deterministic (serving.hostbufs)
    from repro.serving import hostbufs
    vocab = engine.cfg.vocab_size
    prompts = []
    for i in range(n):
        p = hostbufs.aligned_empty((8,), np.int32)
        p[:] = (np.arange(8) * (i + 3)) % vocab
        prompts.append(p)
    return prompts


def audit_engine(engine, prompts: Optional[Sequence[np.ndarray]] = None,
                 max_new_tokens: int = 4,
                 exercise_preempt: bool = True) -> List[Finding]:
    """Serve a few requests through ``engine`` with the aliasing spies
    armed; returns every confirmed finding (empty == clean).

    Drives the REAL path — ``submit`` then ``step`` to completion, plus a
    forced preemption — so the buffers checked are the buffers production
    passes, not synthetic ones."""
    from repro.serving.engine import Request  # local: lint imports stay light

    findings: List[Finding] = []
    if prompts is None:
        prompts = _default_prompts(engine)
    prompt_bufs = {f"prompt[{i}]": np.asarray(p)
                   for i, p in enumerate(prompts)}

    def buffers() -> Dict[str, np.ndarray]:
        named = dict(engine.host_mutable_buffers())
        named.update(prompt_bufs)
        return named

    findings.extend(check_ingestion(engine.host_to_device,
                                    "engine.host_to_device"))

    reqs = [Request(prompt=p, max_new_tokens=max_new_tokens)
            for p in prompts]
    with _spy(engine, "_decode", buffers, findings, "engine._decode"), \
         _spy(engine.kv, "_prefill", buffers, findings,
              "engine.kv._prefill"):
        pending = list(reqs)
        pending = [r for r in pending if not engine.submit(r)]
        for _ in range(max_new_tokens + 2):
            if not engine.active:
                break
            engine.step()
            pending = [r for r in pending if not engine.submit(r)]
        if exercise_preempt and engine.active:
            slot = next(iter(engine.active))
            engine._preempt(slot)
        # drain: preempted requests re-prefill through the spied path too
        while engine.active or engine.preempted:
            for r in list(engine.preempted):
                if engine.submit(r):
                    engine.preempted.remove(r)
            if engine.active:
                engine.step()

    key_states = {f"request[{r.rid}].key_state": r.key_state
                  for r in reqs if r.key_state is not None}
    findings.extend(check_host_views(key_states, "engine._preempt"))
    return findings
