"""Render lint results: human report to a stream, structured dict for
JSON — one format for the sweep, the aliasing audit, or both combined
(what ``tools/jaxlint.py`` emits)."""
from __future__ import annotations

import sys
from typing import Any, Dict, List, Optional

from repro.lint.rules import Finding, all_rules
from repro.lint.sweep import SweepReport


def render_sweep(report: SweepReport, out=None, verbose: bool = False
                 ) -> None:
    out = out or sys.stdout
    n_rules = sum(len(t.rules_run) for t in report.targets)
    print(f"repro.lint sweep: {report.n_decode_targets} decode + "
          f"{report.n_prefill_targets} prefill + "
          f"{report.n_chunk_targets} chunk backends "
          f"(registry: {report.n_decode_backends} + "
          f"{report.n_prefill_backends} + {report.n_chunk_backends}), "
          f"{n_rules} rule runs",
          file=out)
    for t in report.targets:
        mark = "FAIL" if any(f.severity == "error" for f in t.findings) \
            else "ok"
        if verbose or mark != "ok" or t.notes:
            notes = f"  [{'; '.join(t.notes)}]" if t.notes else ""
            print(f"  {mark:>4}  {t.key:<40} "
                  f"rules: {', '.join(t.rules_run) or '-'}{notes}",
                  file=out)
        for f in t.findings:
            print(f"        {f}", file=out)
    print(f"sweep: {'CLEAN' if report.ok else 'VIOLATIONS'} "
          f"({len(report.findings)} findings)", file=out)


def render_findings(title: str, findings: List[Finding], out=None) -> None:
    out = out or sys.stdout
    status = "CLEAN" if not findings else f"{len(findings)} findings"
    print(f"{title}: {status}", file=out)
    for f in findings:
        print(f"  {f}", file=out)


def render_rules(out=None) -> None:
    out = out or sys.stdout
    for rule in all_rules():
        print(f"  {rule.name:<26} {rule.description}", file=out)


def to_json_dict(sweep: Optional[SweepReport] = None,
                 aliasing: Optional[List[Finding]] = None,
                 submit: Optional[List[Finding]] = None,
                 retention: Optional[List[Finding]] = None
                 ) -> Dict[str, Any]:
    doc: Dict[str, Any] = {"rules": {r.name: r.description
                                     for r in all_rules()}}
    ok = True
    if sweep is not None:
        doc["sweep"] = sweep.to_dict()
        ok = ok and sweep.ok
    for key, findings in (("aliasing", aliasing), ("submit", submit),
                          ("retention", retention)):
        if findings is not None:
            doc[key] = [f.to_dict() for f in findings]
            ok = ok and not any(f.severity == "error" for f in findings)
    doc["ok"] = ok
    return doc
