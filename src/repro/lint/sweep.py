"""Registry-wide invariant sweep: lint every registered backend combo.

``sweep()`` enumerates the LIVE ``models.backends`` registries — every
registered ``(cache_kind, style, impl)`` decode AND prefill backend —
builds each combo's serving program at reduced shape through the same
dispatchers the engine serves with (``forward_step`` /
``forward_prefill``), and runs every applicable registered rule on it.
Registering a new backend combo therefore gets it linted with ZERO new
test code; registering a new rule sweeps the whole grid with zero new
per-combo code.

Programs are traced at **bfloat16** (not the reduced configs' float32):
``NoDtypePromotionDrift`` hunts accidental fp32 shadows of the cache, a
class that is invisible when the cache itself is fp32.

Coverage is loud, not best-effort: a registered cache kind or style the
sweep has no target builder for yields an ERROR finding (rather than a
silently-unlinted combo), and the sweep asserts its target count equals
the registry size.  New cache kinds extend the sweep via
``register_sweep_builders(cache_kind, decode=…, prefill=…)`` — the lint
face of the same seam that registers the backend itself.

Shapes: ``SWEEP_MAX_LEN`` is chosen (as in tests/test_paged_prefill) to
collide with no model or pool dimension, so any max_len-sized aval a rule
finds is a real worst-case intermediate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.lint import walker
from repro.lint.rules import (Finding, LintRule, LintTarget, all_rules,
                              run_rules)
from repro.models import (DenseChunkDest, DensePrefillDest, PagedChunkDest,
                          PagedPrefillDest, PagedQ8ChunkDest,
                          PagedQ8PrefillDest, backends, forward_prefill,
                          forward_prefill_chunk, forward_step, init_cache,
                          init_paged_cache, init_paged_q8_cache, init_params,
                          paged_table_blocks)

SWEEP_DTYPE = "bfloat16"   # sub-fp32 so promotion drift is observable
SWEEP_MAX_LEN = 160        # collides with no model/pool dim (cf. tests)
SWEEP_BLOCK = 8
SWEEP_POOL_BLOCKS = 21     # 21*8 = 168 != SWEEP_MAX_LEN
SWEEP_BUCKET = 16
SWEEP_DECODE_LEN = 32
SWEEP_CHUNK = 8            # chunk width (ring paged pins it to the block)
SWEEP_CHUNK_LEN = 16       # dense chunk cache len: == the reduced configs'
#                            sliding window, so the window is NON-binding
#                            and dense chunking is legal (cf. adapters)


@dataclasses.dataclass
class TargetReport:
    """One swept combo: which rules ran, what they found."""
    key: str
    phase: str
    cache_kind: str
    style: str
    impl: str
    rules_run: List[str]
    findings: List[Finding]
    notes: List[str]

    def to_dict(self) -> Dict[str, Any]:
        return {"key": self.key, "phase": self.phase,
                "cache_kind": self.cache_kind, "style": self.style,
                "impl": self.impl, "rules_run": self.rules_run,
                "findings": [f.to_dict() for f in self.findings],
                "notes": self.notes}


@dataclasses.dataclass
class SweepReport:
    targets: List[TargetReport]
    n_decode_backends: int
    n_prefill_backends: int
    n_chunk_backends: int = 0

    @property
    def findings(self) -> List[Finding]:
        return [f for t in self.targets for f in t.findings]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def n_decode_targets(self) -> int:
        return sum(1 for t in self.targets if t.phase == "decode")

    @property
    def n_prefill_targets(self) -> int:
        return sum(1 for t in self.targets if t.phase == "prefill")

    @property
    def n_chunk_targets(self) -> int:
        return sum(1 for t in self.targets if t.phase == "chunk")

    def to_dict(self) -> Dict[str, Any]:
        return {"targets": [t.to_dict() for t in self.targets],
                "n_decode_backends": self.n_decode_backends,
                "n_prefill_backends": self.n_prefill_backends,
                "n_chunk_backends": self.n_chunk_backends,
                "ok": self.ok}


# ---------------------------------------------------------------------------
# target builders, keyed by cache_kind — the extension point new cache
# kinds register alongside their adapter/backends
# ---------------------------------------------------------------------------

# builder(cfg, params, impl) -> dict of LintTarget fields
#   {"jaxpr": …, "lowered": …, "donated_flat": …, "max_len": …,
#    "cache_shapes": …, "cache_dtype": …}
TargetBuilder = Callable[..., Dict[str, Any]]

_DECODE_BUILDERS: Dict[str, TargetBuilder] = {}
_PREFILL_BUILDERS: Dict[str, TargetBuilder] = {}
_CHUNK_BUILDERS: Dict[str, TargetBuilder] = {}


def register_sweep_builders(cache_kind: str, *,
                            decode: Optional[TargetBuilder] = None,
                            prefill: Optional[TargetBuilder] = None,
                            chunk: Optional[TargetBuilder] = None) -> None:
    """Register how the sweep builds ``cache_kind``'s reduced-shape
    programs (latest wins, like every registry here)."""
    if decode is not None:
        _DECODE_BUILDERS[cache_kind] = decode
    if prefill is not None:
        _PREFILL_BUILDERS[cache_kind] = prefill
    if chunk is not None:
        _CHUNK_BUILDERS[cache_kind] = chunk


def _float_cache_fields(cache_shape) -> Tuple[Tuple[Tuple[int, ...], ...],
                                              Any]:
    """(shapes, dtype) of the cache tree's float leaves — what
    ``NoDtypePromotionDrift`` guards against wider shadows of."""
    leaves = [leaf for leaf in jax.tree.leaves(cache_shape)
              if hasattr(leaf, "dtype")
              and jnp.issubdtype(leaf.dtype, jnp.floating)]
    shapes = tuple(tuple(leaf.shape) for leaf in leaves)
    dtype = leaves[0].dtype if leaves else None
    return shapes, dtype


def _instrumented_jaxpr(fn, *args):
    """Re-trace ``fn`` with the repro.obs observer ACTIVE: what the
    program compiles to in an instrumented serve.  Any forward-path code
    consulting ``obs.get_active()`` takes its obs-on branch here, so
    ``NoHostTransferInObsHooks`` can diff the result against the plain
    trace and prove instrumentation stages nothing into the program.

    The fresh lambda is load-bearing: jax caches traces on (function
    identity, avals), and every builder traces ``fn`` on these same avals
    FIRST — re-tracing the same object would return the cached
    uninstrumented jaxpr and the rule could never fire."""
    from repro.obs import Observer, activated
    with activated(Observer(trace_capacity=64)):
        return jax.make_jaxpr(lambda *a: fn(*a))(*args)


def _try_lower(fn, donate_argnums, example_args):
    """Lower ``jit(fn, donate_argnums=…)`` for the example args; returns
    (lowered, donated_flat, note).  Impls that can't lower on this
    backend (un-interpreted Pallas on CPU) degrade to a note, not a
    crash — jaxpr-level rules still run."""
    try:
        lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(
            *example_args)
    except Exception as e:  # pragma: no cover - backend-dependent
        return None, (), (f"lowering unavailable on this backend "
                          f"({type(e).__name__}); jaxpr rules only")
    flat = tuple(walker.donated_flat_indices(example_args, donate_argnums))
    return lowered, flat, None


def _build_decode_dense(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1,), jnp.int32)
    cshape = jax.eval_shape(
        lambda: init_cache(cfg, 1, SWEEP_DECODE_LEN))

    def fn(p, t, c):
        return forward_step(p, cfg, t, c, impl=impl)

    jaxpr = jax.make_jaxpr(fn)(ps, toks, cshape)
    # the engine donates the cache (serve_step donate_argnums=(2,))
    lowered, donated, note = _try_lower(fn, (2,), (ps, toks, cshape))
    shapes, dtype = _float_cache_fields(cshape)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, ps, toks, cshape),
            "notes": [note] if note else []}


def _build_decode_paged(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1,), jnp.int32)
    cshape = jax.eval_shape(
        lambda: init_paged_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                 SWEEP_DECODE_LEN))

    def fn(p, t, c):
        return forward_step(p, cfg, t, c, impl=impl)

    jaxpr = jax.make_jaxpr(fn)(ps, toks, cshape)
    lowered, donated, note = _try_lower(fn, (2,), (ps, toks, cshape))
    shapes, dtype = _float_cache_fields(cshape)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, ps, toks, cshape),
            "notes": [note] if note else []}


def _build_prefill_dense(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_BUCKET), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)

    def fn(p, t, n):
        return forward_prefill(p, cfg, t, DensePrefillDest(SWEEP_DECODE_LEN),
                               impl=impl, true_len=n)

    jaxpr = jax.make_jaxpr(fn)(ps, toks, tl)
    cshape = jax.eval_shape(lambda: init_cache(cfg, 1, SWEEP_DECODE_LEN))
    shapes, dtype = _float_cache_fields(cshape)
    # dense prefill declares no donation (it BUILDS the fresh cache)
    return {"jaxpr": jaxpr, "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, ps, toks, tl)}


def _build_prefill_paged(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_BUCKET), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)
    pool = jax.eval_shape(
        lambda: init_paged_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                 SWEEP_MAX_LEN))
    kp = pool.k
    vp = pool.v
    bids = jax.ShapeDtypeStruct((SWEEP_BUCKET // SWEEP_BLOCK,), jnp.int32)

    def fn(p, t, n, k, v, b):
        return forward_prefill(p, cfg, t, PagedPrefillDest(k, v, b),
                               impl=impl, true_len=n)

    args = (ps, toks, tl, kp, vp, bids)
    jaxpr = jax.make_jaxpr(fn)(*args)
    # the paged adapter donates the pools (build_prefill donate=(3, 4))
    lowered, donated, note = _try_lower(fn, (3, 4), args)
    shapes, dtype = _float_cache_fields(pool)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "max_len": SWEEP_MAX_LEN, "cache_shapes": shapes,
            "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, *args),
            "notes": [note] if note else []}


def _build_chunk_dense(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_CHUNK), jnp.int32)
    s = jax.ShapeDtypeStruct((1,), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)
    slot = jax.ShapeDtypeStruct((1,), jnp.int32)
    cshape = jax.eval_shape(lambda: init_cache(cfg, 1, SWEEP_CHUNK_LEN))

    def fn(p, t, st, n, sl, c):
        return forward_prefill_chunk(p, cfg, t, DenseChunkDest(c, sl),
                                     start=st, true_len=n, impl=impl,
                                     max_len=SWEEP_CHUNK_LEN)

    args = (ps, toks, s, tl, slot, cshape)
    jaxpr = jax.make_jaxpr(fn)(*args)
    # the dense adapter donates the cache (build_chunk donate=(5,))
    lowered, donated, note = _try_lower(fn, (5,), args)
    shapes, dtype = _float_cache_fields(cshape)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, *args),
            "notes": [note] if note else []}


def _build_chunk_paged(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_CHUNK), jnp.int32)
    s = jax.ShapeDtypeStruct((1,), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)
    pool = jax.eval_shape(
        lambda: init_paged_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                 SWEEP_MAX_LEN))
    kp, vp = pool.k, pool.v
    mb = paged_table_blocks(cfg, SWEEP_BLOCK, SWEEP_MAX_LEN)
    trow = jax.ShapeDtypeStruct((1, mb), jnp.int32)
    bids = jax.ShapeDtypeStruct((SWEEP_CHUNK // SWEEP_BLOCK,), jnp.int32)

    def fn(p, t, st, n, k, v, tr, b):
        return forward_prefill_chunk(p, cfg, t, PagedChunkDest(k, v, tr, b),
                                     start=st, true_len=n, impl=impl)

    args = (ps, toks, s, tl, kp, vp, trow, bids)
    jaxpr = jax.make_jaxpr(fn)(*args)
    # the paged adapter donates the pools (build_chunk donate=(4, 5))
    lowered, donated, note = _try_lower(fn, (4, 5), args)
    shapes, dtype = _float_cache_fields(pool)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, *args),
            "notes": [note] if note else []}


def _q8_pool_fields(cache_shape) -> Tuple[Tuple[Tuple[int, ...], ...], Any]:
    """(shapes, dtype) of the q8 cache's INT8 pool leaves — layer-stacked
    AND per-layer sliced, so ``NoDequantizedPoolBuffer`` catches a
    dequantized shadow inside a scanned layer body too.  The float scale
    rows are deliberately excluded: they are supposed to be float."""
    pools = [leaf for leaf in jax.tree.leaves(cache_shape)
             if hasattr(leaf, "dtype") and leaf.dtype == jnp.int8]
    shapes = tuple(tuple(leaf.shape) for leaf in pools) \
        + tuple(tuple(leaf.shape[1:]) for leaf in pools)
    return shapes, (pools[0].dtype if pools else None)


def _build_decode_paged_q8(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1,), jnp.int32)
    cshape = jax.eval_shape(
        lambda: init_paged_q8_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                    SWEEP_DECODE_LEN))

    def fn(p, t, c):
        return forward_step(p, cfg, t, c, impl=impl)

    jaxpr = jax.make_jaxpr(fn)(ps, toks, cshape)
    lowered, donated, note = _try_lower(fn, (2,), (ps, toks, cshape))
    shapes, dtype = _q8_pool_fields(cshape)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, ps, toks, cshape),
            "notes": [note] if note else []}


def _build_prefill_paged_q8(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_BUCKET), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)
    pool = jax.eval_shape(
        lambda: init_paged_q8_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                    SWEEP_MAX_LEN))
    bids = jax.ShapeDtypeStruct((SWEEP_BUCKET // SWEEP_BLOCK,), jnp.int32)

    def fn(p, t, n, k, v, ks, vs, b):
        return forward_prefill(p, cfg, t,
                               PagedQ8PrefillDest(k, v, ks, vs, b),
                               impl=impl, true_len=n)

    args = (ps, toks, tl, pool.k, pool.v, pool.k_scale, pool.v_scale, bids)
    jaxpr = jax.make_jaxpr(fn)(*args)
    # the q8 adapter donates pools AND scales (build_prefill donate=(3..6))
    lowered, donated, note = _try_lower(fn, (3, 4, 5, 6), args)
    shapes, dtype = _q8_pool_fields(pool)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "max_len": SWEEP_MAX_LEN, "cache_shapes": shapes,
            "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, *args),
            "notes": [note] if note else []}


def _build_chunk_paged_q8(cfg, params, impl) -> Dict[str, Any]:
    ps = jax.eval_shape(lambda: params)
    toks = jax.ShapeDtypeStruct((1, SWEEP_CHUNK), jnp.int32)
    s = jax.ShapeDtypeStruct((1,), jnp.int32)
    tl = jax.ShapeDtypeStruct((1,), jnp.int32)
    pool = jax.eval_shape(
        lambda: init_paged_q8_cache(cfg, SWEEP_POOL_BLOCKS, SWEEP_BLOCK, 1,
                                    SWEEP_MAX_LEN))
    mb = paged_table_blocks(cfg, SWEEP_BLOCK, SWEEP_MAX_LEN)
    trow = jax.ShapeDtypeStruct((1, mb), jnp.int32)
    bids = jax.ShapeDtypeStruct((SWEEP_CHUNK // SWEEP_BLOCK,), jnp.int32)

    def fn(p, t, st, n, k, v, ks, vs, tr, b):
        return forward_prefill_chunk(
            p, cfg, t, PagedQ8ChunkDest(k, v, ks, vs, tr, b),
            start=st, true_len=n, impl=impl)

    args = (ps, toks, s, tl, pool.k, pool.v, pool.k_scale, pool.v_scale,
            trow, bids)
    jaxpr = jax.make_jaxpr(fn)(*args)
    # the q8 adapter donates pools AND scales (build_chunk donate=(4..7))
    lowered, donated, note = _try_lower(fn, (4, 5, 6, 7), args)
    shapes, dtype = _q8_pool_fields(pool)
    return {"jaxpr": jaxpr, "lowered": lowered, "donated_flat": donated,
            "cache_shapes": shapes, "cache_dtype": dtype,
            "instrumented_jaxpr": _instrumented_jaxpr(fn, *args),
            "notes": [note] if note else []}


register_sweep_builders("dense", decode=_build_decode_dense,
                        prefill=_build_prefill_dense,
                        chunk=_build_chunk_dense)
register_sweep_builders("paged", decode=_build_decode_paged,
                        prefill=_build_prefill_paged,
                        chunk=_build_chunk_paged)
register_sweep_builders("paged_q8", decode=_build_decode_paged_q8,
                        prefill=_build_prefill_paged_q8,
                        chunk=_build_chunk_paged_q8)


# ---------------------------------------------------------------------------
# the sweep driver
# ---------------------------------------------------------------------------

def sweep_models() -> Dict[str, Tuple[Any, Any]]:
    """style-key -> (cfg, params) at reduced shape, traced-dtype
    ``SWEEP_DTYPE``: "generic" is the unmerged skipless model, "merged"
    its qp (Q/P-free) rewrite — the same recipe as the equivalence
    grids."""
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", n_kv_heads=4,
        dtype=SWEEP_DTYPE, param_dtype=SWEEP_DTYPE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    return {"generic": (cfg, params), "merged": (mcfg, mparams)}


def _uncovered(phase: str, key: Tuple[str, str, str], why: str
               ) -> TargetReport:
    ck, st, impl = key
    t = TargetReport(key=f"{phase}:{ck}/{st}/{impl}", phase=phase,
                     cache_kind=ck, style=st, impl=impl, rules_run=[],
                     findings=[Finding(
                         rule="SweepCoverage",
                         target=f"{phase}:{ck}/{st}/{impl}",
                         message=f"registered backend NOT linted: {why} — "
                                 f"register a sweep builder/model so this "
                                 f"combo is covered",
                     )], notes=[])
    return t


def _sweep_phase(phase: str, keys: List[Tuple[str, str, str]],
                 models: Dict[str, Tuple[Any, Any]],
                 builders: Dict[str, TargetBuilder],
                 rules: Optional[List[LintRule]],
                 progress: Optional[Callable[[str], None]]
                 ) -> List[TargetReport]:
    # trace each (cache_kind, style, impl) program once, keeping the
    # generic programs around as the merged targets' diff baselines
    out: List[TargetReport] = []
    generic_jaxprs: Dict[Tuple[str, str], Any] = {}
    for ck, st, impl in sorted(keys, key=lambda k: (k[0], k[2], k[1])):
        if st not in models:
            out.append(_uncovered(phase, (ck, st, impl),
                                  f"no sweep model for style {st!r}"))
            continue
        if ck not in builders:
            out.append(_uncovered(phase, (ck, st, impl),
                                  f"no sweep builder for cache kind {ck!r}"))
            continue
        cfg, params = models[st]
        if progress:
            progress(f"{phase}:{ck}/{st}/{impl}")
        built = builders[ck](cfg, params, impl)
        notes = built.pop("notes", [])
        if st == "generic":
            generic_jaxprs[(ck, impl)] = built["jaxpr"]
        target = LintTarget(phase=phase, cache_kind=ck, style=st, impl=impl,
                            cfg=cfg,
                            source_jaxpr=generic_jaxprs.get((ck, impl)),
                            **built)
        ran, findings = run_rules(target, rules)
        out.append(TargetReport(key=target.key, phase=phase, cache_kind=ck,
                                style=st, impl=impl, rules_run=ran,
                                findings=findings, notes=notes))
    return out


def sweep(rules: Optional[List[LintRule]] = None,
          progress: Optional[Callable[[str], None]] = None) -> SweepReport:
    """Lint every registered decode and prefill backend.

    ``rules`` defaults to every registered rule; ``progress`` (if given)
    is called with each target key as it is traced.  The returned report
    covers EXACTLY the live registries — one target per registered combo,
    asserted — with loud findings for combos the sweep cannot build."""
    import repro.lint.builtin  # noqa: F401  (ensure built-ins registered)
    models = sweep_models()
    dkeys = backends.registered_backends()
    pkeys = backends.registered_prefill_backends()
    ckeys = backends.registered_chunk_backends()
    targets = _sweep_phase("decode", dkeys, models, _DECODE_BUILDERS,
                           rules, progress)
    targets += _sweep_phase("prefill", pkeys, models, _PREFILL_BUILDERS,
                            rules, progress)
    targets += _sweep_phase("chunk", ckeys, models, _CHUNK_BUILDERS,
                            rules, progress)
    report = SweepReport(targets=targets, n_decode_backends=len(dkeys),
                         n_prefill_backends=len(pkeys),
                         n_chunk_backends=len(ckeys))
    assert report.n_decode_targets == len(dkeys), (
        report.n_decode_targets, len(dkeys))
    assert report.n_prefill_targets == len(pkeys), (
        report.n_prefill_targets, len(pkeys))
    assert report.n_chunk_targets == len(ckeys), (
        report.n_chunk_targets, len(ckeys))
    return report
