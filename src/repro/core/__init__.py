"""The paper's contribution: exact Q/P (K/P, V/P) weight removal for
skipless transformers, plus its weight/bandwidth accounting."""
from repro.core.merge import (
    condition_numbers,
    merge_skipless,
    removed_weight_count,
)
from repro.core.analysis import (
    active_weights_per_token,
    decode_ms_per_token,
    decode_speedup,
    weight_table,
)

__all__ = [
    "condition_numbers",
    "merge_skipless",
    "removed_weight_count",
    "active_weights_per_token",
    "decode_ms_per_token",
    "decode_speedup",
    "weight_table",
]
