"""Weight counting + batch-1 decode speedup model (paper §3).

``weight_table(cfg)`` reproduces the paper's table exactly for the two
example configs (Pythia-6.9B, Mistral-7B) using the paper's own formulas:

  Q+P per layer  = 2·d²
  K+V per layer  = 2·d²·n_kv/n_heads
  FFN per layer  = (2 or 3)·d·hidden           (3 for GLU variants)
  embeddings     = 2·d·vocab                   (input + output)

and extends them to the other assigned families (MoE experts+router, SSD
mixers, hybrid, VLM cross-attn layers, conv positional embeddings).

``decode_speedup(cfg)`` is the paper's bandwidth-bound model: batch-1
autoregressive decode time ∝ bytes of weights read per token, so
speedup = total / (total − removed).  ``active_only=True`` extends it
beyond the paper for MoE (only routed experts are read per token).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig


def _per_layer_counts(cfg: ModelConfig) -> Dict[str, int]:
    d, f = cfg.d_model, cfg.d_ff
    c: Dict[str, int] = {}
    if cfg.has_attention:
        c["qp"] = d * cfg.attn_dim + cfg.attn_dim * d  # Q and P
        c["kv"] = 2 * d * cfg.kv_dim
        if cfg.qkv_bias:
            c["qp"] += cfg.attn_dim
            c["kv"] += 2 * cfg.kv_dim
    glu_mult = 3 if cfg.is_glu else 2
    if cfg.has_ffn:
        if cfg.n_experts:
            c["ffn"] = cfg.n_experts * glu_mult * d * f + d * cfg.n_experts
        else:
            c["ffn"] = glu_mult * d * f
    if cfg.ssm_state:
        d_inner = cfg.ssm_d_inner
        H, G, N, W = cfg.ssm_n_heads, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_conv_width
        conv_ch = d_inner + 2 * G * N
        c["ssm"] = (d * (2 * d_inner + 2 * G * N + H)  # in_proj
                    + W * conv_ch + conv_ch            # conv kernel + bias
                    + 3 * H                            # A_log, D, dt_bias
                    + d_inner                          # gated norm
                    + d_inner * d)                     # out_proj
    return c


def weight_table(cfg: ModelConfig) -> Dict[str, float]:
    """Totals + paper-table-style rows."""
    d = cfg.d_model
    per = _per_layer_counts(cfg)
    embed = d * cfg.vocab_size * (1 if cfg.tie_embeddings else 2)
    if cfg.conv_pos_width:
        embed += cfg.conv_pos_width * d + d

    if cfg.family == "vlm":
        per_cross = cfg.n_layers // cfg.cross_attn_every
        per_self = cfg.n_layers - per_cross
        layer_total = sum(per.values())
        total = per_self * layer_total + per_cross * layer_total + embed
        n_attn_layers = cfg.n_layers
    else:
        layer_total = sum(per.values())
        total = cfg.n_layers * layer_total + embed
        n_attn_layers = cfg.n_layers if cfg.has_attention else 0

    # removable weights under the merged form (serial Fig 1b / Table 1)
    if not cfg.has_attention:
        removed = 0
    elif cfg.family == "hybrid":
        removed = cfg.n_layers * d * cfg.attn_dim  # Q only (see DESIGN §5)
    elif cfg.family == "audio":
        removed = n_attn_layers * per["qp"] - d * d  # input_proj retained
    else:
        removed = n_attn_layers * per["qp"]

    total_wo = total - removed
    return {
        "qp_per_layer": per.get("qp", 0),
        "kv_per_layer": per.get("kv", 0),
        "ffn_per_layer": per.get("ffn", 0),
        "ssm_per_layer": per.get("ssm", 0),
        "embed": embed,
        "total": total,
        "removed": removed,
        "total_without_qp": total_wo,
        "savings_frac": removed / total if total else 0.0,
        "speedup": total / total_wo if total_wo else 1.0,
    }


def active_weights_per_token(cfg: ModelConfig, with_qp: bool = True) -> int:
    """Weights read per decoded token (MoE: routed experts only)."""
    d, f = cfg.d_model, cfg.d_ff
    per = _per_layer_counts(cfg)
    glu_mult = 3 if cfg.is_glu else 2
    if cfg.n_experts:
        per = dict(per)
        per["ffn"] = cfg.experts_per_token * glu_mult * d * f + d * cfg.n_experts
    layer = sum(per.values())
    if not with_qp and cfg.has_attention:
        layer -= per.get("qp", 0) if cfg.family != "hybrid" else d * cfg.attn_dim
    # embedding: one row read + full unembedding matmul
    embed = d + d * cfg.vocab_size
    return cfg.n_layers * layer + embed


def decode_speedup(cfg: ModelConfig, active_only: bool = False) -> float:
    """Paper §3 model: batch-1, memory-bandwidth-bound decode."""
    if active_only:
        a = active_weights_per_token(cfg, with_qp=True)
        b = active_weights_per_token(cfg, with_qp=False)
        return a / b
    t = weight_table(cfg)
    return t["speedup"]


def decode_ms_per_token(n_weights: int, bytes_per_weight: int = 2,
                        hbm_gbps: float = 819.0, chips: int = 1) -> float:
    """Lower-bound ms/token when weight streaming saturates HBM (v5e)."""
    return n_weights * bytes_per_weight / (hbm_gbps * 1e9 * chips) * 1e3


def cost_dict(compiled) -> Dict[str, float]:
    """Normalize a jitted ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a flat dict; newer versions (0.4.37 here) return a
    list with one dict per executable module.  Sum the per-module entries
    into one dict so callers can ``.get("flops")`` uniformly.  Lives here
    (not in launch.dryrun) because importing dryrun has side effects —
    its XLA_FLAGS mutation forces a 512-device host platform."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for c in cost:
            for k, v in (c or {}).items():
                merged[k] = merged.get(k, 0.0) + float(v)
        return merged
    return dict(cost or {})
