"""Exact weight-removal transforms for skipless transformers (the paper).

``merge_skipless(params, cfg, variant)`` maps a ``block_style="skipless"``
(Fig 1a) parameter tree to a mathematically identical
``block_style="skipless_merged"`` tree (Fig 1b/c/d per Table 1):

  variant "qp" (MHA/MQA/GQA):  O*_{i-1} = O_{i-1} Q_i ;  K* = Q⁻¹K ; V* = Q⁻¹V
  variant "kp" (MHA only):     O*_{i-1} = O_{i-1} K_i ;  Q* = K⁻¹Q ; V* = K⁻¹V
  variant "vp" (MHA only):     O*_{i-1} = O_{i-1} V_i ;  Q* = V⁻¹Q ; K* = V⁻¹K
  all variants:                M*_i = P_i M_i

General rule implemented here: removing projection T_i of block i rewrites
the block-i input basis ``u* = u T_i (+ b_T)``.  This requires
  (a) right-multiplying every *producer* of u (the previous block's output
      matrix — FFN w_down / expert w_down — or the embedding table for i=0)
      by T_i, and
  (b) left-multiplying every OTHER *consumer* of u in block i by T_i⁻¹
      (the remaining attention projections; for hybrid blocks also the SSM
      in_proj).
Affine extension (ours — the paper is bias-free): with QKV biases,
``u* = u T + b_T``, so consumers get ``b'_c = b_c − b_T (T⁻¹ W_c)`` and the
previous block's output gains ``b_out = b_T`` (the embedding gains
``embed_bias``).

P-removal folds P into the FFN input matrices (and MoE router + every
expert's input matrices — same shapes, so MoE merging is free), except:
  * hybrid blocks keep P (the FFN reads the fused attn+ssm stream, see
    DESIGN.md §5) — hybrid gets the Q-removal half only;
  * parallel blocks (paper Fig 3) are a trainable architecture, not an
    exact rewrite — this module only handles serial stacks (the paper's §4
    equivalence experiment is serial Fig 1b/2b as well).

Continuous-input models (audio frames, family="audio") cannot fold T_0 into
an embedding table; the merge emits an explicit ``input_proj`` (= T_0)
instead, so one d×d matrix of savings is forgone for block 0 only.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import layer_plan

# All merge math runs on host in numpy float64: this is an offline,
# init/conversion-time transform, and float64 keeps the rewrite exact even
# for ill-conditioned Q/K/V (cond ~ 1e3 costs ~1e-13 relative in f64).


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def _inv(mat) -> np.ndarray:
    return np.linalg.inv(_f64(mat))


def _t_of(attn: Dict[str, jnp.ndarray], variant: str):
    """The projection being removed (T) and its bias, for one layer (stacked ok)."""
    w = attn["w" + variant[0]]
    b = attn.get("b" + variant[0])
    return w, b


def condition_numbers(params, cfg: ModelConfig, variant: str = "qp") -> np.ndarray:
    """cond₂(T_i) per layer — the paper §4 invertibility audit."""
    plan = layer_plan(cfg)
    mats = []
    if plan["kind"] == "vlm":
        qs = params["layers"]["attn"]["w" + variant[0]]
        mats.append(np.asarray(qs.reshape(-1, *qs.shape[-2:])))
        mats.append(np.asarray(params["cross_layers"]["attn"]["w" + variant[0]]))
    else:
        mats.append(np.asarray(params["layers"]["attn"]["w" + variant[0]]))
    conds = []
    for stack in mats:
        for m in stack:
            s = np.linalg.svd(m.astype(np.float64), compute_uv=False)
            conds.append(s[0] / s[-1])
    return np.asarray(conds)


# ---------------------------------------------------------------------------
# the merge
# ---------------------------------------------------------------------------

def merge_skipless(params: Dict[str, Any], cfg: ModelConfig,
                   variant: str = "qp") -> Tuple[Dict[str, Any], ModelConfig]:
    """Exact (Fig 1) merge of a serial skipless model.  Returns
    (merged_params, merged_cfg)."""
    if cfg.block_style != "skipless":
        raise ValueError("merge_skipless expects block_style='skipless'")
    if cfg.parallel_block:
        raise ValueError(
            "exact merging applies to the serial layout (paper Fig 1/2; "
            "the parallel Fig 3 forms are trainable architectures)")
    mcfg = cfg.with_(block_style="skipless_merged", merged_variant=variant)
    mcfg.validate_style()

    plan = layer_plan(cfg)
    out: Dict[str, Any] = {k: v for k, v in params.items()
                           if k not in ("layers", "cross_layers", "embed")}
    out["embed"] = dict(params["embed"])

    if plan["kind"] == "vlm":
        return _merge_vlm(params, cfg, mcfg, variant, out)

    layers = params["layers"]
    attn = layers["attn"]
    T, bT = _t_of(attn, variant)  # (L, d, d), optional (L, d)
    T = _f64(T)
    bT = None if bT is None else _f64(bT)
    Tinv = _inv(T)  # batched over the layer axis

    new_layers = _merge_layer_stack(layers, cfg, variant, T, bT, Tinv,
                                    next_T=_shifted(T),
                                    next_bT=_shifted_bias(bT))
    out["layers"] = new_layers

    # fold T_0 (+ b_T0) into the embedding / input projection
    dt = params["embed"]["table"].dtype
    T0 = T[0]
    if cfg.family == "audio":
        out["input_proj"] = jnp.asarray(T0, dt)
        if bT is not None:
            out["embed_bias"] = jnp.asarray(bT[0], dt)
    else:
        out["embed"]["table"] = jnp.asarray(
            _f64(params["embed"]["table"]) @ T0, dt)
        if bT is not None:
            out["embed_bias"] = jnp.asarray(bT[0], dt)
        if cfg.tie_embeddings:
            # the unembedding must keep the ORIGINAL table: basis rotation
            # applies to the input side only. Untie.
            out["unembed"] = {"table": params["embed"]["table"]}
            mcfg = mcfg.with_(tie_embeddings=False)
    return out, mcfg


def _shifted(T: np.ndarray) -> np.ndarray:
    """next_T[i] = T[i+1]; last gets identity (no next block)."""
    eye = np.eye(T.shape[-1], dtype=T.dtype)[None]
    return np.concatenate([_f64(T)[1:], eye], axis=0)


def _shifted_bias(bT):
    if bT is None:
        return None
    zero = np.zeros_like(bT[:1])
    return np.concatenate([bT[1:], zero], axis=0)


def _merge_layer_stack(layers, cfg: ModelConfig, variant: str,
                       T, bT, Tinv, next_T, next_bT) -> Dict[str, Any]:
    """Merge a homogeneous stacked layer tree (dense/moe/hybrid/audio)."""
    attn = layers["attn"]
    new: Dict[str, Any] = {}
    new_attn: Dict[str, Any] = {}

    # (b) consumers of u: remaining attention projections  W' = T⁻¹ W,
    #     biases b' = b − b_T (T⁻¹ W)
    for name in ("q", "k", "v"):
        if name == variant[0]:
            continue  # eliminated / identity
        w = attn["w" + name]
        w2 = np.einsum("lde,lef->ldf", Tinv, _f64(w))
        new_attn["w" + name] = jnp.asarray(w2, w.dtype)
        b = attn.get("b" + name)
        if bT is not None:
            b0 = 0.0 if b is None else _f64(b)
            new_attn["b" + name] = jnp.asarray(
                b0 - np.einsum("ld,ldf->lf", bT, w2), w.dtype)
        elif b is not None:
            new_attn["b" + name] = b

    is_hybrid = "ssm" in layers and "attn" in layers
    keep_p = is_hybrid  # hybrid: P stays (Q-removal only)

    if keep_p:
        new_attn["wp"] = attn["wp"]
        # SSM in_proj is a consumer of u too
        new_ssm = dict(layers["ssm"])
        w = new_ssm["in_proj"]
        new_ssm["in_proj"] = jnp.asarray(
            np.einsum("lde,lef->ldf", Tinv, _f64(w)), w.dtype)
        if bT is not None:
            raise NotImplementedError("hybrid merge with QKV biases")
        new["ssm"] = new_ssm

    new["attn"] = new_attn

    # P-fold into FFN/MoE input matrices; w_down absorbs next block's T
    def fold_P(w_in):  # (L, d, f) -> (L, ad, f)
        if keep_p:
            return w_in
        P = attn["wp"]  # (L, ad, d)
        return jnp.asarray(np.einsum("lad,ldf->laf", _f64(P), _f64(w_in)),
                           w_in.dtype)

    def absorb_next(w_down):  # (L, f, d) @ next_T (L, d, d)
        return jnp.asarray(np.einsum("lfd,lde->lfe", _f64(w_down), _f64(next_T)),
                           w_down.dtype)

    if "ffn" in layers:
        ffn = dict(layers["ffn"])
        if "w_gate" in ffn:
            ffn["w_gate"] = fold_P(ffn["w_gate"])
            ffn["w_up"] = fold_P(ffn["w_up"])
            ffn["w_down"] = absorb_next(ffn["w_down"])
        else:
            ffn["w_in"] = fold_P(ffn["w_in"])
            ffn["w_out"] = absorb_next(ffn["w_out"])
        new["ffn"] = ffn
    if "moe" in layers:
        moe = dict(layers["moe"])
        if not keep_p:
            P = _f64(attn["wp"])
            moe["router"] = jnp.asarray(
                np.einsum("lad,lde->lae", P, _f64(moe["router"])), jnp.float32)
            moe["w_gate"] = jnp.asarray(
                np.einsum("lad,ledf->leaf", P, _f64(moe["w_gate"])),
                moe["w_gate"].dtype)
            moe["w_up"] = jnp.asarray(
                np.einsum("lad,ledf->leaf", P, _f64(moe["w_up"])),
                moe["w_up"].dtype)
        moe["w_down"] = jnp.asarray(
            np.einsum("lefd,ldg->lefg", _f64(moe["w_down"]), _f64(next_T)),
            moe["w_down"].dtype)
        new["moe"] = moe
    if "ssm" in layers and not is_hybrid:
        raise ValueError("pure SSM stacks have no Q/K/V/P to merge")

    # b_out: next block's folded bias enters the stream after w_down
    if next_bT is not None:
        new["b_out"] = jnp.asarray(next_bT, jax.tree.leaves(attn)[0].dtype)

    return new


def _merge_vlm(params, cfg: ModelConfig, mcfg: ModelConfig, variant: str, out):
    """VLM: interleaved self/cross stacks. Layer order is
    [self(g,0)…self(g,spg-1), cross(g)] for g in 0..ng-1."""
    if variant != "qp":
        raise ValueError("VLM merge supports the qp variant (cross-attn K/V "
                         "read vision tokens, which are not stream-basis)")
    if cfg.qkv_bias:
        raise NotImplementedError("vlm merge with QKV biases")
    slf = params["layers"]  # (ng, spg, …)
    crs = params["cross_layers"]  # (ng, …)
    ng = jax.tree.leaves(crs)[0].shape[0]
    spg = jax.tree.leaves(slf)[0].shape[1]
    d = cfg.d_model

    Tq_self = _f64(slf["attn"]["wq"])  # (ng, spg, d, d)
    Tq_cross = _f64(crs["attn"]["wq"])  # (ng, d, d)

    # next_T for self(g,s): self(g,s+1) if s<spg-1 else cross(g)
    next_T_self = np.concatenate(
        [Tq_self[:, 1:], Tq_cross[:, None]], axis=1)  # (ng, spg, d, d)
    # next_T for cross(g): self(g+1, 0); last cross gets identity
    eye = np.eye(d)[None]
    next_T_cross = np.concatenate([Tq_self[1:, 0], eye], axis=0)  # (ng, d, d)

    def flat(tree, n):  # (ng, spg, …) -> (ng*spg, …)
        return jax.tree.map(lambda x: x.reshape((n,) + x.shape[2:]), tree)

    slf_flat = flat(slf, ng * spg)
    T = _f64(slf_flat["attn"]["wq"])
    Tinv = _inv(T)
    merged_self = _merge_layer_stack(
        slf_flat, cfg, variant, T, None, Tinv,
        next_T=next_T_self.reshape(ng * spg, d, d), next_bT=None)
    out["layers"] = jax.tree.map(
        lambda x: x.reshape((ng, spg) + x.shape[1:]), merged_self)

    # cross layers: only consumer of u is Q (K/V read vision) -> no (b) step
    new_cross: Dict[str, Any] = {"attn": {
        "wk": crs["attn"]["wk"], "wv": crs["attn"]["wv"]}}
    P = _f64(crs["attn"]["wp"])
    ffn = dict(crs["ffn"])
    dtf = ffn["w_gate"].dtype
    ffn["w_gate"] = jnp.asarray(np.einsum("lad,ldf->laf", P, _f64(ffn["w_gate"])), dtf)
    ffn["w_up"] = jnp.asarray(np.einsum("lad,ldf->laf", P, _f64(ffn["w_up"])), dtf)
    ffn["w_down"] = jnp.asarray(
        np.einsum("lfd,lde->lfe", _f64(ffn["w_down"]), next_T_cross), dtf)
    new_cross["ffn"] = ffn
    out["cross_layers"] = new_cross

    dt = params["embed"]["table"].dtype
    out["embed"]["table"] = jnp.asarray(
        _f64(params["embed"]["table"]) @ Tq_self[0, 0], dt)
    return out, mcfg


# ---------------------------------------------------------------------------
# weight-savings accounting for a merged tree (used by benchmarks/tests)
# ---------------------------------------------------------------------------

def removed_weight_count(params_before, params_after) -> int:
    n_before = sum(int(x.size) for x in jax.tree.leaves(params_before))
    n_after = sum(int(x.size) for x in jax.tree.leaves(params_after))
    return n_before - n_after
