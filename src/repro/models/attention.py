"""Attention cores: MHA/MQA/GQA, causal & sliding-window, prefill & decode.

Projections (Q/K/V/P) live in the *block* modules (``core.blocks`` /
``models.transformer``) because the paper's merged form changes which
projections exist.  This module only computes attention given projected
(and RoPE'd) q/k/v.

Three implementations:
  * ``impl="xla"`` — chunked exact attention (lax.map over query chunks) so
    the materialized score buffer is O(chunk × S_k), never O(S_q × S_k).
    This is the path the multi-pod dry-run lowers.
  * ``impl="pallas"`` — TPU Pallas flash-attention kernel (kernels/).
  * ``impl="pallas_interpret"`` — same kernel, interpret mode (CPU tests).

Cores fetch their Pallas route from ``kernels.ops.ATTENTION_KERNELS``,
keyed (phase, cache_kind, style) like the serving backend registries
(``models.backends``) — one table says which combos have fused kernels.
``attention_core_merged`` is the prefill face of the paper's merged
(Q/P-removed) layout: stream-as-query, no head-major transposes, output
in the FFN-input basis.

GQA is computed grouped (q reshaped to (…, n_kv, group, d)) — KV heads are
never materialized repeated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paging import paged_ring_active
from repro.kernels import quant

NEG_INF = -1e30


def _mask_bias(
    q_pos: jnp.ndarray,  # (B, Sq) int32
    kv_pos: jnp.ndarray,  # (B, Sk) int32
    *,
    causal: bool,
    sliding_window: int,
    kv_valid: Optional[jnp.ndarray],  # (B, Sk) bool
) -> jnp.ndarray:
    """Additive bias (B, 1, Sq, Sk) fp32: 0 where attendable, NEG_INF else."""
    ok = jnp.ones((q_pos.shape[0], q_pos.shape[1], kv_pos.shape[1]), bool)
    if causal:
        ok &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if sliding_window > 0:
        ok &= q_pos[:, :, None] - kv_pos[:, None, :] < sliding_window
    if kv_valid is not None:
        ok &= kv_valid[:, None, :]
    return jnp.where(ok, 0.0, NEG_INF)[:, None, :, :].astype(jnp.float32)


def _attend_block(q, k, v, bias, scale):
    """q (B,Sq,Hkv,G,D) k/v (B,Sk,Hkv,D) bias (B,1,Sq,Sk) -> (B,Sq,Hkv,G,D)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale + bias[:, :, None, :, :]
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)


def attention_core(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    q_positions: jnp.ndarray,  # (B, Sq) int32
    kv_positions: jnp.ndarray,  # (B, Sk) int32
    causal: bool = True,
    sliding_window: int = 0,
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Sk) bool (padded caches)
    query_chunk: int = 1024,
    impl: str = "xla",
) -> jnp.ndarray:
    """Exact softmax attention; returns (B, Sq, Hq, D) in v.dtype."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.attention_kernel("prefill", "dense", "generic")(
            q, k, v,
            q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, sliding_window=sliding_window, kv_valid=kv_valid,
            interpret=(impl == "pallas_interpret"),
        )

    qg = q.reshape(B, Sq, Hkv, G, D)

    if Sq <= query_chunk or Sq % query_chunk != 0:
        bias = _mask_bias(q_positions, kv_positions, causal=causal,
                          sliding_window=sliding_window, kv_valid=kv_valid)
        out = _attend_block(qg, k, v, bias, scale)
        return out.reshape(B, Sq, Hq, D)

    # chunked over query blocks: score buffer is (B, chunk, …) not (B, Sq, …)
    n_chunks = Sq // query_chunk
    qg_c = qg.reshape(B, n_chunks, query_chunk, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp_c = q_positions.reshape(B, n_chunks, query_chunk).transpose(1, 0, 2)

    def one_chunk(args):
        qc, qpc = args
        bias = _mask_bias(qpc, kv_positions, causal=causal,
                          sliding_window=sliding_window, kv_valid=kv_valid)
        return _attend_block(qc, k, v, bias, scale)

    out = jax.lax.map(one_chunk, (qg_c, qp_c))  # (n_chunks, B, chunk, Hkv, G, D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D)
    return out


def attention_core_merged(
    u: jnp.ndarray,  # (B, Sq, d_model) — RoPE'd residual stream (merged query)
    k: jnp.ndarray,  # (B, Sk, Hkv, D) — K*, native (sequence-major) layout
    v: jnp.ndarray,  # (B, Sk, Hkv, D) — V*
    *,
    q_positions: jnp.ndarray,  # (B, Sq) int32
    kv_positions: jnp.ndarray,  # (B, Sk) int32
    n_kv_heads: int,
    causal: bool = True,
    sliding_window: int = 0,
    query_chunk: int = 1024,
    impl: str = "xla",
    cache_kind: str = "dense",
    k_scale: Optional[jnp.ndarray] = None,  # (B, Sk//sg, Hkv) f32 (q8 only)
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Merged (Q/P-removed, paper Fig 1b) full-sequence attention — the
    PREFILL sibling of ``decode_attention_core_merged``.

    In ``skipless_merged`` qp-variant blocks the residual stream *is* the
    query basis (Q folded into the producers of u) and no P projection
    exists, so this core takes the stream directly — the grouped-head view
    is a bitcast — and returns the (B, Sq, d_model) FFN-input stream.  The
    pallas route is the stream-as-query flash kernel reading K*/V* tiles
    in their native layout; numerics are identical to ``attention_core``
    on the bitcast head view.  ``cache_kind`` selects the prefill row of
    ``kernels.ops.ATTENTION_KERNELS`` (both cache kinds currently share
    the flash kernel — paging changes the KV *write*, not the math).

    ``k_scale``/``v_scale`` flip the core into q8 mode (the ``paged_q8``
    kind's prefill fake-quant): k/v arrive as int8 at the pool's
    quantization and the scales are per-(page, head).  The pallas route
    hands the int8 tiles + scales to the in-kernel-dequant flash kernel;
    the XLA route dequantizes the sequence once (O(Sk·Hkv·D), same as any
    other kv buffer this core already holds) and falls through.
    """
    B, Sq, d = u.shape
    D = k.shape[3]
    quantized = k_scale is not None

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        kw = {}
        if quantized:
            kw = dict(k_scale=k_scale, v_scale=v_scale)
        return kops.attention_kernel("prefill", cache_kind, "merged")(
            u, k, v, n_kv_heads=n_kv_heads,
            q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"), **kw,
        )

    if quantized:
        k = quant.q8_dequant_seq(k, k_scale, u.dtype)
        v = quant.q8_dequant_seq(v, v_scale, u.dtype)

    out = attention_core(
        u.reshape(B, Sq, d // D, D), k, v,
        q_positions=q_positions, kv_positions=kv_positions,
        causal=causal, sliding_window=sliding_window,
        query_chunk=query_chunk, impl=impl)
    return out.reshape(B, Sq, d)


def decode_attention_core(
    q: jnp.ndarray,  # (B, Hq, D) — single new token per sequence
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    *,
    cache_len: jnp.ndarray,  # (B,) int32 — number of valid cache entries
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """One-token attention against a (padded) KV cache -> (B, Hq, D).

    The query's position is ``cache_len`` (0-indexed next position); the
    cache holds positions [0, cache_len).  For sliding-window archs the
    cache may be a ring buffer — ``kv_positions`` are then supplied by the
    cache layer via ``decode_attention_core_positions``.
    """
    B, S, Hkv, D = k_cache.shape
    kv_positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return decode_attention_core_positions(
        q, k_cache, v_cache, kv_positions=kv_positions,
        q_position=cache_len, sliding_window=sliding_window, impl=impl,
    )


def decode_attention_core_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream (merged query)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D) — K*, native serving layout
    v_cache: jnp.ndarray,  # (B, S, Hkv, D) — V*
    *,
    kv_positions: jnp.ndarray,  # (B, S) int32; -1 marks empty slots
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Merged (Q/P-removed, paper Fig 1b) decode attention.

    In ``skipless_merged`` qp-variant blocks the residual stream *is* the
    query basis (Q folded into the producers of u), and no P projection
    exists — the attention output is already the FFN input.  So this core
    takes the stream directly, skips any q projection, and returns the
    (B, d_model) stream for the FFN.  Numerics are identical to
    ``decode_attention_core_positions`` on the bitcast head view.
    """
    B, d = u.shape
    D = k_cache.shape[3]

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("dense", "merged")(
            u, k_cache, v_cache, kv_positions=kv_positions,
            q_position=q_position, n_kv_heads=n_kv_heads,
            sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"),
        )

    out = decode_attention_core_positions(
        u.reshape(B, d // D, D), k_cache, v_cache,
        kv_positions=kv_positions, q_position=q_position,
        sliding_window=sliding_window, impl=impl)
    return out.reshape(B, d)


def decode_attention_core_positions(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    *,
    kv_positions: jnp.ndarray,  # (B, S) int32; -1 marks empty slots
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("dense", "generic")(
            q, k_cache, v_cache, kv_positions=kv_positions,
            q_position=q_position, sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"),
        )

    qg = q.reshape(B, Hkv, G, D)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    ok = (kv_positions >= 0) & (kv_positions[:, :] <= q_position[:, None])
    if sliding_window > 0:
        ok &= q_position[:, None] - kv_positions < sliding_window
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (B, S)
    probs = jax.nn.softmax(scores + bias[:, None, None, :], axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# paged decode cores (block-table gather over a physical page pool)
# ---------------------------------------------------------------------------

def paged_kv_positions(block_tables: jnp.ndarray, block_size: int,
                       q_position: Optional[jnp.ndarray] = None,
                       ring_blocks: int = 0) -> jnp.ndarray:
    """kv positions of a slot's densified page view; unmapped (-1) blocks
    stay -1 (empty-slot mask).

    Absolute addressing (``ring_blocks`` = 0): logical block j covers
    [j*bs, (j+1)*bs).  Ring addressing (windowed tables bounded at
    ceil(window/bs)+1 recycled slots — see ``kernels.paging``): slot j
    holds the latest absolute block ≡ j (mod ring) not beyond the query's
    current block, so positions are reconstructed from ``q_position``;
    slots reconstructing to b < 0 (never entered) are -1."""
    B, MB = block_tables.shape
    if ring_blocks:
        j = jnp.arange(MB, dtype=jnp.int32)[None, :]
        lb = (jnp.asarray(q_position, jnp.int32) // block_size).reshape(B, 1)
        b = lb - ((lb + ring_blocks - j) % ring_blocks)
        pos = jnp.repeat(b * block_size, block_size, axis=1) + \
            jnp.tile(jnp.arange(block_size, dtype=jnp.int32), MB)[None, :]
        mapped = jnp.repeat((block_tables >= 0) & (b >= 0), block_size,
                            axis=1)
        return jnp.where(mapped, pos, -1)
    pos = jnp.arange(MB * block_size, dtype=jnp.int32)[None, :]
    mapped = jnp.repeat(block_tables >= 0, block_size, axis=1)
    return jnp.broadcast_to(jnp.where(mapped, pos, -1), (B, MB * block_size))


def _paged_gather(pool: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """(NB, bs, Hkv, D) pool + (B, MB) tables -> (B, MB*bs, Hkv, D) view."""
    B, MB = block_tables.shape
    g = pool[jnp.maximum(block_tables, 0)]  # (B, MB, bs, Hkv, D)
    return g.reshape(B, MB * pool.shape[1], *pool.shape[2:])


def decode_attention_core_paged(
    q: jnp.ndarray,  # (B, Hq, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — physical page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D)
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """One-token attention against a paged KV pool -> (B, Hq, D).

    The pallas path hands the pool and table straight to the paged kernel
    (pages are gathered block-by-block inside the grid); the XLA path
    densifies the slot's logical view first and defers to the dense core.
    Ring addressing (windowed tables bounded at ceil(window/bs)+1 recycled
    slots) is derived from the window and the table width
    (``kernels.paging``), with positions reconstructed per query.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("paged", "generic")(
            q, k_pool, v_pool, block_tables=block_tables,
            q_position=q_position, sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"))

    bs = k_pool.shape[1]
    ring = paged_ring_active(sliding_window, bs, block_tables.shape[1])
    return decode_attention_core_positions(
        q, _paged_gather(k_pool, block_tables),
        _paged_gather(v_pool, block_tables),
        kv_positions=paged_kv_positions(block_tables, bs, q_position, ring),
        q_position=q_position, sliding_window=sliding_window, impl=impl)


def decode_attention_core_paged_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream (merged query)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — K* page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) — V* page pool
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Merged (Q/P-removed) decode attention over a paged KV pool.

    Same contract as ``decode_attention_core_merged`` — the stream is the
    query and the output stays in the FFN-input basis — with the cache
    behind a block table instead of a dense per-slot buffer.
    """
    B, d = u.shape
    D = k_pool.shape[3]

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("paged", "merged")(
            u, k_pool, v_pool, block_tables=block_tables,
            q_position=q_position, n_kv_heads=n_kv_heads,
            sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"))

    out = decode_attention_core_paged(
        u.reshape(B, d // D, D), k_pool, v_pool, block_tables=block_tables,
        q_position=q_position, sliding_window=sliding_window, impl=impl)
    return out.reshape(B, d)


# ---------------------------------------------------------------------------
# quantized (paged_q8) decode cores: int8 pools + per-(page, head) scales
# ---------------------------------------------------------------------------

def _paged_gather_q8(pool: jnp.ndarray, scale: jnp.ndarray,
                     block_tables: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """Densify + dequantize a slot's logical view of an int8 pool:
    (NB, bs, Hkv, D) int8 + (NB, Hkv) f32 scales + (B, MB) tables ->
    (B, MB*bs, Hkv, D) ``out_dtype``.  Unmapped blocks gather page 0
    (masked to -1 positions by callers, as in ``_paged_gather``)."""
    B, MB = block_tables.shape
    bt = jnp.maximum(block_tables, 0)
    g = pool[bt].astype(jnp.float32)  # (B, MB, bs, Hkv, D)
    g = g * scale[bt][:, :, None, :, None]
    return g.reshape(B, MB * pool.shape[1], *pool.shape[2:]).astype(out_dtype)


def decode_attention_core_paged_q8(
    q: jnp.ndarray,  # (B, Hq, D)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """One-token attention against an int8 paged pool -> (B, Hq, D).

    Pallas hands pools + scales to the in-kernel-dequant paged kernel (the
    full-precision view never exists); XLA densifies the slot's logical
    view dequantized page-by-page and defers to the dense core, mirroring
    ``decode_attention_core_paged``.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("paged_q8", "generic")(
            q, k_pool, v_pool, k_scale=k_scale, v_scale=v_scale,
            block_tables=block_tables, q_position=q_position,
            sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"))

    bs = k_pool.shape[1]
    ring = paged_ring_active(sliding_window, bs, block_tables.shape[1])
    return decode_attention_core_positions(
        q, _paged_gather_q8(k_pool, k_scale, block_tables, q.dtype),
        _paged_gather_q8(v_pool, v_scale, block_tables, q.dtype),
        kv_positions=paged_kv_positions(block_tables, bs, q_position, ring),
        q_position=q_position, sliding_window=sliding_window, impl=impl)


def decode_attention_core_paged_q8_merged(
    u: jnp.ndarray,  # (B, d_model) — RoPE'd residual stream (merged query)
    k_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 K* page pool
    v_pool: jnp.ndarray,  # (NB, bs, Hkv, D) int8 V* page pool
    k_scale: jnp.ndarray,  # (NB, Hkv) float32 per-(page, head) scales
    v_scale: jnp.ndarray,  # (NB, Hkv) float32
    *,
    block_tables: jnp.ndarray,  # (B, MB) int32 page ids, -1 unmapped
    q_position: jnp.ndarray,  # (B,) int32
    n_kv_heads: int,
    sliding_window: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Merged (Q/P-removed) decode attention over an int8 paged pool —
    the paper's serving fast path at a quarter of the page-pool HBM
    traffic.  Contract as ``decode_attention_core_paged_merged``."""
    B, d = u.shape
    D = k_pool.shape[3]

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.decode_kernel("paged_q8", "merged")(
            u, k_pool, v_pool, k_scale=k_scale, v_scale=v_scale,
            block_tables=block_tables, q_position=q_position,
            n_kv_heads=n_kv_heads, sliding_window=sliding_window,
            interpret=(impl == "pallas_interpret"))

    out = decode_attention_core_paged_q8(
        u.reshape(B, d // D, D), k_pool, v_pool, k_scale, v_scale,
        block_tables=block_tables, q_position=q_position,
        sliding_window=sliding_window, impl=impl)
    return out.reshape(B, d)
