"""Feed-forward networks: SwiGLU / GEGLU / GeLU-MLP.

The FFN input dimension is a parameter (``d_in``) because under the paper's
merged form (Fig 1b) the post-attention projection P is folded into the FFN
input matrices, whose input is then the attention concat (attn_dim) rather
than the block stream (d_model).  The fold does not change any shape when
attn_dim == d_model (all assigned archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_ffn(key, d_in: int, d_ff: int, d_out: int, ffn_type: str,
             dtype=jnp.float32, init_fn=dense_init, out_gain: float = 1.0):
    if ffn_type in ("swiglu", "geglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": init_fn(k1, d_in, d_ff, dtype),
            "w_up": init_fn(k2, d_in, d_ff, dtype),
            "w_down": init_fn(k3, d_ff, d_out, dtype, scale=out_gain),
        }
    elif ffn_type == "gelu_mlp":
        k1, k2 = jax.random.split(key)
        return {
            "w_in": init_fn(k1, d_in, d_ff, dtype),
            "w_out": init_fn(k2, d_ff, d_out, dtype, scale=out_gain),
        }
    raise ValueError(f"unknown ffn_type {ffn_type!r}")


def ffn_hidden(params, x, ffn_type: str):
    """First half of the FFN: input matmul(s) + nonlinearity -> (…, d_ff)."""
    if ffn_type in ("swiglu", "geglu"):
        act = jax.nn.silu if ffn_type == "swiglu" else jax.nn.gelu
        g = x @ params["w_gate"].astype(x.dtype)
        u = x @ params["w_up"].astype(x.dtype)
        return act(g) * u
    h = x @ params["w_in"].astype(x.dtype)
    return jax.nn.gelu(h)


def ffn_out(params, h, ffn_type: str):
    w = params["w_down"] if ffn_type in ("swiglu", "geglu") else params["w_out"]
    return h @ w.astype(h.dtype)


def apply_ffn(params, x, ffn_type: str):
    return ffn_out(params, ffn_hidden(params, x, ffn_type), ffn_type)
