"""Mamba2 SSD (state-space duality) mixer — chunked scan, pure JAX/XLA path.

Implements the SSD algorithm of arXiv:2405.21060: within-chunk outputs via
masked (decay-weighted) matmuls — the "duality" with attention, which is what
makes this MXU-friendly — plus a sequential inter-chunk recurrence carrying
the (H, P, N) state.  A Pallas kernel for the intra-chunk compute lives in
``kernels/ssd_scan.py``; this module is the XLA oracle path used by the
dry-run and smoke tests (``impl="xla"``).

Block layout (mamba_ssm reference):
  in_proj -> [z (d_inner) | xBC (d_inner + 2·G·N) | dt (H)]
  causal depthwise conv over xBC, silu
  SSD(x, dt, A, B, C) + D·x
  gated RMSNorm: norm(y * silu(z))
  out_proj -> d_model
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, apply_rmsnorm


class SSMState(NamedTuple):
    ssm: jnp.ndarray  # (B, H, P, N)
    conv: jnp.ndarray  # (B, W-1, conv_channels)


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_inner = cfg.ssm_d_inner
    H = cfg.ssm_n_heads
    G, N, W = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_conv_width
    d_in_proj = 2 * d_inner + 2 * G * N + H
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    dt = np.exp(np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), H))
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(k1, d, d_in_proj, dtype),
        "conv_kernel": (jax.random.normal(k2, (W, conv_channels(cfg))) / np.sqrt(W)).astype(dtype),
        "conv_bias": jnp.zeros((conv_channels(cfg),), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.asarray(dt_bias, dtype),
        "norm": init_rmsnorm(d_inner, dtype),
        "out_proj": dense_init(k3, d_inner, d, dtype),
    }


# ---------------------------------------------------------------------------
# chunked SSD scan (sequence mode)
# ---------------------------------------------------------------------------

def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) — post-softplus, positive
    A: jnp.ndarray,  # (H,) — negative
    Bm: jnp.ndarray,  # (B, S, H, N) — already broadcast G->H
    Cm: jnp.ndarray,  # (B, S, H, N)
    *,
    chunk: int,
    D: Optional[jnp.ndarray] = None,  # (H,)
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    impl: str = "xla",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    L = chunk if S % chunk == 0 else S
    nc = S // L
    dtype = x.dtype

    a = (dt * A).astype(jnp.float32)  # (B, S, H), <= 0

    def c(t, tail_shape):
        return t.reshape((B_, nc, L) + tail_shape)

    x_c = c(x, (H, P))
    a_c = c(a, (H,))
    dt_c = c(dt.astype(jnp.float32), (H,))
    B_c = c(Bm, (H, N))
    C_c = c(Cm, (H, N))

    state0 = (jnp.zeros((B_, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        return kops.ssd_scan(x, dt, A, Bm, Cm, chunk=L, D=D,
                             init_state=init_state,
                             interpret=(impl == "pallas_interpret"))

    def body(carry, inp):
        xc, ac, dtc, Bc, Cc = inp  # leading axis B_
        A_cum = jnp.cumsum(ac, axis=1)  # (B, L, H)
        a_sum = A_cum[:, -1, :]  # (B, H)
        decay_out = jnp.exp(A_cum)  # (B, L, H)
        decay_end = jnp.exp(a_sum[:, None, :] - A_cum)  # (B, L, H)

        # intra-chunk (the "dual" attention-like term)
        CB = jnp.einsum("blhn,bmhn->blmh", Cc, Bc, preferred_element_type=jnp.float32)
        seg = A_cum[:, :, None, :] - A_cum[:, None, :, :]  # (B, L, M, H)
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        kern = jnp.where(mask, jnp.exp(seg), 0.0) * CB * dtc[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhp->blhp", kern, xc.astype(jnp.float32))

        # inter-chunk (state entering this chunk)
        y_inter = jnp.einsum("blhn,bhpn->blhp", Cc.astype(jnp.float32), carry)
        y_inter = y_inter * decay_out[..., None]

        # state update
        dBx = jnp.einsum("blh,blh,blhn,blhp->bhpn", decay_end, dtc,
                         Bc.astype(jnp.float32), xc.astype(jnp.float32))
        new_state = carry * jnp.exp(a_sum)[:, :, None, None] + dBx

        return new_state, (y_intra + y_inter)

    # scan over chunks (chunk axis must lead)
    xs = tuple(t.transpose(1, 0, *range(2, t.ndim)) for t in (x_c, a_c, dt_c, B_c, C_c))
    final_state, ys = jax.lax.scan(body, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, P)
    if D is not None:
        y = y + D[None, None, :, None].astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(dtype), final_state


def ssd_step(
    x: jnp.ndarray,  # (B, H, P)
    dt: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, H, N)
    Cm: jnp.ndarray,  # (B, H, N)
    state: jnp.ndarray,  # (B, H, P, N) fp32
    D: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step. Returns (y (B,H,P), new_state)."""
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32 * A)[:, :, None, None]  # (B, H, 1, 1)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt32, Bm.astype(jnp.float32),
                     x.astype(jnp.float32))
    new_state = state * decay + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    if D is not None:
        y = y + D[None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# full mixer (projections + conv + SSD + gate + norm)
# ---------------------------------------------------------------------------

def _split_in_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_inner = cfg.ssm_d_inner
    gn = cfg.ssm_n_groups * cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xBC, dt


def _broadcast_groups(t: jnp.ndarray, cfg: ModelConfig):
    """(…, G, N) -> (…, H, N) by repeating each group across its heads."""
    H, G = cfg.ssm_n_heads, cfg.ssm_n_groups
    reps = H // G
    return jnp.repeat(t, reps, axis=-2)


def apply_mamba2_seq(
    params, x: jnp.ndarray, cfg: ModelConfig, *,
    init_state: Optional[SSMState] = None, return_state: bool = False,
    impl: str = "xla",
):
    """Sequence mode (train / prefill). x (B,S,d) -> (B,S,d) [, SSMState]."""
    B, S, d = x.shape
    H, P, N, G, W = (cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.ssm_n_groups, cfg.ssm_conv_width)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)

    # causal depthwise conv over xBC
    kern = params["conv_kernel"].astype(jnp.float32)  # (W, C)
    if init_state is not None:
        xBC_in = jnp.concatenate([init_state.conv.astype(xBC.dtype), xBC], axis=1)
    else:
        xBC_in = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    conv_tail = xBC_in[:, -(W - 1):, :] if W > 1 else xBC_in[:, :0, :]
    xBC32 = xBC_in.astype(jnp.float32)
    conv = sum(xBC32[:, i:i + S, :] * kern[i][None, None, :] for i in range(W))
    xBC = jax.nn.silu(conv + params["conv_bias"].astype(jnp.float32)).astype(x.dtype)

    x_ssm, B_in, C_in = jnp.split(
        xBC, [cfg.ssm_d_inner, cfg.ssm_d_inner + G * N], axis=-1)
    x_h = x_ssm.reshape(B, S, H, P)
    Bm = _broadcast_groups(B_in.reshape(B, S, G, N), cfg)
    Cm = _broadcast_groups(C_in.reshape(B, S, G, N), cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, fin = ssd_chunked(x_h, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                         D=params["D"].astype(jnp.float32),
                         init_state=None if init_state is None else init_state.ssm,
                         impl=impl)
    y = y.reshape(B, S, cfg.ssm_d_inner)
    y = apply_rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(y.dtype)
    if return_state:
        return out, SSMState(ssm=fin, conv=conv_tail.astype(jnp.float32))
    return out


def apply_mamba2_step(params, x: jnp.ndarray, cfg: ModelConfig, state: SSMState,
                      ) -> Tuple[jnp.ndarray, SSMState]:
    """Decode mode: x (B, d) one token -> (B, d), new state."""
    B, d = x.shape
    H, P, N, G, W = (cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.ssm_n_groups, cfg.ssm_conv_width)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)

    window = jnp.concatenate([state.conv, xBC.astype(jnp.float32)[:, None, :]], axis=1)
    kern = params["conv_kernel"].astype(jnp.float32)
    conv = jnp.einsum("bwc,wc->bc", window, kern) + params["conv_bias"].astype(jnp.float32)
    new_conv = window[:, 1:, :]
    xBC = jax.nn.silu(conv).astype(x.dtype)

    x_ssm, B_in, C_in = jnp.split(
        xBC, [cfg.ssm_d_inner, cfg.ssm_d_inner + G * N], axis=-1)
    x_h = x_ssm.reshape(B, H, P)
    Bm = _broadcast_groups(B_in.reshape(B, G, N), cfg)
    Cm = _broadcast_groups(C_in.reshape(B, G, N), cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, new_ssm = ssd_step(x_h, dt, A, Bm, Cm, state.ssm,
                          D=params["D"].astype(jnp.float32))
    y = y.reshape(B, cfg.ssm_d_inner)
    y = apply_rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(y.dtype)
    return out, SSMState(ssm=new_ssm, conv=new_conv)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    return SSMState(
        ssm=jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_channels(cfg)), jnp.float32),
    )
