"""Transformer stacks for every assigned family, in all paper block styles.

Block styles (paper mapping):
  standard         pre-norm residual blocks (public-literature baseline)
  skipless         Fig 1(a): no skips / no norms, full Q,K,V,P
  skipless_merged  Fig 1(b): Q and P removed.  Serial layout is the paper's
                   exact rewrite (see core/merge.py); parallel layout is the
                   paper's Fig 3(a) architecture.
  residual_qpfree  Fig 4: Q/P-free blocks *with* norms and skips (paper §5)

Layer kinds: "attn" (self-attn + FFN/MoE), "cross" (vlm cross-attn + FFN),
"ssm" (mamba2 mixer), "hybrid" (attn ∥ ssm heads + FFN).

All stacks scan over layer-stacked params so the lowered HLO is O(1) in
depth (required for tractable 512-device compiles; also the production
choice). The VLM interleave (cross-attn every Nth layer) scans over
"super-blocks" of (N-1) self layers + 1 cross layer.

Modes:
  forward_train / forward_encode : full-sequence, returns logits (+aux)
  forward_prefill                : full-sequence prefill DISPATCHER — the
                                   destination (``DensePrefillDest`` /
                                   ``PagedPrefillDest``) picks the
                                   cache_kind axis and the config picks
                                   the style axis (``prefill_style_key``)
                                   of the ``models.backends`` PREFILL
                                   registry; merged qp layouts run the
                                   stream-as-query flash core end to end
  forward_step                   : one token vs either serving cache
                                   (serve_step body); the per-layer
                                   attention route is looked up in the
                                   ``models.backends`` registry keyed on
                                   (cache_kind, style, impl)

Both serving dispatchers validate at the boundary (ValueError — survives
``python -O``, unlike the asserts they replaced) and fail unknown
registry combos with KeyError before any compute.  ``forward_decode`` /
``forward_decode_paged`` remain as deprecated shims over ``forward_step``;
``forward_prefill``'s old ``cache_len=``/``pages=`` mega-signature remains
as a deprecated shim over the ``dest=`` dispatch.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import paging
from repro.kernels import quant
from repro.models import attention as attn_mod
from repro.models import backends
from repro.models import ffn as ffn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import (
    apply_conv_pos,
    apply_embedding,
    apply_rmsnorm,
    apply_rope,
    apply_unembedding,
    dense_init,
    dtype_of,
    init_conv_pos,
    init_embedding,
    init_rmsnorm,
    orthogonal_init,
)


def _init_fn_for(cfg: ModelConfig):
    """Orthogonal init for skipless styles (norm-preserving, cond(Q)≈1 so
    the merged runtime is numerically clean); lecun-normal otherwise."""
    if cfg.init_style == "orthogonal":
        return orthogonal_init
    if cfg.init_style == "normal":
        return dense_init
    return (orthogonal_init if cfg.block_style in ("skipless", "skipless_merged")
            else dense_init)

# ---------------------------------------------------------------------------
# layer kind layout per config
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> Dict[str, Any]:
    """Describes how layers are stacked/scanned for this config."""
    if cfg.family == "ssm":
        return {"kind": "ssm", "n": cfg.n_layers}
    if cfg.family == "hybrid":
        return {"kind": "hybrid", "n": cfg.n_layers}
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        assert cfg.n_layers % per == 0
        return {"kind": "vlm", "n_groups": cfg.n_layers // per, "self_per_group": per - 1}
    return {"kind": "attn", "n": cfg.n_layers}


# ---------------------------------------------------------------------------
# per-layer param init
# ---------------------------------------------------------------------------

def _init_attn_proj(key, cfg: ModelConfig, dtype, merged: bool, cross: bool):
    """Q/K/V/P params for one attention sub-module.

    Merged styles omit the eliminated pair per ``cfg.merged_variant``
    (paper Table 1): "qp" drops wq+wp, "kp" drops wk+wp, "vp" drops wv+wp.
    Cross-attention always keeps wk/wv (they read the vision tokens, which
    are not in the rotated stream basis) — only "qp" is legal for cross.
    """
    d, ad, kd = cfg.d_model, cfg.attn_dim, cfg.kv_dim
    init_fn = _init_fn_for(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    variant = cfg.merged_variant if merged else ""
    if variant and cross and variant != "qp":
        raise ValueError("cross-attention supports only the qp merged variant")
    if variant != "qp":
        p["wq"] = init_fn(ks[0], d, ad, dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((ad,), dtype)
    if variant != "kp":
        p["wk"] = init_fn(ks[1], d, kd, dtype)
        if cfg.qkv_bias:
            p["bk"] = jnp.zeros((kd,), dtype)
    if variant != "vp":
        p["wv"] = init_fn(ks[2], d, kd, dtype)
        if cfg.qkv_bias:
            p["bv"] = jnp.zeros((kd,), dtype)
    if not merged:
        p["wp"] = init_fn(ks[3], ad, d, dtype)
    return p


def _needs_norms(style: str) -> bool:
    return style in ("standard", "residual_qpfree")


def _is_merged(style: str) -> bool:
    return style in ("skipless_merged", "residual_qpfree")


def init_block(key, cfg: ModelConfig, kind: str, dtype) -> Dict[str, Any]:
    style = cfg.block_style
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    merged = _is_merged(style)

    if kind in ("attn", "cross", "hybrid"):
        p["attn"] = _init_attn_proj(ks[0], cfg, dtype, merged, cross=(kind == "cross"))
    if kind == "hybrid":
        # hybrid merged style removes Q only (P must stay: FFN input is the
        # fused attn+ssm stream — see DESIGN.md §5), so keep wp always.
        if merged and "wp" not in p["attn"]:
            p["attn"]["wp"] = dense_init(ks[5], cfg.attn_dim, cfg.d_model, dtype)
        p["ssm"] = m2.init_mamba2(ks[1], cfg, dtype)
    if kind == "ssm":
        p["ssm"] = m2.init_mamba2(ks[1], cfg, dtype)

    if cfg.has_ffn and kind != "ssm":
        # merged serial dense/moe/vlm: FFN input dim is attn_dim (P folded in)
        ffn_in = cfg.attn_dim if (merged and not cfg.parallel_block and kind != "hybrid") else cfg.d_model
        if cfg.n_experts and kind == "attn":
            p["moe"] = moe_mod.init_moe(ks[2], ffn_in, cfg.d_ff, cfg.d_model,
                                        cfg.n_experts, cfg.ffn_type, dtype)
        else:
            p["ffn"] = ffn_mod.init_ffn(ks[2], ffn_in, cfg.d_ff, cfg.d_model,
                                        cfg.ffn_type, dtype,
                                        init_fn=_init_fn_for(cfg),
                                        out_gain=cfg.ffn_out_gain)

    if _needs_norms(style):
        p["norm1"] = init_rmsnorm(cfg.d_model, dtype)
        if cfg.has_ffn and kind != "ssm":
            p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params["embed"] = init_embedding(keys[0], cfg.padded_vocab, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[1], cfg.padded_vocab, cfg.d_model, dtype)

    def stack_layers(k, n, kind):
        lk = jax.random.split(k, n)
        layers = [init_block(lki, cfg, kind, dtype) for lki in lk]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    if plan["kind"] == "vlm":
        ng, spg = plan["n_groups"], plan["self_per_group"]
        sk = jax.random.split(keys[2], ng)
        groups = [stack_layers(ski, spg, "attn") for ski in sk]
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)  # (ng, spg, …)
        params["cross_layers"] = stack_layers(keys[3], ng, "cross")  # (ng, …)
    else:
        params["layers"] = stack_layers(keys[2], plan["n"], plan["kind"])

    if _needs_norms(cfg.block_style):
        params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if cfg.conv_pos_width:
        params["conv_pos"] = init_conv_pos(keys[4], cfg.d_model, cfg.conv_pos_width, dtype)
    return params


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# attention sub-module apply (projections + rope + core)
# ---------------------------------------------------------------------------

def _project_qkv(lp, cfg: ModelConfig, u, kv_src, merged: bool):
    """u: (B,S,d) query-side stream; kv_src: (B,Sk,d) key/value source.

    In merged styles the projection named by ``cfg.merged_variant`` is the
    identity: the stream is already in that projection's output basis
    (paper Fig 2b/c/d).
    """
    ad, kd, Dh = cfg.attn_dim, cfg.kv_dim, cfg.d_head
    variant = cfg.merged_variant if merged else ""

    def proj(name, src):
        y = src @ lp["w" + name].astype(u.dtype)
        if "b" + name in lp:
            y = y + lp["b" + name].astype(u.dtype)
        return y

    q = u if variant == "qp" else proj("q", u)
    k = kv_src if variant == "kp" else proj("k", kv_src)
    v = kv_src if variant == "vp" else proj("v", kv_src)
    B, Sq = u.shape[0], u.shape[1]
    Sk = kv_src.shape[1]
    q = q.reshape(B, Sq, cfg.n_heads, Dh)
    k = k.reshape(B, Sk, cfg.n_kv_heads, Dh)
    v = v.reshape(B, Sk, cfg.n_kv_heads, Dh)
    return q, k, v


def _self_attention_seq(lp, cfg: ModelConfig, u, positions, merged: bool,
                        impl: str, qkv_sharding=None,
                        merged_core: bool = False, cache_kind: str = "dense",
                        q8_block: int = 0, q8_true_len=None):
    """``merged_core`` selects the stream-as-query attention core (merged
    qp layouts only: q below is an identity view of u, so handing it to
    ``attention_core_merged`` keeps every tensor in its native layout —
    the prefill twin of the merged decode fast path).

    ``q8_block`` > 0 (paged_q8 prefill) quantizes K/V at pool granularity
    — int8 per ``q8_block``-token × kv-head block, positions >=
    ``q8_true_len`` masked to zero first — and attends over the QUANTIZED
    view (in-kernel dequant on the merged route, an XLA dequant
    otherwise), so prefill logits see exactly the pool bytes that
    ``_finish_paged_q8`` later stores.  The RAW float K/V is still what's
    collected: the finish path re-quantizes it with the same function and
    mask, landing bit-identical ints + scales in the pool."""
    q, k, v = _project_qkv(lp, cfg, u, u, merged)
    if qkv_sharding is not None:
        # merged styles lose the TP sharding anchor for q (no wq matmul to
        # propagate head-sharding from): without this constraint GSPMD
        # computes attention replicated over the model axis (§Perf)
        q = jax.lax.with_sharding_constraint(q, qkv_sharding)
        k = jax.lax.with_sharding_constraint(k, qkv_sharding)
        v = jax.lax.with_sharding_constraint(v, qkv_sharding)
    q = apply_rope(q, positions, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    B, S = u.shape[0], u.shape[1]
    if q8_block:
        valid = None if q8_true_len is None else \
            (positions < q8_true_len[:, None])
        kq, ksc = quant.q8_quantize_seq(k, q8_block, valid)
        vq, vsc = quant.q8_quantize_seq(v, q8_block, valid)
        if merged_core:
            out = attn_mod.attention_core_merged(
                q.reshape(B, S, cfg.attn_dim), kq, vq,
                q_positions=positions, kv_positions=positions,
                n_kv_heads=cfg.n_kv_heads, causal=cfg.causal,
                sliding_window=cfg.sliding_window, impl=impl,
                query_chunk=cfg.query_chunk or S, cache_kind=cache_kind,
                k_scale=ksc, v_scale=vsc)
            return out, (k, v)
        kd = quant.q8_dequant_seq(kq, ksc, k.dtype)
        vd = quant.q8_dequant_seq(vq, vsc, v.dtype)
        out = attn_mod.attention_core(
            q, kd, vd, q_positions=positions, kv_positions=positions,
            causal=cfg.causal, sliding_window=cfg.sliding_window, impl=impl,
            query_chunk=cfg.query_chunk or q.shape[1])
        return out.reshape(B, S, cfg.attn_dim), (k, v)
    if merged_core:
        out = attn_mod.attention_core_merged(
            q.reshape(B, S, cfg.attn_dim), k, v,
            q_positions=positions, kv_positions=positions,
            n_kv_heads=cfg.n_kv_heads, causal=cfg.causal,
            sliding_window=cfg.sliding_window, impl=impl,
            query_chunk=cfg.query_chunk or S, cache_kind=cache_kind)
        return out, (k, v)
    out = attn_mod.attention_core(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=cfg.causal, sliding_window=cfg.sliding_window, impl=impl,
        query_chunk=cfg.query_chunk or q.shape[1])
    return out.reshape(B, S, cfg.attn_dim), (k, v)


def _cross_attention_seq(lp, cfg: ModelConfig, u, vision, merged: bool, impl: str):
    """Cross-attn: queries from text stream, K/V from vision tokens (no rope)."""
    q, k, v = _project_qkv(lp, cfg, u, vision, merged)
    B, S = u.shape[0], u.shape[1]
    nv = vision.shape[1]
    qpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    kpos = jnp.broadcast_to(jnp.arange(nv, dtype=jnp.int32), (B, nv))
    out = attn_mod.attention_core(q, k, v, q_positions=qpos, kv_positions=kpos,
                                  causal=False, sliding_window=0, impl=impl,
                                  query_chunk=cfg.query_chunk or q.shape[1])
    return out.reshape(B, S, cfg.attn_dim), (k, v)


def _attn_out_proj(lp, cat):
    return cat @ lp["wp"].astype(cat.dtype)


# ---------------------------------------------------------------------------
# FFN dispatch (dense or MoE)
# ---------------------------------------------------------------------------

def _apply_ffn_or_moe(p, cfg: ModelConfig, x, dropless: bool = False):
    """Returns (out, aux_loss)."""
    if "moe" in p:
        out, aux = moe_mod.apply_moe(
            p["moe"], x, n_experts=cfg.n_experts,
            experts_per_token=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor, ffn_type=cfg.ffn_type,
            dropless=dropless, impl=cfg.moe_impl,
            group_size=cfg.moe_group or x.shape[0] * x.shape[1])
        return out, aux
    return ffn_mod.apply_ffn(p["ffn"], x, cfg.ffn_type), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# one block, sequence mode
# ---------------------------------------------------------------------------

def apply_block_seq(p, cfg: ModelConfig, kind: str, u, ctx) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Returns (out_stream, aux_loss, kv_for_cache)."""
    style = cfg.block_style
    merged = _is_merged(style)
    impl = ctx.get("impl", "xla")
    positions = ctx["positions"]
    aux = jnp.float32(0.0)
    kv = None

    if kind == "ssm":
        if style == "standard":
            out = u + m2.apply_mamba2_seq(p["ssm"], apply_rmsnorm(p["norm1"], u), cfg, impl=impl) \
                if "norm1" in p else u + m2.apply_mamba2_seq(p["ssm"], u, cfg, impl=impl)
        else:  # skipless ssm (no paper technique applicable)
            out = m2.apply_mamba2_seq(p["ssm"], u, cfg, impl=impl)
        return out, aux, None

    def attn_fn(x):
        nonlocal kv
        if kind == "cross":
            cat, kv_ = _cross_attention_seq(p["attn"], cfg, x, ctx["vision"], merged, impl)
        else:
            cat, kv_ = _self_attention_seq(
                p["attn"], cfg, x, positions, merged, impl,
                qkv_sharding=ctx.get("qkv_sharding"),
                merged_core=ctx.get("merged_core", False),
                cache_kind=ctx.get("cache_kind", "dense"),
                q8_block=ctx.get("q8_block", 0),
                q8_true_len=ctx.get("q8_true_len"))
        kv = kv_
        return cat

    def mixer_fn(x):
        """kind-specific token mixer producing a d_model stream delta."""
        cat = attn_fn(x)
        if kind == "hybrid":
            a = _attn_out_proj(p["attn"], cat)
            s = m2.apply_mamba2_seq(p["ssm"], x, cfg, impl=impl)
            return 0.5 * (a + s)
        if merged:
            return cat  # no P; FFN input matrices carry the P fold
        return _attn_out_proj(p["attn"], cat)

    if style == "standard":
        if cfg.parallel_block:
            n = apply_rmsnorm(p["norm1"], u)
            f, aux = _apply_ffn_or_moe(p, cfg, n)
            out = u + mixer_fn(n) + f
        else:
            h = u + mixer_fn(apply_rmsnorm(p["norm1"], u))
            f, aux = _apply_ffn_or_moe(p, cfg, apply_rmsnorm(p["norm2"], h))
            out = h + f
    elif style == "residual_qpfree":
        if cfg.parallel_block:
            n = apply_rmsnorm(p["norm1"], u)
            f, aux = _apply_ffn_or_moe(p, cfg, n)
            out = u + mixer_fn(n) + f
        else:
            h = u + mixer_fn(apply_rmsnorm(p["norm1"], u))
            f, aux = _apply_ffn_or_moe(p, cfg, apply_rmsnorm(p["norm2"], h))
            out = h + f
    elif style == "skipless":
        if cfg.parallel_block:
            f, aux = _apply_ffn_or_moe(p, cfg, u)
            out = mixer_fn(u) + f
        else:
            mid = mixer_fn(u)
            out, aux = _apply_ffn_or_moe(p, cfg, mid)
    elif style == "skipless_merged":
        if cfg.parallel_block:
            f, aux = _apply_ffn_or_moe(p, cfg, u)
            out = mixer_fn(u) + f  # Fig 3(a): cat adds directly (no P)
        else:
            mid = mixer_fn(u)  # = cat for dense/moe/vlm; fused for hybrid
            out, aux = _apply_ffn_or_moe(p, cfg, mid)
        if "b_out" in p:  # folded b_q of the NEXT block (affine merge)
            out = out + p["b_out"].astype(out.dtype)
    else:
        raise ValueError(style)

    return out, aux, kv


# ---------------------------------------------------------------------------
# full-sequence forward (train / encode / prefill)
# ---------------------------------------------------------------------------

def _scan_blocks_seq(params, cfg: ModelConfig, h, ctx, collect_kv: bool,
                     remat: bool = False, unroll: bool = False):
    plan = layer_plan(cfg)
    aux0 = jnp.float32(0.0)
    u = True if unroll else 1

    def block_fn(kind):
        def f(carry, lp):
            h, aux = carry
            out, a, kv = apply_block_seq(lp, cfg, kind, h, ctx)
            if ctx.get("stream_sharding") is not None:
                # sequence parallelism on the layer-boundary stream: the
                # saved-for-backward carries shard over (dp, seq-tp) instead
                # of being replicated across the model axis (§Perf H6)
                out = jax.lax.with_sharding_constraint(
                    out, ctx["stream_sharding"])
            return (out, aux + a), (kv if collect_kv else None)
        if remat == "dots":
            # partial remat: keep matmul outputs, recompute the cheap
            # elementwise/softmax glue — trades some of full-remat's
            # recompute FLOPs for modest extra saved bytes (§Perf H7b)
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.dots_saveable,
                prevent_cse=False)
        if remat:
            return jax.checkpoint(f, prevent_cse=False)
        return f

    if plan["kind"] == "vlm":
        def group_fn(carry, gp):
            (h, aux) = carry
            (h, aux), kvs_self = jax.lax.scan(block_fn("attn"), (h, aux),
                                              gp["self"], unroll=u)
            (h, aux), kv_cross = block_fn("cross")((h, aux), gp["cross"])
            return (h, aux), (kvs_self, kv_cross)
        gparams = {"self": params["layers"], "cross": params["cross_layers"]}
        (h, aux), kvs = jax.lax.scan(group_fn, (h, aux0), gparams, unroll=u)
        return h, aux, kvs
    else:
        (h, aux), kvs = jax.lax.scan(block_fn(plan["kind"]), (h, aux0),
                                     params["layers"], unroll=u)
        return h, aux, kvs


def embed_inputs(params, cfg: ModelConfig, tokens_or_frames):
    cdt = dtype_of(cfg.dtype)
    if tokens_or_frames.dtype in (jnp.int32, jnp.int64):
        h = apply_embedding(params["embed"], tokens_or_frames, cdt)
        if cfg.block_style in ("skipless", "skipless_merged"):
            # skipless stacks have no residual to carry scale, and GLU FFNs
            # attenuate sub-unit signals quadratically (silu(g)·u ~ 0.5·s²),
            # so 0.02-std embeddings collapse to zero logits and zero grads.
            # Scale the embedding output to the GLU fixed point (std ≈ 2,
            # where silu(g)·u sustains its input scale); He et al. use
            # comparable signal-preserving inits for skipless nets.
            h = h * (2.0 / 0.02)
    else:
        h = tokens_or_frames.astype(cdt)  # stubbed modality frontend output
    if "conv_pos" in params:
        h = apply_conv_pos(params["conv_pos"], h)
    # merged models: frame inputs can't fold Q_0 into an embedding table, so
    # the merge keeps Q_0 as an explicit input projection (see core/merge.py)
    if "input_proj" in params:
        h = h @ params["input_proj"].astype(h.dtype)
    if "embed_bias" in params:  # folded b_q of the first block (affine merge)
        h = h + params["embed_bias"].astype(h.dtype)
    return h


def forward_seq(params, cfg: ModelConfig, inputs, *, positions=None,
                vision=None, impl: str = "xla", remat: bool = False,
                collect_kv: bool = False, unroll: bool = False,
                stream_sharding=None, qkv_sharding=None,
                merged_core: bool = False, cache_kind: str = "dense",
                q8_block: int = 0, q8_true_len=None):
    """Full-sequence forward. inputs: int tokens (B,S) or frames (B,S,d).

    ``merged_core`` routes self-attention through the stream-as-query
    merged core (prefill backends set it for merged qp layouts);
    ``cache_kind`` tags which prefill kernel-table row the core fetches.
    ``q8_block``/``q8_true_len`` (paged_q8 prefill) make every self-
    attention layer attend over the pool-granularity QUANTIZED K/V view
    (``_self_attention_seq``) while collecting the raw floats.
    """
    B, S = inputs.shape[0], inputs.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = embed_inputs(params, cfg, inputs)
    ctx = {"positions": positions, "vision": None if vision is None else
           vision.astype(h.dtype), "impl": impl,
           "stream_sharding": stream_sharding, "qkv_sharding": qkv_sharding,
           "merged_core": merged_core, "cache_kind": cache_kind,
           "q8_block": q8_block, "q8_true_len": q8_true_len}
    h, aux, kvs = _scan_blocks_seq(params, cfg, h, ctx, collect_kv, remat,
                                   unroll=unroll)
    if "final_norm" in params:
        h = apply_rmsnorm(params["final_norm"], h)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = apply_unembedding(table, h)
    return logits, aux, kvs


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray, *, z_loss: float = 1e-4,
            ignore_index: int = -100, vocab_size: int = 0):
    """Token-mean cross entropy (fp32) + z-loss. labels (B,S) int32.

    ``vocab_size``: logical vocab — logits for padded ids (>= vocab_size)
    are masked out of the softmax (see ModelConfig.padded_vocab)."""
    logits = logits.astype(jnp.float32)
    if vocab_size and vocab_size < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# decode cache
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    k: Optional[jnp.ndarray]  # (L, B, Sc, Hkv, Dh) — Sc = window or max_len
    v: Optional[jnp.ndarray]
    kv_pos: Optional[jnp.ndarray]  # (B, Sc) int32, -1 = empty (shared across layers)
    length: jnp.ndarray  # (B,) int32 — tokens generated so far (= next position)
    ssm: Optional[m2.SSMState]  # stacked (L, …) for ssm/hybrid
    cross_k: Optional[jnp.ndarray]  # (Lc, B, nv, Hkv, Dh)
    cross_v: Optional[jnp.ndarray]


def n_attn_layers(cfg: ModelConfig) -> int:
    """Number of self-attention layers holding per-token KV (the leading
    cache axis of both serving cache kinds)."""
    plan = layer_plan(cfg)
    if plan["kind"] in ("attn", "hybrid"):
        return plan["n"]
    if plan["kind"] == "vlm":
        return plan["n_groups"] * plan["self_per_group"]
    return 0


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Shapes for an empty cache (used by init and by input_specs)."""
    plan = layer_plan(cfg)
    cdt = dtype_of(cfg.dtype)
    Sc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    spec: Dict[str, Any] = {}
    n_attn = n_attn_layers(cfg)
    if n_attn:
        spec["k"] = ((n_attn, batch, Sc, cfg.n_kv_heads, cfg.d_head), cdt)
        spec["v"] = spec["k"]
        spec["kv_pos"] = ((batch, Sc), jnp.int32)
    spec["length"] = ((batch,), jnp.int32)
    if cfg.ssm_state:
        n_ssm = plan["n"]
        spec["ssm"] = {
            "ssm": ((n_ssm, batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": ((n_ssm, batch, cfg.ssm_conv_width - 1, m2.conv_channels(cfg)), jnp.float32),
        }
    if plan["kind"] == "vlm":
        spec["cross_k"] = ((plan["n_groups"], batch, cfg.n_vision_tokens,
                            cfg.n_kv_heads, cfg.d_head), cdt)
        spec["cross_v"] = spec["cross_k"]
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> DecodeCache:
    spec = cache_spec(cfg, batch, max_len)

    def z(name, fill=0):
        if name not in spec:
            return None
        sh, dt = spec[name]
        return jnp.full(sh, fill, dt)

    ssm = None
    if "ssm" in spec:
        ssm = m2.SSMState(
            ssm=jnp.zeros(spec["ssm"]["ssm"][0], jnp.float32),
            conv=jnp.zeros(spec["ssm"]["conv"][0], jnp.float32),
        )
    return DecodeCache(
        k=z("k"), v=z("v"),
        kv_pos=None if "kv_pos" not in spec else jnp.full(spec["kv_pos"][0], -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
        ssm=ssm, cross_k=z("cross_k"), cross_v=z("cross_v"),
    )


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the cache
# ---------------------------------------------------------------------------

def _last_logits_and_length(logits, true_len, B, S):
    """Gather the last REAL position's logits (bucketed prompts are
    right-padded; causality keeps positions < true_len exact)."""
    if true_len is None:
        return logits[:, -1, :], jnp.full((B,), S, jnp.int32)
    true_len = jnp.asarray(true_len, jnp.int32)
    last = jnp.take_along_axis(
        logits, (true_len - 1)[:, None, None], axis=1)[:, 0, :]
    return last, true_len


class DensePrefillDest(NamedTuple):
    """Destination of a dense prefill: build a fresh ``DecodeCache`` of
    ``cache_len`` positions.  ``full_cache`` keeps the cache ``cache_len``
    long even for sliding-window configs (whose dense serving cache is a
    window-sized ring buffer), for callers that need every prompt
    position.  Both fields are STATIC (python ints/bools, resolved at
    trace time)."""
    cache_len: int
    full_cache: bool = False


class PagedPrefillDest(NamedTuple):
    """Destination of a direct-to-page paged prefill: the prompt's KV is
    written straight into the mapped physical blocks of the pool — no
    worst-case-length intermediate cache and no post-prefill scatter pass.

    ``k_pool``/``v_pool`` are (L, NB, bs, Hkv, Dh) page pools;
    ``block_ids`` is (ceil(S/bs),) int32 mapping this request's logical
    (absolute) block j to its physical page, with -1 for blocks that must
    NOT be written (prefix-shared pages already holding the prefix —
    possibly extended by another live request's decoded tokens — bucket-
    padding blocks past the prompt, and, under a sliding window, prompt
    blocks wholly out of every future query's window: a windowed request's
    table is a bounded ring of ceil(window/bs)+1 recycled slots and only
    the live window's blocks are mapped — ``kernels.paging``)."""
    k_pool: Any
    v_pool: Any
    block_ids: Any


class PagedQ8PrefillDest(NamedTuple):
    """Destination of a direct-to-page QUANTIZED paged prefill: the
    ``PagedPrefillDest`` contract over int8 pools — ``k_pool``/``v_pool``
    are (L, NB, bs, Hkv, Dh) int8 pages, ``k_scale``/``v_scale`` their
    (L, NB, Hkv) float32 per-(page, kv-head) scales (``kernels.quant``),
    and ``block_ids`` is the same (ceil(S/bs),) physical mapping with -1
    dropping the write.  The prefill program quantizes the collected
    prompt KV at pool granularity and scatters ints AND scales into the
    mapped pages — a full-precision pool never exists."""
    k_pool: Any
    v_pool: Any
    k_scale: Any
    v_scale: Any
    block_ids: Any


def prefill_style_key(cfg: ModelConfig) -> str:
    """Projection-style axis of the PREFILL backend registry key.

    "merged" iff the whole-prompt forward can run the stream-as-query
    attention core in every self-attention layer (qp variant of the merged
    styles on attention-only stacks: the stream IS the query and no P
    exists, so prefill attention reads only K*/V* weights).  kp/vp merged
    variants stay "generic" — their eliminated projection is an identity
    inside ``_project_qkv`` and no dedicated route exists (exactly as at
    decode).  ssm/hybrid/vlm stacks are "generic" too (hybrid keeps P;
    vlm interleaves cross-attention layers that read vision tokens).
    """
    if layer_plan(cfg)["kind"] != "attn":
        return "generic"
    if cfg.block_style in ("skipless_merged", "residual_qpfree") \
            and cfg.merged_variant == "qp":
        return "merged"
    return "generic"


def _prefill_seq(params, cfg: ModelConfig, inputs, ctx, *,
                 merged_core: bool, cache_kind: str):
    """The shared full-sequence pass every prefill backend starts with."""
    return forward_seq(params, cfg, inputs, vision=ctx.get("vision"),
                       impl=ctx.get("impl", "xla"), collect_kv=True,
                       unroll=ctx.get("unroll", False),
                       qkv_sharding=ctx.get("qkv_sharding"),
                       merged_core=merged_core, cache_kind=cache_kind)


def _finish_paged(cfg: ModelConfig, logits, kvs, dest: PagedPrefillDest, ctx,
                  B: int, S: int):
    """Scatter the collected prompt KV direct-to-page (see
    ``PagedPrefillDest``) and gather the last real position's logits."""
    k_pool, v_pool, block_ids = dest
    last_logits, _ = _last_logits_and_length(logits, ctx.get("true_len"), B, S)
    ks, vs = kvs  # (L, 1, S, Hkv, Dh)
    L, bs, NB = k_pool.shape[0], k_pool.shape[2], k_pool.shape[1]
    nbk = block_ids.shape[0]
    pad = nbk * bs - S
    if pad:
        ks = jnp.pad(ks, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        vs = jnp.pad(vs, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
    kb = ks[:, 0].reshape(L, nbk, bs, *ks.shape[3:])
    vb = vs[:, 0].reshape(L, nbk, bs, *vs.shape[3:])
    # unmapped/-1 destinations are clamped out of range and DROPPED:
    # shared-prefix pages (owned content, maybe another request's
    # decoded tail) and bucket-padding blocks are never touched
    safe = jnp.where(block_ids >= 0, block_ids, NB).astype(jnp.int32)
    k_pool = k_pool.at[:, safe].set(kb.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[:, safe].set(vb.astype(v_pool.dtype), mode="drop")
    return last_logits, (k_pool, v_pool)


def _finish_paged_q8(cfg: ModelConfig, logits, kvs, dest: PagedQ8PrefillDest,
                     ctx, B: int, S: int):
    """Quantize the collected prompt KV at pool granularity and scatter
    ints + scales direct-to-page.  Positions >= true_len are masked to
    zero BEFORE the per-block absmax — the same mask and the same
    ``quant.q8_quantize_pages`` the prefill attention fake-quanted with,
    so the pool bytes are bit-identical to what the prompt's own logits
    already attended over (and padding garbage never inflates a real
    block's scale)."""
    k_pool, v_pool, k_scale, v_scale, block_ids = dest
    last_logits, _ = _last_logits_and_length(logits, ctx.get("true_len"), B, S)
    ks, vs = kvs  # (L, 1, S, Hkv, Dh)
    L, bs, NB = k_pool.shape[0], k_pool.shape[2], k_pool.shape[1]
    nbk = block_ids.shape[0]
    pad = nbk * bs - S
    if pad:
        ks = jnp.pad(ks, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        vs = jnp.pad(vs, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
    true_len = ctx.get("true_len")
    limit = jnp.int32(S) if true_len is None else \
        jnp.asarray(true_len, jnp.int32).reshape(B)[0]
    pos = jnp.arange(nbk * bs, dtype=jnp.int32)
    valid = (pos < limit)[None, None, :, None, None]
    ks = jnp.where(valid, ks.astype(jnp.float32), 0.0)
    vs = jnp.where(valid, vs.astype(jnp.float32), 0.0)
    kb = ks[:, 0].reshape(L, nbk, bs, *ks.shape[3:])
    vb = vs[:, 0].reshape(L, nbk, bs, *vs.shape[3:])
    kq, ksc = quant.q8_quantize_pages(kb)  # ints (L,nbk,bs,Hkv,Dh), (L,nbk,Hkv)
    vq, vsc = quant.q8_quantize_pages(vb)
    # same drop-scatter as _finish_paged, extended to the scale rows:
    # a page and its scale move as one unit
    safe = jnp.where(block_ids >= 0, block_ids, NB).astype(jnp.int32)
    k_pool = k_pool.at[:, safe].set(kq, mode="drop")
    v_pool = v_pool.at[:, safe].set(vq, mode="drop")
    k_scale = k_scale.at[:, safe].set(ksc, mode="drop")
    v_scale = v_scale.at[:, safe].set(vsc, mode="drop")
    return last_logits, (k_pool, v_pool, k_scale, v_scale)


def _finish_dense(params, cfg: ModelConfig, inputs, logits, kvs,
                  dest: DensePrefillDest, ctx, B: int, S: int):
    """Place the collected prompt KV into a fresh ``DecodeCache`` (ring-
    phased under a sliding window) and gather the last real logits."""
    true_len = ctx.get("true_len")
    cache_cfg = cfg.with_(sliding_window=0) if dest.full_cache else cfg
    cache = init_cache(cache_cfg, B, dest.cache_len)
    Sc = cache.k.shape[2] if cache.k is not None else 0

    def place(kv_stacked):
        # kv_stacked: (L, B, S, Hkv, Dh) -> keep the last Sc positions,
        # ROLLED into ring phase: decode writes position p at slot p % Sc,
        # so position S-Sc+i must land at index (S-Sc+i) % Sc — without the
        # roll, decode after a longer-than-window prompt overwrites live
        # in-window entries instead of the expired ones.
        if S >= Sc:
            kept = kv_stacked[:, :, S - Sc:, :, :]
            shift = (S - Sc) % Sc
            return jnp.roll(kept, shift, axis=2) if shift else kept
        pad = [(0, 0), (0, 0), (0, Sc - S), (0, 0), (0, 0)]
        return jnp.pad(kv_stacked, pad)

    last_logits, length = _last_logits_and_length(logits, true_len, B, S)
    if true_len is not None:
        true_len = length  # normalized int32 view for the kv_pos mask below
    new = cache._replace(length=length)
    plan = layer_plan(cfg)
    if plan["kind"] == "vlm":
        kv_self, kv_cross = kvs  # ((ng, spg, B,S,H,D)×2, (ng, B,nv,H,D)×2)
        ks, vs = kv_self
        ng, spg = ks.shape[0], ks.shape[1]
        ks = ks.reshape(ng * spg, *ks.shape[2:])
        vs = vs.reshape(ng * spg, *vs.shape[2:])
        new = new._replace(k=place(ks), v=place(vs),
                           cross_k=kv_cross[0], cross_v=kv_cross[1])
    elif cfg.has_attention:
        ks, vs = kvs
        new = new._replace(k=place(ks), v=place(vs))
    if new.kv_pos is not None:
        pos = jnp.arange(Sc, dtype=jnp.int32)[None, :] + max(S - Sc, 0)
        valid = pos < (S if true_len is None else true_len[:, None])
        kvp = jnp.where(valid, pos, -1).astype(jnp.int32) * \
            jnp.ones((B, 1), jnp.int32)
        if S >= Sc and (S - Sc) % Sc:  # match place()'s ring phase
            kvp = jnp.roll(kvp, (S - Sc) % Sc, axis=1)
        new = new._replace(kv_pos=kvp)
    if cfg.ssm_state:
        # re-run mamba path collecting final states (cheap relative to attn).
        # NOTE: SSM state is not position-masked, so bucketed (padded)
        # prompts are unsupported here — the engine disables bucketing for
        # ssm/hybrid families.
        ssm = _prefill_ssm_states(params, cfg, inputs, ctx.get("vision"),
                                  ctx.get("impl", "xla"),
                                  ctx.get("unroll", False))
        new = new._replace(ssm=ssm)
    return last_logits, new


# --- the four registered prefill routes --------------------------------------

def _prefill_dense_generic(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("dense", "generic"): projects q/k/v as
    the config dictates (kp/vp merged variants pass through — their
    eliminated projection is an identity) and fills a ``DecodeCache``.
    Covers every family (attn/ssm/hybrid/vlm)."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq(params, cfg, inputs, ctx,
                                  merged_core=False, cache_kind="dense")
    return _finish_dense(params, cfg, inputs, logits, kvs, dest, ctx, B, S)


def _prefill_dense_merged(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("dense", "merged"): the Q/P-removed
    PREFILL fast path — every self-attention layer runs the stream-as-
    query core (``attention_core_merged`` / the merged flash kernel), so
    the whole-prompt forward reads no Q or P weights and moves no
    head-major transposes; the filled cache is byte-identical in layout
    to the generic backend's."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq(params, cfg, inputs, ctx,
                                  merged_core=True, cache_kind="dense")
    return _finish_dense(params, cfg, inputs, logits, kvs, dest, ctx, B, S)


def _prefill_paged_generic(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("paged", "generic"): generic projection
    path, prompt KV written DIRECT-TO-PAGE into the mapped pool blocks."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq(params, cfg, inputs, ctx,
                                  merged_core=False, cache_kind="paged")
    return _finish_paged(cfg, logits, kvs, dest, ctx, B, S)


def _prefill_paged_merged(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("paged", "merged"): stream-as-query
    attention AND direct-to-page KV writes — prefill HBM traffic is the
    prompt's own pages plus K*/V*-only weight reads."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq(params, cfg, inputs, ctx,
                                  merged_core=True, cache_kind="paged")
    return _finish_paged(cfg, logits, kvs, dest, ctx, B, S)


def _prefill_seq_q8(params, cfg: ModelConfig, inputs,
                    dest: PagedQ8PrefillDest, ctx, *, merged_core: bool):
    """Quantized-pool variant of ``_prefill_seq``: thread the pool's
    block size + the prompt's true length into the stack so every layer
    fake-quants its K/V at pool granularity (``_self_attention_seq``)."""
    true_len = ctx.get("true_len")
    q8_true_len = None if true_len is None else \
        jnp.asarray(true_len, jnp.int32).reshape(inputs.shape[0])
    return forward_seq(params, cfg, inputs, vision=ctx.get("vision"),
                       impl=ctx.get("impl", "xla"), collect_kv=True,
                       unroll=ctx.get("unroll", False),
                       qkv_sharding=ctx.get("qkv_sharding"),
                       merged_core=merged_core, cache_kind="paged_q8",
                       q8_block=int(dest.k_pool.shape[2]),
                       q8_true_len=q8_true_len)


def _prefill_paged_q8_generic(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("paged_q8", "generic"): generic
    projection path attending over the quantized K/V view, prompt KV
    quantized and written direct-to-page as int8 + scales."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq_q8(params, cfg, inputs, dest, ctx,
                                     merged_core=False)
    return _finish_paged_q8(cfg, logits, kvs, dest, ctx, B, S)


def _prefill_paged_q8_merged(params, cfg: ModelConfig, inputs, dest, ctx):
    """Registered prefill backend ("paged_q8", "merged"): stream-as-query
    attention with IN-KERNEL dequant (the q8 merged flash kernel) AND
    int8 direct-to-page writes — prefill attention streams one byte per
    pooled element."""
    B, S = inputs.shape[0], inputs.shape[1]
    logits, _, kvs = _prefill_seq_q8(params, cfg, inputs, dest, ctx,
                                     merged_core=True)
    return _finish_paged_q8(cfg, logits, kvs, dest, ctx, B, S)


backends.register_prefill_backend("dense", "generic", _prefill_dense_generic)
backends.register_prefill_backend("dense", "merged", _prefill_dense_merged,
                                  fast_path=True)
backends.register_prefill_backend("paged", "generic", _prefill_paged_generic)
backends.register_prefill_backend("paged", "merged", _prefill_paged_merged,
                                  fast_path=True)
backends.register_prefill_backend("paged_q8", "generic",
                                  _prefill_paged_q8_generic)
backends.register_prefill_backend("paged_q8", "merged",
                                  _prefill_paged_q8_merged, fast_path=True)


def forward_prefill(params, cfg: ModelConfig, inputs, dest=None, *,
                    cache_len: int = 0, vision=None, impl: str = "xla",
                    unroll: bool = False, qkv_sharding=None, true_len=None,
                    full_cache: bool = False, pages=None):
    """Cache-aware prefill — the single dispatcher over the
    ``models.backends`` PREFILL registry.

    ``dest`` names the destination cache and selects the cache_kind axis
    of the registry key; the config selects the style axis
    (``prefill_style_key``), so merged (Q/P-removed) "qp" models take the
    stream-as-query fast path in every self-attention layer while every
    other combination routes through the generic backend:

    * ``DensePrefillDest(cache_len, full_cache=False)`` — returns
      (last_token_logits (B, V), ``DecodeCache`` of ``cache_len``
      positions).
    * ``PagedPrefillDest(k_pool, v_pool, block_ids)`` — writes the
      prompt's KV DIRECTLY into the mapped physical pool blocks (see the
      dest's docstring for the block_ids contract) and returns
      (last_token_logits (B, V), (k_pool, v_pool)).

    ``true_len`` (B,) int32 supports bucketed prompts: ``inputs`` may be
    RIGHT-padded to a bucket length, and causality guarantees positions
    < true_len are unaffected by the padding — the returned logits are
    gathered at ``true_len - 1`` and the cache marks padded positions
    empty (dense: kv_pos = -1; paged: in-page positions past ``length``,
    hidden by the causal mask) with ``length = true_len``, so decode
    overwrites them in order.  ``None`` means the whole sequence is real.

    Invalid requests (paged on a non-attention stack, paged batch > 1,
    too few mapped blocks, non-positive dense cache_len) raise ValueError
    at the dispatch boundary; unknown (cache_kind, style, impl) combos
    raise the registry's KeyError.  DEPRECATED: calling without ``dest``
    — the old ``cache_len=``/``pages=`` mega-signature — still works via
    a compatibility shim but emits DeprecationWarning (matching the
    ``forward_decode``/``forward_decode_paged`` shims).
    """
    if dest is None:
        warnings.warn(
            "forward_prefill's cache_len=/pages= mega-signature is "
            "deprecated; pass dest=DensePrefillDest(cache_len, full_cache) "
            "or dest=PagedPrefillDest(k_pool, v_pool, block_ids) — the "
            "dispatcher routes it through the models.backends prefill "
            "registry either way", DeprecationWarning, stacklevel=2)
        dest = (PagedPrefillDest(*pages) if pages is not None
                else DensePrefillDest(cache_len=cache_len,
                                      full_cache=full_cache))
    elif pages is not None or cache_len or full_cache:
        # a half-migrated call mixing both conventions would silently drop
        # the legacy arguments — fail instead of prefilling the wrong kind
        raise ValueError(
            "forward_prefill got both dest= and legacy cache_len=/pages=/"
            "full_cache= arguments; the destination lives entirely in "
            "dest — drop the legacy kwargs")

    B, S = int(inputs.shape[0]), int(inputs.shape[1])
    if isinstance(dest, (PagedPrefillDest, PagedQ8PrefillDest)):
        quantized = isinstance(dest, PagedQ8PrefillDest)
        kind = "paged_q8" if quantized else "paged"
        plan = layer_plan(cfg)
        if plan["kind"] != "attn":
            raise ValueError(
                f"paged prefill supports attention-only stacks, not "
                f"{plan['kind']!r} (family {cfg.family!r})")
        if B != 1:
            raise ValueError(
                f"paged prefill inserts one request at a time, got batch "
                f"size {B}")
        nbk, bs = int(dest.block_ids.shape[0]), int(dest.k_pool.shape[2])
        if nbk * bs < S:
            raise ValueError(
                f"{type(dest).__name__}.block_ids maps {nbk} blocks of "
                f"{bs} tokens — too few for a {S}-token prompt")
        if quantized and S % bs:
            # the whole-prompt fake-quant reshapes (B, S) into S/bs pool
            # blocks, so the bucket length must tile exactly (every
            # serving bucket is a power of two >= the block size)
            raise ValueError(
                f"paged_q8 prefill needs the (padded) prompt length to be "
                f"a multiple of the page size: {S} % {bs} != 0")
    elif isinstance(dest, DensePrefillDest):
        kind = "dense"
        if dest.cache_len <= 0:
            raise ValueError(
                "dense prefill needs DensePrefillDest.cache_len > 0, got "
                f"{dest.cache_len!r}")
    else:
        raise ValueError(
            f"unknown prefill destination {type(dest).__name__!r}; expected "
            "DensePrefillDest, PagedPrefillDest, or PagedQ8PrefillDest (or "
            "register a PrefillBackend for a new cache kind)")

    backend = backends.get_prefill_backend(kind, prefill_style_key(cfg), impl)
    ctx = {"vision": vision, "impl": impl, "unroll": unroll,
           "qkv_sharding": qkv_sharding, "true_len": true_len}
    return backend.run(params, cfg, inputs, dest, ctx)


def _prefill_ssm_states(params, cfg: ModelConfig, inputs, vision, impl,
                        unroll: bool = False):
    """Second pass over ssm/hybrid layers to collect final SSM states.

    Exactness note: for ``hybrid``/``ssm`` families the stream must be
    identical to the main pass — it is, because we rerun the same blocks; we
    just additionally thread ``return_state``. Implemented as a dedicated scan
    to keep the common (attention-only) prefill path free of SSM plumbing.
    """
    B, S = inputs.shape[0], inputs.shape[1]
    h = embed_inputs(params, cfg, inputs)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def f(carry, lp):
        h = carry
        kind = layer_plan(cfg)["kind"]
        # mirror apply_block_seq but thread state out of the ssm mixer
        if kind == "ssm":
            if cfg.block_style == "standard":
                delta, st = m2.apply_mamba2_seq(lp["ssm"], apply_rmsnorm(lp["norm1"], h),
                                                cfg, return_state=True, impl=impl)
                out = h + delta
            else:
                out, st = m2.apply_mamba2_seq(lp["ssm"], h, cfg, return_state=True, impl=impl)
            return out, st
        # hybrid
        style = cfg.block_style
        merged = _is_merged(style)
        x = apply_rmsnorm(lp["norm1"], h) if "norm1" in lp else h
        cat, _ = _self_attention_seq(lp["attn"], cfg, x, positions, merged, impl)
        a = _attn_out_proj(lp["attn"], cat)
        s, st = m2.apply_mamba2_seq(lp["ssm"], x, cfg, return_state=True, impl=impl)
        mix = 0.5 * (a + s)
        if style == "standard" or style == "residual_qpfree":
            hh = h + mix
            f_, _ = _apply_ffn_or_moe(lp, cfg, apply_rmsnorm(lp["norm2"], hh))
            out = hh + f_
        else:
            out, _ = _apply_ffn_or_moe(lp, cfg, mix)
        return out, st

    _, states = jax.lax.scan(f, h, params["layers"], unroll=True if unroll else 1)
    return states


# ---------------------------------------------------------------------------
# decode: one token against the cache
# ---------------------------------------------------------------------------

def _rope_and_insert(cfg: ModelConfig, q, k_new, v_new, k_layer, v_layer,
                     length):
    """RoPE the step's q/k at position ``length`` and write the new k/v into
    the ring-buffer slot (slot = length % Sc under sliding window).
    Returns (q, k_layer, v_layer)."""
    pos = length[:, None]  # (B,1)
    q = apply_rope(q, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k_new = apply_rope(k_new, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    Sc = k_layer.shape[1]
    slot = (length % Sc).astype(jnp.int32)

    def upd(cache, new, i):
        return jax.lax.dynamic_update_slice(cache, new, (i, 0, 0))

    k_layer = jax.vmap(upd)(k_layer, k_new.astype(k_layer.dtype), slot)
    v_layer = jax.vmap(upd)(v_layer, v_new.astype(v_layer.dtype), slot)
    return q, k_layer, v_layer


def _attn_step_dense(lp, cfg: ModelConfig, u1, k_layer, v_layer, ctx):
    """Registered backend ("dense", "generic"): projects q/k/v as the
    config dictates (kp/vp merged variants pass through — their eliminated
    projection is an identity inside ``_project_qkv``).

    u1 (B,1,d); k_layer/v_layer (B,Sc,Hkv,Dh). Returns (cat, new_k, new_v).
    """
    B, length = u1.shape[0], ctx["length"]
    merged = _is_merged(cfg.block_style)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, merged)
    q, k_layer, v_layer = _rope_and_insert(cfg, q, k_new, v_new,
                                           k_layer, v_layer, length)
    out = attn_mod.decode_attention_core_positions(
        q[:, 0], k_layer, v_layer,
        kv_positions=ctx["kv_pos"], q_position=length,
        sliding_window=cfg.sliding_window, impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), k_layer, v_layer


def _qkv_reanchor(ctx, q, k_new, v_new):
    """Merged styles lose the TP sharding anchor for q (no wq matmul to
    propagate head-sharding from) — same fix as ``_self_attention_seq``."""
    sh = ctx.get("qkv_sharding")
    if sh is None:
        return q, k_new, v_new
    return (jax.lax.with_sharding_constraint(q, sh),
            jax.lax.with_sharding_constraint(k_new, sh),
            jax.lax.with_sharding_constraint(v_new, sh))


def _attn_step_dense_merged(lp, cfg: ModelConfig, u1, k_layer, v_layer, ctx):
    """Registered backend ("dense", "merged"): the Q/P-removed decode fast
    path — paper Fig 1b cashed in at serve time.  The residual stream is
    the query basis, so the only attention-side weights read per token are
    K*/V*: no d×d Q matmul, no P matmul, and the attention output lands
    directly in the FFN-input basis (the kernel also consumes the cache
    in its native layout).  Numerically identical to the generic backend
    with variant "qp"; it exists so serving never touches the eliminated
    projections.
    """
    B, length = u1.shape[0], ctx["length"]
    # variant "qp": _project_qkv returns the stream itself as q (identity)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, True)
    q, k_new, v_new = _qkv_reanchor(ctx, q, k_new, v_new)
    q, k_layer, v_layer = _rope_and_insert(cfg, q, k_new, v_new,
                                           k_layer, v_layer, length)
    out = attn_mod.decode_attention_core_merged(
        q.reshape(B, cfg.attn_dim), k_layer, v_layer,
        kv_positions=ctx["kv_pos"], q_position=length,
        n_kv_heads=cfg.n_kv_heads,
        sliding_window=cfg.sliding_window, impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), k_layer, v_layer


def _cross_attn_step(lp, cfg: ModelConfig, u1, ck, cv, merged: bool, impl: str):
    B = u1.shape[0]
    if merged:
        q = u1
    else:
        q = u1 @ lp["wq"].astype(u1.dtype)
        if "bq" in lp:
            q = q + lp["bq"].astype(u1.dtype)
    q = q.reshape(B, cfg.n_heads, cfg.d_head)
    nv = ck.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(nv, dtype=jnp.int32), (B, nv))
    out = attn_mod.decode_attention_core_positions(
        q, ck, cv, kv_positions=kv_pos,
        q_position=jnp.full((B,), nv, jnp.int32) + 1,  # attend to all vision tokens
        sliding_window=0, impl=impl)
    return out.reshape(B, 1, cfg.attn_dim)


def apply_block_step(p, cfg: ModelConfig, kind: str, u1, layer_cache, ctx):
    """One block, one token. layer_cache: dict of this layer's cache slices."""
    style = cfg.block_style
    merged = _is_merged(style)
    impl = ctx.get("impl", "xla")
    new_cache = dict(layer_cache)

    if kind == "ssm":
        st = m2.SSMState(ssm=layer_cache["ssm"], conv=layer_cache["conv"])
        x = apply_rmsnorm(p["norm1"], u1) if "norm1" in p else u1
        delta, st2 = m2.apply_mamba2_step(p["ssm"], x[:, 0], cfg, st)
        new_cache.update(ssm=st2.ssm, conv=st2.conv)
        out = u1 + delta[:, None] if style == "standard" else delta[:, None]
        return out, new_cache

    def mixer_fn(x):
        if kind == "cross":
            cat = _cross_attn_step(p["attn"], cfg, x, layer_cache["ck"],
                                   layer_cache["cv"], merged, impl)
            return cat if merged else _attn_out_proj(p["attn"], cat)
        # the registry seam: the per-layer attention route (cache layout ×
        # projection style × impl) was resolved once by forward_step
        cat, nk, nv = ctx["backend"].step(
            p["attn"], cfg, x, layer_cache["k"], layer_cache["v"], ctx)
        new_cache.update(k=nk, v=nv)
        if kind == "hybrid":
            st = m2.SSMState(ssm=layer_cache["ssm"], conv=layer_cache["conv"])
            a = _attn_out_proj(p["attn"], cat)
            s, st2 = m2.apply_mamba2_step(p["ssm"], x[:, 0], cfg, st)
            new_cache.update(ssm=st2.ssm, conv=st2.conv)
            return 0.5 * (a + s[:, None])
        if merged:
            return cat
        return _attn_out_proj(p["attn"], cat)

    if style in ("standard", "residual_qpfree"):
        if cfg.parallel_block:
            n = apply_rmsnorm(p["norm1"], u1)
            f, _ = _apply_ffn_or_moe(p, cfg, n, dropless=True)
            out = u1 + mixer_fn(n) + f
        else:
            h = u1 + mixer_fn(apply_rmsnorm(p["norm1"], u1))
            f, _ = _apply_ffn_or_moe(p, cfg, apply_rmsnorm(p["norm2"], h), dropless=True)
            out = h + f
    else:
        if cfg.parallel_block:
            f, _ = _apply_ffn_or_moe(p, cfg, u1, dropless=True)
            out = mixer_fn(u1) + f
        else:
            mid = mixer_fn(u1)
            out, _ = _apply_ffn_or_moe(p, cfg, mid, dropless=True)
        if style == "skipless_merged" and "b_out" in p:
            out = out + p["b_out"].astype(out.dtype)
    return out, new_cache


def serving_style_key(cfg: ModelConfig) -> str:
    """Projection-style axis of the backend registry key for this config.

    "merged" iff the per-token step can skip every eliminated projection
    (qp variant of the merged styles on attention/vlm stacks — the stream
    IS the query and no P exists).  kp/vp merged variants return
    "generic": their eliminated projection is an identity inside
    ``_project_qkv``, so no dedicated route exists (or is needed — they
    decode token-identically through the generic backend).  Hybrid stacks
    are "generic" too: their merged form keeps P (the FFN input is the
    fused attn+ssm stream), so the fast path's contract doesn't hold.
    """
    plan = layer_plan(cfg)
    if plan["kind"] not in ("attn", "vlm"):
        return "generic"
    if cfg.block_style in ("skipless_merged", "residual_qpfree") \
            and cfg.merged_variant == "qp":
        return "merged"
    return "generic"


def forward_step(params, cfg: ModelConfig, token, cache, *,
                 impl: str = "xla", unroll: bool = False,
                 qkv_sharding=None):
    """One decode step against EITHER serving cache — the single serving
    entry point (serve_step body).

    token: (B,) int32 (or (B,d) frames). Returns (logits (B,V), new cache)
    where ``cache`` (and the return) is a ``DecodeCache`` or a
    ``PagedDecodeCache``; the cache type selects the cache_kind axis of
    the backend registry key and the config selects the style axis
    (``serving_style_key``), so merged (Q/P-removed) "qp" models take the
    fast path — per-token attention reads only K*/V* weights and the
    merged ``b_out`` bias is applied in-stream after the FFN — while
    every other combination routes through the generic backend.
    ``qkv_sharding`` re-anchors TP head sharding for merged styles (no wq
    matmul).  Unknown (cache_kind, style, impl) combinations raise
    KeyError from the registry before any compute.
    """
    paged_q8 = isinstance(cache, PagedQ8DecodeCache)
    paged = paged_q8 or isinstance(cache, PagedDecodeCache)
    plan = layer_plan(cfg)
    if paged:
        assert plan["kind"] == "attn", (
            "paged decode supports attention-only stacks; got " + plan["kind"])
    kind = "paged_q8" if paged_q8 else ("paged" if paged else "dense")
    backend = backends.get_backend(kind, serving_style_key(cfg), impl)
    # embed through the same front-end as the seq path: skipless styles
    # scale the embedding output, and merged trees fold Q_0 into the table
    # plus optional input_proj / embed_bias — skipping any of these makes
    # decode diverge from prefill
    inputs = token[:, None] if token.dtype in (jnp.int32, jnp.int64) \
        else token[:, None, :]
    h = embed_inputs(params, cfg, inputs)

    if paged:
        ctx = {"length": cache.length, "block_tables": cache.block_tables,
               "impl": impl, "qkv_sharding": qkv_sharding,
               "backend": backend}

        def f(h, xs):
            lp, lc = xs
            out, nc = apply_block_step(lp, cfg, "attn", h, lc, ctx)
            return out, nc

        # q8 stores scan as (pool, scale) pairs — apply_block_step passes
        # them through to the backend step opaquely
        stores = {"k": (cache.k, cache.k_scale),
                  "v": (cache.v, cache.v_scale)} if paged_q8 else \
            {"k": cache.k, "v": cache.v}
        h, ncs = jax.lax.scan(f, h, (params["layers"], stores),
                              unroll=True if unroll else 1)
        if "final_norm" in params:
            h = apply_rmsnorm(params["final_norm"], h)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = apply_unembedding(table, h)[:, 0, :]
        if paged_q8:
            return logits, cache._replace(
                k=ncs["k"][0], k_scale=ncs["k"][1],
                v=ncs["v"][0], v_scale=ncs["v"][1],
                length=cache.length + 1)
        return logits, cache._replace(k=ncs["k"], v=ncs["v"],
                                      length=cache.length + 1)

    # mark the new token's slot as valid BEFORE attention so it attends to
    # itself (ring-buffer slot = length % Sc under sliding window)
    kv_pos = cache.kv_pos
    if kv_pos is not None:
        Sc = kv_pos.shape[1]
        slot = (cache.length % Sc).astype(jnp.int32)
        kv_pos = jax.vmap(lambda pr, s, ln: pr.at[s].set(ln))(
            kv_pos, slot, cache.length)
    ctx = {"length": cache.length, "kv_pos": kv_pos, "impl": impl,
           "qkv_sharding": qkv_sharding, "backend": backend}

    def layer_cache_slices(kind):
        if kind == "ssm":
            return {"ssm": cache.ssm.ssm, "conv": cache.ssm.conv}
        d = {"k": cache.k, "v": cache.v}
        if kind == "hybrid":
            d.update(ssm=cache.ssm.ssm, conv=cache.ssm.conv)
        return d

    new_cache = cache
    if plan["kind"] == "vlm":
        ng, spg = plan["n_groups"], plan["self_per_group"]
        ks = cache.k.reshape(ng, spg, *cache.k.shape[1:])
        vs = cache.v.reshape(ng, spg, *cache.v.shape[1:])

        def group_fn(h, xs):
            gp, klayers, vlayers, ck, cv = xs

            def self_fn(h, xs2):
                lp, kl, vl = xs2
                out, nc = apply_block_step(lp, cfg, "attn", h,
                                           {"k": kl, "v": vl}, ctx)
                return out, (nc["k"], nc["v"])

            h, (nk, nv) = jax.lax.scan(self_fn, h, (gp["self"], klayers, vlayers),
                                       unroll=True if unroll else 1)
            out, _ = apply_block_step(gp["cross"], cfg, "cross", h,
                                      {"ck": ck, "cv": cv}, ctx)
            return out, (nk, nv)

        gparams = {"self": params["layers"], "cross": params["cross_layers"]}
        h, (nk, nv) = jax.lax.scan(group_fn, h,
                                   (gparams, ks, vs, cache.cross_k, cache.cross_v),
                                   unroll=True if unroll else 1)
        new_cache = new_cache._replace(k=nk.reshape(cache.k.shape),
                                       v=nv.reshape(cache.v.shape))
    else:
        kind = plan["kind"]

        def f(h, xs):
            lp, lc = xs
            out, nc = apply_block_step(lp, cfg, kind, h, lc, ctx)
            return out, nc

        lcaches = {}
        if kind in ("attn", "hybrid"):
            lcaches.update(k=cache.k, v=cache.v)
        if kind in ("ssm", "hybrid"):
            lcaches.update(ssm=cache.ssm.ssm, conv=cache.ssm.conv)
        h, ncs = jax.lax.scan(f, h, (params["layers"], lcaches),
                              unroll=True if unroll else 1)
        if "k" in ncs:
            new_cache = new_cache._replace(k=ncs["k"], v=ncs["v"])
        if "ssm" in ncs:
            new_cache = new_cache._replace(
                ssm=m2.SSMState(ssm=ncs["ssm"], conv=ncs["conv"]))

    if "final_norm" in params:
        h = apply_rmsnorm(params["final_norm"], h)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = apply_unembedding(table, h)[:, 0, :]

    # advance shared cache bookkeeping
    if kv_pos is not None:
        new_cache = new_cache._replace(kv_pos=kv_pos)
    new_cache = new_cache._replace(length=cache.length + 1)
    return logits, new_cache


# ---------------------------------------------------------------------------
# paged decode: block-table cache (pool of physical pages) — serving layer
# allocates/frees pages host-side (serving.paged_kv_cache), this module only
# consumes the device-side view
# ---------------------------------------------------------------------------

class PagedDecodeCache(NamedTuple):
    """Device view of the paged KV cache (attention-only stacks).

    ``k``/``v`` are pools of physical pages shared by every serving slot;
    ``block_tables[b, j]`` maps slot b's logical block j to a physical page
    (-1 = unmapped).  Sliding-window configs bound the table at
    ``ceil(window/bs)+1`` RING slots (absolute block j lives at slot
    j % ring and out-of-window pages are recycled in place); readers
    reconstruct each slot's absolute positions from ``length``, so the
    ring phase is carried by the cache exactly as the dense ring buffer
    carries it (``kernels.paging``).  Page content beyond a slot's
    ``length`` may be stale (freed/reused/recycled pages are not
    scrubbed) — the causal mask hides it, and decode always writes
    position ``length`` before attending.
    """
    k: jnp.ndarray  # (L, n_blocks, block_size, Hkv, Dh) — physical pages
    v: jnp.ndarray
    block_tables: jnp.ndarray  # (B, MB) int32 page ids, -1 unmapped
    length: jnp.ndarray  # (B,) int32 — tokens so far (= next position)


def paged_table_blocks(cfg: ModelConfig, block_size: int, max_len: int) -> int:
    """Block-table width for one serving slot: ``ceil(max_len/bs)`` slots
    in absolute addressing, or the ring bound ``ceil(window/bs)+1`` when a
    sliding window makes that strictly smaller — windowed requests then
    wrap the table and recycle out-of-window pages in place (the paged
    sibling of the dense window-sized ring buffer; ``kernels.paging``)."""
    mb = -(-max_len // block_size)
    r = paging.paged_ring_blocks(cfg.sliding_window, block_size)
    return r if 0 < r < mb else mb


def paged_cache_spec(cfg: ModelConfig, n_blocks: int, block_size: int,
                     n_slots: int, max_len: int):
    """Shapes for an empty paged cache (init and jit input specs)."""
    plan = layer_plan(cfg)
    if plan["kind"] != "attn":
        raise ValueError(
            f"paged KV cache supports attention-only stacks, not "
            f"{plan['kind']!r} (family {cfg.family!r})")
    cdt = dtype_of(cfg.dtype)
    mb = paged_table_blocks(cfg, block_size, max_len)
    pool = ((plan["n"], n_blocks, block_size, cfg.n_kv_heads, cfg.d_head), cdt)
    return {"k": pool, "v": pool,
            "block_tables": ((n_slots, mb), jnp.int32),
            "length": ((n_slots,), jnp.int32)}


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     n_slots: int, max_len: int) -> PagedDecodeCache:
    spec = paged_cache_spec(cfg, n_blocks, block_size, n_slots, max_len)
    return PagedDecodeCache(
        k=jnp.zeros(*spec["k"]), v=jnp.zeros(*spec["v"]),
        block_tables=jnp.full(spec["block_tables"][0], -1, jnp.int32),
        length=jnp.zeros(*spec["length"]))


class PagedQ8DecodeCache(NamedTuple):
    """Device view of the QUANTIZED paged KV cache: the
    ``PagedDecodeCache`` contract with int8 pools plus per-(page,
    kv-head) float32 scale arrays (``kernels.quant`` layout).  Scales are
    part of the page: CoW copies them with the bytes
    (``serving.paged_kv_cache.copy_block_q8``) and recycled pages'
    stale scales are garbage hidden exactly like stale page bytes —
    decode's quantize-on-write resets a page's scale when it enters the
    page at offset 0."""
    k: jnp.ndarray  # (L, n_blocks, block_size, Hkv, Dh) int8 pages
    v: jnp.ndarray
    k_scale: jnp.ndarray  # (L, n_blocks, Hkv) float32
    v_scale: jnp.ndarray
    block_tables: jnp.ndarray  # (B, MB) int32 page ids, -1 unmapped
    length: jnp.ndarray  # (B,) int32 — tokens so far (= next position)


def paged_q8_cache_spec(cfg: ModelConfig, n_blocks: int, block_size: int,
                        n_slots: int, max_len: int):
    """Shapes for an empty quantized paged cache (init and jit specs)."""
    spec = paged_cache_spec(cfg, n_blocks, block_size, n_slots, max_len)
    plan = layer_plan(cfg)
    pool = (spec["k"][0], jnp.int8)
    scale = ((plan["n"], n_blocks, cfg.n_kv_heads), jnp.float32)
    return {"k": pool, "v": pool, "k_scale": scale, "v_scale": scale,
            "block_tables": spec["block_tables"],
            "length": spec["length"]}


def init_paged_q8_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                        n_slots: int, max_len: int) -> PagedQ8DecodeCache:
    spec = paged_q8_cache_spec(cfg, n_blocks, block_size, n_slots, max_len)
    return PagedQ8DecodeCache(
        k=jnp.zeros(*spec["k"]), v=jnp.zeros(*spec["v"]),
        k_scale=jnp.zeros(*spec["k_scale"]),
        v_scale=jnp.zeros(*spec["v_scale"]),
        block_tables=jnp.full(spec["block_tables"][0], -1, jnp.int32),
        length=jnp.zeros(*spec["length"]))


def _rope_and_insert_paged(cfg: ModelConfig, q, k_new, v_new, k_pool, v_pool,
                           block_tables, length):
    """RoPE the step's q/k at position ``length`` and scatter the new k/v
    into each slot's mapped page (page = table[length // bs], offset =
    length % bs; ring-addressed windowed tables wrap the table index —
    ``kernels.paging``).  Unmapped slots (idle batch rows) drop the
    write."""
    pos = length[:, None]  # (B,1)
    q = apply_rope(q, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k_new = apply_rope(k_new, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    MB = block_tables.shape[1]
    ring = paging.paged_ring_active(cfg.sliding_window, bs, MB)
    lb = (length // bs).astype(jnp.int32)
    lb = (lb % ring) if ring else jnp.minimum(lb, MB - 1)
    off = (length % bs).astype(jnp.int32)
    blk = jnp.take_along_axis(block_tables, lb[:, None], axis=1)[:, 0]
    safe = jnp.where(blk >= 0, blk, NB)  # NB is out of range -> dropped
    k_pool = k_pool.at[safe, off].set(k_new[:, 0].astype(k_pool.dtype),
                                      mode="drop")
    v_pool = v_pool.at[safe, off].set(v_new[:, 0].astype(v_pool.dtype),
                                      mode="drop")
    return q, k_pool, v_pool


def _attn_step_paged(lp, cfg: ModelConfig, u1, k_pool, v_pool, ctx):
    """Registered backend ("paged", "generic"): decode step vs a paged
    pool.  u1 (B,1,d); k_pool/v_pool (NB,bs,Hkv,Dh).  Returns (cat,
    new_k_pool, new_v_pool)."""
    B, length = u1.shape[0], ctx["length"]
    block_tables = ctx["block_tables"]
    merged = _is_merged(cfg.block_style)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, merged)
    q, k_pool, v_pool = _rope_and_insert_paged(cfg, q, k_new, v_new,
                                               k_pool, v_pool, block_tables,
                                               length)
    out = attn_mod.decode_attention_core_paged(
        q[:, 0], k_pool, v_pool, block_tables=block_tables,
        q_position=length, sliding_window=cfg.sliding_window,
        impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), k_pool, v_pool


def _attn_step_paged_merged(lp, cfg: ModelConfig, u1, k_pool, v_pool, ctx):
    """Registered backend ("paged", "merged"): the Q/P-removed fast path
    vs a paged pool — per token the attention-side HBM traffic is K*/V*
    weights plus the slot's mapped pages: no Q/P weight reads AND no dense
    worst-case-length cache."""
    B, length = u1.shape[0], ctx["length"]
    block_tables = ctx["block_tables"]
    # variant "qp": _project_qkv returns the stream itself as q (identity)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, True)
    q, k_new, v_new = _qkv_reanchor(ctx, q, k_new, v_new)
    q, k_pool, v_pool = _rope_and_insert_paged(cfg, q, k_new, v_new,
                                               k_pool, v_pool, block_tables,
                                               length)
    out = attn_mod.decode_attention_core_paged_merged(
        q.reshape(B, cfg.attn_dim), k_pool, v_pool,
        block_tables=block_tables, q_position=length,
        n_kv_heads=cfg.n_kv_heads, sliding_window=cfg.sliding_window,
        impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), k_pool, v_pool


def _rope_and_insert_paged_q8(cfg: ModelConfig, q, k_new, v_new,
                              k_pool, v_pool, k_scale, v_scale,
                              block_tables, length):
    """``_rope_and_insert_paged`` over int8 pools: RoPE, then QUANTIZE the
    new token into each slot's mapped page under the page's monotone
    scale merge (``kernels.quant.q8_append_token`` — the scale row is
    written in the same drop-scatter as the page bytes).  Runs in plain
    XLA inside every impl's program, so pool bits are impl-independent."""
    pos = length[:, None]  # (B,1)
    q = apply_rope(q, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k_new = apply_rope(k_new, pos, style=cfg.rope_style, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    MB = block_tables.shape[1]
    ring = paging.paged_ring_active(cfg.sliding_window, bs, MB)
    lb = (length // bs).astype(jnp.int32)
    lb = (lb % ring) if ring else jnp.minimum(lb, MB - 1)
    off = (length % bs).astype(jnp.int32)
    blk = jnp.take_along_axis(block_tables, lb[:, None], axis=1)[:, 0]
    safe = jnp.where(blk >= 0, blk, NB)  # NB is out of range -> dropped
    k_pool, k_scale = quant.q8_append_token(k_pool, k_scale, k_new[:, 0],
                                            safe, off)
    v_pool, v_scale = quant.q8_append_token(v_pool, v_scale, v_new[:, 0],
                                            safe, off)
    return q, k_pool, v_pool, k_scale, v_scale


def _attn_step_paged_q8(lp, cfg: ModelConfig, u1, k_store, v_store, ctx):
    """Registered backend ("paged_q8", "generic"): decode step vs the
    quantized pool.  The scan-carried stores are (pool, scale) pytree
    pairs — ``apply_block_step`` treats them opaquely, so the block wiring
    is untouched.  Returns (cat, (k_pool, k_scale), (v_pool, v_scale))."""
    B, length = u1.shape[0], ctx["length"]
    block_tables = ctx["block_tables"]
    k_pool, k_scale = k_store
    v_pool, v_scale = v_store
    merged = _is_merged(cfg.block_style)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, merged)
    q, k_pool, v_pool, k_scale, v_scale = _rope_and_insert_paged_q8(
        cfg, q, k_new, v_new, k_pool, v_pool, k_scale, v_scale,
        block_tables, length)
    out = attn_mod.decode_attention_core_paged_q8(
        q[:, 0], k_pool, v_pool, k_scale, v_scale,
        block_tables=block_tables, q_position=length,
        sliding_window=cfg.sliding_window, impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), (k_pool, k_scale), \
        (v_pool, v_scale)


def _attn_step_paged_q8_merged(lp, cfg: ModelConfig, u1, k_store, v_store,
                               ctx):
    """Registered backend ("paged_q8", "merged"): the Q/P-removed fast
    path vs the quantized pool — per token the attention-side HBM traffic
    is K*/V* weights plus ONE BYTE per mapped pooled element (the pallas
    kernel dequantizes per tile in VMEM; no full-precision pool view is
    ever materialized)."""
    B, length = u1.shape[0], ctx["length"]
    block_tables = ctx["block_tables"]
    k_pool, k_scale = k_store
    v_pool, v_scale = v_store
    # variant "qp": _project_qkv returns the stream itself as q (identity)
    q, k_new, v_new = _project_qkv(lp, cfg, u1, u1, True)
    q, k_new, v_new = _qkv_reanchor(ctx, q, k_new, v_new)
    q, k_pool, v_pool, k_scale, v_scale = _rope_and_insert_paged_q8(
        cfg, q, k_new, v_new, k_pool, v_pool, k_scale, v_scale,
        block_tables, length)
    out = attn_mod.decode_attention_core_paged_q8_merged(
        q.reshape(B, cfg.attn_dim), k_pool, v_pool, k_scale, v_scale,
        block_tables=block_tables, q_position=length,
        n_kv_heads=cfg.n_kv_heads, sliding_window=cfg.sliding_window,
        impl=ctx["impl"])
    return out.reshape(B, 1, cfg.attn_dim), (k_pool, k_scale), \
        (v_pool, v_scale)


# the serving attention routes, one per (cache layout × projection
# style); each registration covers xla/pallas/pallas_interpret (the steps
# read ``impl`` from ctx and the cores dispatch on it)
backends.register_backend("dense", "generic", _attn_step_dense)
backends.register_backend("dense", "merged", _attn_step_dense_merged,
                          fast_path=True)
backends.register_backend("paged", "generic", _attn_step_paged)
backends.register_backend("paged", "merged", _attn_step_paged_merged,
                          fast_path=True)
backends.register_backend("paged_q8", "generic", _attn_step_paged_q8)
backends.register_backend("paged_q8", "merged", _attn_step_paged_q8_merged,
                          fast_path=True)


# ---------------------------------------------------------------------------
# deprecated per-cache-kind entry points (thin shims over forward_step)
# ---------------------------------------------------------------------------

def forward_decode(params, cfg: ModelConfig, token, cache: DecodeCache, *,
                   impl: str = "xla", unroll: bool = False,
                   qkv_sharding=None):
    """DEPRECATED: use ``forward_step`` (it dispatches on the cache type)."""
    warnings.warn(
        "forward_decode is deprecated; use forward_step, which serves "
        "every (cache_kind, style, impl) combo through the backend "
        "registry", DeprecationWarning, stacklevel=2)
    return forward_step(params, cfg, token, cache, impl=impl, unroll=unroll,
                        qkv_sharding=qkv_sharding)


def forward_decode_paged(params, cfg: ModelConfig, token,
                         cache: PagedDecodeCache, *, impl: str = "xla",
                         unroll: bool = False, qkv_sharding=None):
    """DEPRECATED: use ``forward_step`` (it dispatches on the cache type)."""
    warnings.warn(
        "forward_decode_paged is deprecated; use forward_step, which "
        "serves every (cache_kind, style, impl) combo through the backend "
        "registry", DeprecationWarning, stacklevel=2)
    return forward_step(params, cfg, token, cache, impl=impl, unroll=unroll,
                        qkv_sharding=qkv_sharding)


# ---------------------------------------------------------------------------
# chunked prefill: fixed-size prompt slices against the SERVING cache — the
# third phase of the backend registry (repro.serving.sched interleaves these
# programs with decode steps inside one engine iteration)
# ---------------------------------------------------------------------------

# causal-mask sentinel for kv positions that must never be attended (empty
# dense ring entries / unmapped pages): larger than any real position, so the
# causal test kv_pos <= q_pos excludes them without a separate validity mask
# (attention_core_merged has no kv_valid parameter)
_CHUNK_POS_SENTINEL = jnp.int32(2 ** 30)


class DenseChunkDest(NamedTuple):
    """Destination of one dense prefill chunk: batch row ``slot`` of the
    BATCHED serving ``DecodeCache`` (not a fresh single-request cache — the
    chunk writes in place at positions [start, start+C) of that row, so the
    caller should donate the cache).  ``slot`` is a (1,) int32 array
    (traced, so one compiled program serves every slot)."""
    cache: Any
    slot: Any


class PagedChunkDest(NamedTuple):
    """Destination of one paged prefill chunk, written direct-to-page.

    ``block_table`` is THIS slot's (1, MB) table row (true mapping, not the
    shield-masked decode view); ``block_ids`` maps the chunk's C//bs
    logical blocks to physical pages, -1 = drop the write (prefix-shared
    pages already holding the prefix, and blocks past the prompt under
    final-chunk padding — exactly the ``PagedPrefillDest.block_ids``
    contract, per chunk)."""
    k_pool: Any
    v_pool: Any
    block_table: Any
    block_ids: Any


class PagedQ8ChunkDest(NamedTuple):
    """Destination of one QUANTIZED paged prefill chunk: the
    ``PagedChunkDest`` contract over int8 pools + per-(page, kv-head)
    float32 scales — the chunk quantizes its K/V at pool granularity and
    writes ints AND scale rows in the same drop-scatter."""
    k_pool: Any
    v_pool: Any
    k_scale: Any
    v_scale: Any
    block_table: Any
    block_ids: Any


def _chunk_last_logits(logits, start, true_len, C):
    """Last REAL position's logits within the chunk: index true_len-1-start
    clipped into [0, C) — meaningful on the final chunk (where the prompt's
    last token lies in [start, start+C)), arbitrary-but-finite otherwise."""
    idx = jnp.clip(true_len - 1 - start, 0, C - 1)  # (1,)
    return jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0, :]


def _chunk_block_scan(params, cfg: ModelConfig, h, chunk_attn, k_stack,
                      v_stack, impl, qkv_sharding):
    """Run the block stack over a chunk stream, scanning per-layer KV
    stores exactly as ``forward_step`` does, with ``chunk_attn`` as the
    attention route (``apply_block_step``'s backend seam is shape-agnostic
    in the stream's sequence extent, so the whole block wiring — styles,
    b_out, norms, FFN — is reused as-is)."""
    ctx = {"impl": impl, "qkv_sharding": qkv_sharding,
           "backend": backends.AttentionBackend(
               cache_kind="chunk", style="chunk", impl=impl,
               step=chunk_attn)}

    def f(hh, xs):
        lp, lc = xs
        out, nc = apply_block_step(lp, cfg, "attn", hh, lc, ctx)
        return out, nc

    h, ncs = jax.lax.scan(f, h, (params["layers"],
                                 {"k": k_stack, "v": v_stack}))
    if "final_norm" in params:
        h = apply_rmsnorm(params["final_norm"], h)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return apply_unembedding(table, h), ncs  # (1, C, V), {"k","v"} stacks


def _chunk_rope(cfg: ModelConfig, q, k_new, positions):
    q = apply_rope(q, positions, style=cfg.rope_style, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k_new = apply_rope(k_new, positions, style=cfg.rope_style,
                       theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    return q, k_new


def _chunk_dense(params, cfg: ModelConfig, chunk, dest, ctx, *,
                 merged_core: bool):
    """Shared body of both dense chunk routes.

    Per layer: write the chunk's K/V into the slot's rows at [start,
    start+C) FIRST, then attend over the full row — the chunk attends to
    itself and every earlier chunk, and overwrites the frontier-parked
    garbage a concurrent batched decode step may have deposited (the
    scheduler pins a mid-prefill slot's device length at the chunk
    frontier, so that garbage never lands anywhere else).  Positions use
    the XLA positions-based cores for every impl — the flash kernels
    assume arange positions, so a fused chunk kernel is a follow-up
    (ROADMAP) — and kv_pos validity rides the causal mask via a > max
    position sentinel.  Padded final-chunk positions get kv_pos =
    absolute position >= true_len: no earlier query attends to them and
    decode overwrites them in order (the bucketed-prefill invariant)."""
    cache, slot = dest.cache, dest.slot
    start, true_len = ctx["start"], ctx["true_len"]
    impl = ctx.get("impl", "xla")
    C = chunk.shape[1]
    s0, p0 = slot[0], start[0]
    Sc = cache.k.shape[2]
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    merged = _is_merged(cfg.block_style)
    pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (1,C)

    kv_pos = jax.lax.dynamic_update_slice(cache.kv_pos, pos,
                                          (s0, p0))
    kv_row = jax.lax.dynamic_slice(kv_pos, (s0, jnp.int32(0)), (1, Sc))
    kv_eff = jnp.where(kv_row >= 0, kv_row, _CHUNK_POS_SENTINEL)

    def chunk_attn(lp, cfg_, x, k_layer, v_layer, actx):
        q, k_new, v_new = _project_qkv(lp, cfg_, x, x, merged)
        q, k_new, v_new = _qkv_reanchor(actx, q, k_new, v_new)
        q, k_new = _chunk_rope(cfg_, q, k_new, pos)
        k_layer = jax.lax.dynamic_update_slice(
            k_layer, k_new.astype(k_layer.dtype), (s0, p0, 0, 0))
        v_layer = jax.lax.dynamic_update_slice(
            v_layer, v_new.astype(v_layer.dtype), (s0, p0, 0, 0))
        k_row = jax.lax.dynamic_slice(k_layer, (s0, 0, 0, 0),
                                      (1, Sc, Hkv, Dh))
        v_row = jax.lax.dynamic_slice(v_layer, (s0, 0, 0, 0),
                                      (1, Sc, Hkv, Dh))
        if merged_core:
            out = attn_mod.attention_core_merged(
                q.reshape(1, C, cfg_.attn_dim), k_row, v_row,
                q_positions=pos, kv_positions=kv_eff,
                n_kv_heads=cfg_.n_kv_heads, causal=cfg_.causal,
                sliding_window=cfg_.sliding_window, query_chunk=C,
                impl="xla", cache_kind="dense")
            return out, k_layer, v_layer
        out = attn_mod.attention_core(
            q, k_row, v_row, q_positions=pos, kv_positions=kv_eff,
            causal=cfg_.causal, sliding_window=cfg_.sliding_window,
            query_chunk=C, impl="xla")
        return out.reshape(1, C, cfg_.attn_dim), k_layer, v_layer

    h = embed_inputs(params, cfg, chunk)
    logits, ncs = _chunk_block_scan(params, cfg, h, chunk_attn,
                                    cache.k, cache.v, impl,
                                    ctx.get("qkv_sharding"))
    last = _chunk_last_logits(logits, start, true_len, C)
    new_len = cache.length.at[s0].set(jnp.minimum(p0 + C, true_len[0]))
    return last, cache._replace(k=ncs["k"], v=ncs["v"], kv_pos=kv_pos,
                                length=new_len)


def _chunk_paged(params, cfg: ModelConfig, chunk, dest, ctx, *,
                 merged_core: bool):
    """Shared body of both paged chunk routes: write the chunk's pages
    (drop-scatter, ``PagedChunkDest.block_ids`` contract), then attend
    over the slot's densified page view.  The gather materializes a
    (1, MB*bs) view per layer — same extent as the XLA paged decode core;
    a fused chunk kernel that walks the table is the follow-up
    (``NoOversizedBuffer`` deliberately does not cover the chunk phase).
    Ring (sliding-window) tables: the dispatcher pins C == block_size, so
    the whole chunk lives in one ring slot and position reconstruction at
    the chunk's last query is exact for every query in it."""
    k_pool, v_pool, table, bids = dest
    start, true_len = ctx["start"], ctx["true_len"]
    impl = ctx.get("impl", "xla")
    C = chunk.shape[1]
    NB, bs = k_pool.shape[1], k_pool.shape[2]
    MB = table.shape[1]
    nbk = C // bs
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    merged = _is_merged(cfg.block_style)
    pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (1,C)
    ring = paging.paged_ring_active(cfg.sliding_window, bs, MB)
    kvpos = attn_mod.paged_kv_positions(table, bs, start + (C - 1), ring)
    kv_eff = jnp.where(kvpos >= 0, kvpos, _CHUNK_POS_SENTINEL)
    safe = jnp.where(bids >= 0, bids, NB).astype(jnp.int32)  # (nbk,)

    def chunk_attn(lp, cfg_, x, kp, vp, actx):
        q, k_new, v_new = _project_qkv(lp, cfg_, x, x, merged)
        q, k_new, v_new = _qkv_reanchor(actx, q, k_new, v_new)
        q, k_new = _chunk_rope(cfg_, q, k_new, pos)
        kb = k_new[0].astype(kp.dtype).reshape(nbk, bs, Hkv, Dh)
        vb = v_new[0].astype(vp.dtype).reshape(nbk, bs, Hkv, Dh)
        kp = kp.at[safe].set(kb, mode="drop")
        vp = vp.at[safe].set(vb, mode="drop")
        gk = attn_mod._paged_gather(kp, table)  # (1, MB*bs, Hkv, Dh)
        gv = attn_mod._paged_gather(vp, table)
        if merged_core:
            out = attn_mod.attention_core_merged(
                q.reshape(1, C, cfg_.attn_dim), gk, gv,
                q_positions=pos, kv_positions=kv_eff,
                n_kv_heads=cfg_.n_kv_heads, causal=cfg_.causal,
                sliding_window=cfg_.sliding_window, query_chunk=C,
                impl="xla", cache_kind="paged")
            return out, kp, vp
        out = attn_mod.attention_core(
            q, gk, gv, q_positions=pos, kv_positions=kv_eff,
            causal=cfg_.causal, sliding_window=cfg_.sliding_window,
            query_chunk=C, impl="xla")
        return out.reshape(1, C, cfg_.attn_dim), kp, vp

    h = embed_inputs(params, cfg, chunk)
    logits, ncs = _chunk_block_scan(params, cfg, h, chunk_attn,
                                    k_pool, v_pool, impl,
                                    ctx.get("qkv_sharding"))
    last = _chunk_last_logits(logits, start, true_len, C)
    return last, (ncs["k"], ncs["v"])


def _chunk_paged_q8(params, cfg: ModelConfig, chunk, dest, ctx, *,
                    merged_core: bool):
    """Shared body of both paged_q8 chunk routes: ``_chunk_paged`` over
    the quantized pool.  The chunk's K/V is quantized at pool granularity
    (positions >= true_len masked to zero first — the same
    ``quant.q8_quantize_seq`` call the whole-prompt q8 prefill fake-quants
    with, so a chunked prompt lands bit-identical pool bytes) and the
    attention view is the DEQUANTIZED page gather, matching what decode's
    q8 cores reconstruct.  Scan-carried stores are (pool, scale) pairs,
    exactly as in the q8 decode step."""
    k_pool, v_pool, k_scale, v_scale, table, bids = dest
    start, true_len = ctx["start"], ctx["true_len"]
    impl = ctx.get("impl", "xla")
    C = chunk.shape[1]
    NB, bs = k_pool.shape[1], k_pool.shape[2]
    MB = table.shape[1]
    nbk = C // bs
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    merged = _is_merged(cfg.block_style)
    pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (1,C)
    ring = paging.paged_ring_active(cfg.sliding_window, bs, MB)
    kvpos = attn_mod.paged_kv_positions(table, bs, start + (C - 1), ring)
    kv_eff = jnp.where(kvpos >= 0, kvpos, _CHUNK_POS_SENTINEL)
    safe = jnp.where(bids >= 0, bids, NB).astype(jnp.int32)  # (nbk,)
    valid = pos < true_len[:, None]  # (1,C)

    def chunk_attn(lp, cfg_, x, kst, vst, actx):
        kp, ks = kst
        vp, vs = vst
        q, k_new, v_new = _project_qkv(lp, cfg_, x, x, merged)
        q, k_new, v_new = _qkv_reanchor(actx, q, k_new, v_new)
        q, k_new = _chunk_rope(cfg_, q, k_new, pos)
        kq, ksc = quant.q8_quantize_seq(k_new, bs, valid)
        vq, vsc = quant.q8_quantize_seq(v_new, bs, valid)
        kp = kp.at[safe].set(kq[0].reshape(nbk, bs, Hkv, Dh), mode="drop")
        vp = vp.at[safe].set(vq[0].reshape(nbk, bs, Hkv, Dh), mode="drop")
        ks = ks.at[safe].set(ksc[0], mode="drop")
        vs = vs.at[safe].set(vsc[0], mode="drop")
        gk = attn_mod._paged_gather_q8(kp, ks, table, x.dtype)
        gv = attn_mod._paged_gather_q8(vp, vs, table, x.dtype)
        if merged_core:
            out = attn_mod.attention_core_merged(
                q.reshape(1, C, cfg_.attn_dim), gk, gv,
                q_positions=pos, kv_positions=kv_eff,
                n_kv_heads=cfg_.n_kv_heads, causal=cfg_.causal,
                sliding_window=cfg_.sliding_window, query_chunk=C,
                impl="xla", cache_kind="paged_q8")
            return out, (kp, ks), (vp, vs)
        out = attn_mod.attention_core(
            q, gk, gv, q_positions=pos, kv_positions=kv_eff,
            causal=cfg_.causal, sliding_window=cfg_.sliding_window,
            query_chunk=C, impl="xla")
        return out.reshape(1, C, cfg_.attn_dim), (kp, ks), (vp, vs)

    h = embed_inputs(params, cfg, chunk)
    logits, ncs = _chunk_block_scan(params, cfg, h, chunk_attn,
                                    (k_pool, k_scale), (v_pool, v_scale),
                                    impl, ctx.get("qkv_sharding"))
    last = _chunk_last_logits(logits, start, true_len, C)
    return last, (ncs["k"][0], ncs["v"][0], ncs["k"][1], ncs["v"][1])


# --- the four registered chunk routes ----------------------------------------

def _chunk_dense_generic(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("dense", "generic")."""
    return _chunk_dense(params, cfg, chunk, dest, ctx, merged_core=False)


def _chunk_dense_merged(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("dense", "merged"): the Q/P-removed fast
    path chunk-by-chunk — the chunk program reads no Q or P weights."""
    return _chunk_dense(params, cfg, chunk, dest, ctx, merged_core=True)


def _chunk_paged_generic(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("paged", "generic")."""
    return _chunk_paged(params, cfg, chunk, dest, ctx, merged_core=False)


def _chunk_paged_merged(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("paged", "merged"): stream-as-query
    attention AND direct-to-page chunk writes."""
    return _chunk_paged(params, cfg, chunk, dest, ctx, merged_core=True)


def _chunk_paged_q8_generic(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("paged_q8", "generic")."""
    return _chunk_paged_q8(params, cfg, chunk, dest, ctx, merged_core=False)


def _chunk_paged_q8_merged(params, cfg: ModelConfig, chunk, dest, ctx):
    """Registered chunk backend ("paged_q8", "merged"): stream-as-query
    attention AND int8 direct-to-page chunk writes."""
    return _chunk_paged_q8(params, cfg, chunk, dest, ctx, merged_core=True)


backends.register_chunk_backend("dense", "generic", _chunk_dense_generic)
backends.register_chunk_backend("dense", "merged", _chunk_dense_merged,
                                fast_path=True)
backends.register_chunk_backend("paged", "generic", _chunk_paged_generic)
backends.register_chunk_backend("paged", "merged", _chunk_paged_merged,
                                fast_path=True)
backends.register_chunk_backend("paged_q8", "generic",
                                _chunk_paged_q8_generic)
backends.register_chunk_backend("paged_q8", "merged", _chunk_paged_q8_merged,
                                fast_path=True)


def forward_prefill_chunk(params, cfg: ModelConfig, chunk, dest, *,
                          start, true_len, impl: str = "xla",
                          qkv_sharding=None, max_len: Optional[int] = None):
    """One fixed-size prefill chunk against the SERVING cache — the single
    dispatcher over the ``models.backends`` CHUNK registry.

    ``chunk`` is (1, C) int32: tokens [start, start+C) of ONE stream's
    prompt, right-padded past ``true_len`` on the final chunk.  ``start``
    and ``true_len`` are (1,) int32 (traced — one compiled program serves
    every chunk of every prompt).  Returns (last_logits (1, V) — the
    prompt's last real position, meaningful on the final chunk — plus the
    filled destination, mirroring ``forward_prefill``):

    * ``DenseChunkDest(cache, slot)`` — writes rows [start, start+C) of
      batch row ``slot`` in place and returns the updated ``DecodeCache``.
      Sliding-window dense configs are rejected (the window-sized ring
      cache can't hold a partial prompt at absolute positions; the
      scheduler falls back to its monolithic whole-prompt path there).
    * ``PagedChunkDest(k_pool, v_pool, block_table, block_ids)`` — writes
      the chunk's pages and returns (k_pool, v_pool).  C must be a
      multiple of the block size; ring (windowed) tables additionally pin
      C == block_size so every chunk occupies exactly one ring slot.

    Attention-only stacks only (ssm/hybrid state has no mid-prompt
    checkpoint; vlm interleaves cross-attention).  MoE FFNs route
    dropless, like decode.  The chunk programs use the positions-based
    XLA attention cores internally for every impl — the flash kernels
    assume arange positions — so a fused chunk kernel is follow-up work;
    weight-side fast-path structure (no Q/P reads when merged) is intact
    and jaxpr-asserted by the lint sweep's chunk phase.
    """
    B, C = int(chunk.shape[0]), int(chunk.shape[1])
    if B != 1:
        raise ValueError(f"chunked prefill feeds one stream at a time, got "
                         f"batch size {B}")
    plan = layer_plan(cfg)
    if plan["kind"] != "attn":
        raise ValueError(
            f"chunked prefill supports attention-only stacks, not "
            f"{plan['kind']!r} (family {cfg.family!r})")
    if isinstance(dest, (PagedChunkDest, PagedQ8ChunkDest)):
        kind = "paged_q8" if isinstance(dest, PagedQ8ChunkDest) else "paged"
        bs = int(dest.k_pool.shape[2])
        MB = int(dest.block_table.shape[1])
        if C % bs:
            raise ValueError(f"chunk width {C} must be a multiple of the "
                             f"page size {bs}")
        if paging.paged_ring_active(cfg.sliding_window, bs, MB) and C != bs:
            raise ValueError(
                f"ring (sliding-window) paged chunks must be exactly one "
                f"block: chunk width {C} != block size {bs}")
        if int(dest.block_ids.shape[0]) != C // bs:
            raise ValueError(
                f"{type(dest).__name__}.block_ids maps "
                f"{int(dest.block_ids.shape[0])} blocks; a {C}-token chunk "
                f"over {bs}-token pages needs {C // bs}")
    elif isinstance(dest, DenseChunkDest):
        kind = "dense"
        # a BINDING window (window < max_len) makes the dense cache a
        # window-sized ring buffer, which can't park a partial prompt at
        # absolute positions; a window >= max_len never masks or wraps
        # anything and chunks exactly like window=0.  The cache alone
        # can't distinguish the two (Sc = min(max_len, window)), so the
        # static ``max_len`` hint carries the check — the serving adapter
        # always passes it, and the scheduler routes binding-window dense
        # requests through its monolithic whole-prompt fallback instead.
        if cfg.sliding_window and max_len is not None \
                and cfg.sliding_window < max_len:
            raise ValueError(
                "dense sliding-window chunked prefill is unsupported (the "
                "window-sized ring cache can't park a partial prompt at "
                "absolute positions); use the scheduler's monolithic "
                "fallback or the paged cache")
    else:
        raise ValueError(
            f"unknown chunk destination {type(dest).__name__!r}; expected "
            "DenseChunkDest, PagedChunkDest, or PagedQ8ChunkDest (or "
            "register a ChunkBackend for a new cache kind)")

    backend = backends.get_chunk_backend(kind, prefill_style_key(cfg), impl)
    ctx = {"start": jnp.asarray(start, jnp.int32).reshape(1),
           "true_len": jnp.asarray(true_len, jnp.int32).reshape(1),
           "impl": impl, "qkv_sharding": qkv_sharding}
    return backend.run(params, cfg, chunk, dest, ctx)
