"""Token-choice top-k Mixture-of-Experts FFN (GShard/Switch style).

Design targets (1000+ node fleet):
  * expert weights carry a leading ``experts`` axis -> sharded over the
    ``model`` mesh axis (expert parallelism); dispatch/combine einsums induce
    the all-to-all-style resharding in GSPMD.
  * dispatch is computed GROUP-WISE (static ``group_size`` tokens per group,
    scanned) so the one-hot dispatch tensor is O(group × E × capacity), never
    O(tokens × E × capacity).
  * capacity-factor token dropping (standard at scale); dropped tokens pass
    through with zero FFN delta (their residual/stream value is preserved by
    the block, matching production MoE semantics).
  * aux load-balance loss (Switch: E * Σ_e fraction_e · prob_e) is returned
    so the trainer can add it.

Under the paper's merged form (Fig 1b applied to MoE) the shared P matrix is
folded into EVERY expert's input matrices (same shapes — P·W_e is d×f like
W_e), so QP removal is exact for MoE too; see core/merge.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d_in: int, d_ff: int, d_out: int, n_experts: int,
             ffn_type: str, dtype=jnp.float32):
    kr, kg, ku, kd = jax.random.split(key, 4)
    if ffn_type not in ("swiglu", "geglu"):
        raise ValueError("MoE experts use GLU FFNs in this framework")

    def stack(k, fan_in, fan_out):
        keys = jax.random.split(k, n_experts)
        return jnp.stack([dense_init(ki, fan_in, fan_out, dtype) for ki in keys])

    return {
        "router": dense_init(kr, d_in, n_experts, jnp.float32),
        "w_gate": stack(kg, d_in, d_ff),  # (E, d_in, f)
        "w_up": stack(ku, d_in, d_ff),
        "w_down": stack(kd, d_ff, d_out),  # (E, f, d_out)
    }


def _capacity(group_size: int, n_experts: int, k: int, factor: float) -> int:
    cap = int(group_size * k * factor / n_experts)
    return max(cap, 1)


def apply_moe(
    params,
    x: jnp.ndarray,  # (B, S, d)
    *,
    n_experts: int,
    experts_per_token: int,
    capacity_factor: float = 1.25,
    group_size: int = 2048,
    ffn_type: str = "swiglu",
    dropless: bool = False,
    impl: str = "scatter",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,d), aux_loss scalar).

    ``dropless=True`` (serving/decode): capacity is set to the group size so
    no token is ever dropped — exactness matters at inference and the groups
    are small (one decode step). Training keeps capacity-factor dropping
    (standard at scale).

    ``impl``:
      "scatter" (default) — tokens are routed into the (E, C, d) expert
        buffer with scatter-add and combined back with gathers:
        O(T·k·d) data movement + O(E·C·d·f) expert compute.
      "einsum"  — GShard-style one-hot dispatch/combine einsums. Kept as the
        reference semantics, but its dispatch matmul costs O(g·E·C·d) =
        O(g²·k·cf·d) FLOPs per group — quadratic in group size, and measured
        ~100× the expert FLOPs at production sizes (see EXPERIMENTS.md
        §Perf). Both impls implement identical capacity semantics and are
        tested for exact agreement.
    """
    B, S, d = x.shape
    k = experts_per_token
    E = n_experts
    tokens = B * S
    g = min(group_size, tokens)
    if tokens % g:
        g = tokens  # degenerate small inputs: one group
    n_groups = tokens // g
    cap = g if dropless else _capacity(g, E, k, capacity_factor)

    xf = x.reshape(n_groups, g, d)
    act = jax.nn.silu if ffn_type == "swiglu" else jax.nn.gelu

    w_gate = params["w_gate"]
    w_up = params["w_up"]
    w_down = params["w_down"]
    router = params["router"]

    def _route(xg):
        """Shared routing: returns (gate_vals, idx, slot, keep, aux)."""
        logits = (xg.astype(jnp.float32) @ router)  # (g, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)  # (g, k)
        # renormalize the chosen gates (standard for top-k routing)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (g, k, E)
        # position of each (token, choice) in its expert queue, priority by
        # (choice rank, token order):
        flat = onehot.transpose(1, 0, 2).reshape(k * g, E)  # choice-major
        pos_flat = jnp.cumsum(flat, axis=0) - flat  # (k*g, E)
        pos = pos_flat.reshape(k, g, E).transpose(1, 0, 2)  # (g, k, E)
        within_cap = (pos < cap) & (onehot > 0)
        slot = jnp.einsum("gke,gke->gk", pos, onehot.astype(pos.dtype))
        slot = jnp.clip(slot, 0, cap - 1).astype(jnp.int32)
        keep = jnp.any(within_cap, axis=-1)  # (g, k)

        frac = jnp.mean(onehot[:, 0, :], axis=0)  # top-1 routing fraction
        mean_prob = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(frac * mean_prob)
        return gate_vals, idx, slot, keep, onehot, aux

    def _experts(expert_in, cdt):
        """(E, C, d) -> (E, C, d) through the per-expert GLU FFN."""
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, w_gate.astype(cdt)))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, w_up.astype(cdt))
        return jnp.einsum("ecf,efd->ecd", h, w_down.astype(cdt))

    def one_group_scatter(xg):  # (g, d)
        gate_vals, idx, slot, keep, _, aux = _route(xg)
        cdt = xg.dtype
        # destination bin of each (token, choice): expert*C + slot; dropped
        # pairs go to an overflow row that is sliced away
        dest = jnp.where(keep, idx * cap + slot, E * cap).reshape(g * k)
        x_rep = jnp.repeat(xg, k, axis=0)  # (g*k, d) — token per choice
        buf = jnp.zeros((E * cap + 1, d), cdt).at[dest].add(x_rep)
        expert_in = buf[:E * cap].reshape(E, cap, d)
        eo = _experts(expert_in, cdt)
        # combine: gather each pair's expert output, weight, sum over k
        pair_out = eo.reshape(E * cap, d)[jnp.clip(dest, 0, E * cap - 1)]
        w = (gate_vals * keep.astype(jnp.float32)).reshape(g * k, 1)
        out = jnp.sum((pair_out.astype(jnp.float32) * w).reshape(g, k, d), axis=1)
        return out.astype(x.dtype), aux

    def one_group_einsum(xg):  # (g, d) — GShard reference (see docstring)
        gate_vals, idx, slot, keep, onehot, aux = _route(xg)
        cdt = xg.dtype
        slot_oh = jax.nn.one_hot(slot, cap, dtype=cdt)
        disp = (onehot * keep[..., None]).astype(cdt)[..., None] * slot_oh[:, :, None, :]
        disp_tok = jnp.sum(disp, axis=1)  # (g, E, C)
        expert_in = jnp.einsum("gec,gd->ecd", disp_tok, xg)
        eo = _experts(expert_in, cdt)
        combine = jnp.einsum("gkec,gk->gec", disp.astype(jnp.float32),
                             gate_vals * keep.astype(jnp.float32))
        out = jnp.einsum("gec,ecd->gd", combine.astype(cdt), eo)
        return out.astype(x.dtype), aux

    one_group = one_group_scatter if impl == "scatter" else one_group_einsum
    if n_groups == 1:
        out, aux = one_group(xf[0])
        return out.reshape(B, S, d)[:], aux
    outs, auxes = jax.lax.map(one_group, xf)
    return outs.reshape(B, S, d), jnp.mean(auxes)
