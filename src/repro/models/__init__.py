from repro.models.transformer import (
    DecodeCache,
    count_params,
    forward_decode,
    forward_prefill,
    forward_seq,
    init_cache,
    init_params,
    layer_plan,
    lm_loss,
)

__all__ = [
    "DecodeCache",
    "count_params",
    "forward_decode",
    "forward_prefill",
    "forward_seq",
    "init_cache",
    "init_params",
    "layer_plan",
    "lm_loss",
]
