"""Backend registries: the seam between the model and its KV cache.

The paper's point is that Q/P-free (KV-weights-only) attention is a *layout
choice*, not a fork of the model code — but a serving stack accumulates
variants along three independent axes:

  cache_kind  how per-token KV is stored: "dense" (per-slot ring buffer,
              ``DecodeCache``), "paged" (block-pool pages behind a block
              table, ``PagedDecodeCache``), or "paged_q8" (the same pages
              quantized to int8 with per-(page, kv-head) scales,
              ``PagedQ8DecodeCache``)
  style       which projections the step reads: "generic" (projects q/k/v
              as the config dictates, covering unmerged models AND the
              kp/vp merged variants whose eliminated projection is an
              identity inside ``_project_qkv``) or "merged" (the qp fast
              path: the residual stream IS the query, no Q or P weights
              exist to read)
  impl        "xla" | "pallas" | "pallas_interpret"

Rather than one hand-wired entry point per combination (PR 1–2 grew four
``_attn_step*`` functions plus a ``forward_decode``/``forward_decode_paged``
pair; PR 3's ``forward_prefill`` branched the same three axes inline),
every combination is a registered backend, and BOTH serving phases have a
single dispatcher looking their route up here:

  * decode — :class:`AttentionBackend` (a per-layer, per-token attention
    step) behind ``models.transformer.forward_step``;
  * prefill — :class:`PrefillBackend` (a whole-sequence prefill program:
    run the stack, collect KV, write it into the destination cache)
    behind ``models.transformer.forward_prefill``.

Registering a new backend (e.g. a quantized-cache kind or a fused step for
a new merged variant) is::

    from repro.models import backends

    def my_step(lp, cfg, u1, k_store, v_store, ctx):
        # u1 (B,1,d) stream; k_store/v_store in the cache kind's layout;
        # ctx carries "length", "impl", "qkv_sharding" and the cache
        # kind's addressing ("kv_pos" dense / "block_tables" paged).
        ...
        return cat, new_k_store, new_v_store

    backends.register_backend("mykind", "generic", my_step)

    def my_prefill(params, cfg, inputs, dest, ctx):
        # dest is the cache-kind's destination (``DensePrefillDest`` /
        # ``PagedPrefillDest`` / your own); ctx carries "vision", "impl",
        # "unroll", "qkv_sharding", "true_len".
        ...
        return last_logits, filled_dest

    backends.register_prefill_backend("mykind", "generic", my_prefill)

A third phase shares the key space: *chunk* — a fixed-size slice of a
prompt prefilled in place (``ChunkBackend`` behind
``models.transformer.forward_prefill_chunk``).  Chunked prefill is what the
continuous-batching scheduler (``repro.serving.sched``) interleaves with
decode: one compiled program per cache kind processes chunk ``[start,
start+C)`` of a single stream against the batched cache/pool, so admission
never stalls in a whole-prompt prefill.  Register with
``register_chunk_backend("mykind", "generic", my_chunk_run)`` where
``my_chunk_run(params, cfg, chunk, dest, ctx) -> (last_logits, dest')``.

Steps take ``impl`` from ``ctx`` so one function usually serves every impl
key; all ``register_*`` helpers register all three impls by default.
Lookups of unregistered combinations fail loudly with the list of
registered keys — there is no silent fallback path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

CACHE_KINDS = ("dense", "paged", "paged_q8")
STYLES = ("generic", "merged")
IMPLS = ("xla", "pallas", "pallas_interpret")

# step(lp, cfg, u1, k_store, v_store, ctx) -> (cat, new_k_store, new_v_store)
StepFn = Callable[..., Tuple]


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One registered (cache_kind, style, impl) decode-attention route.

    ``fast_path`` is True when the per-token step reads no Q or P weights
    (the paper's merged qp layout cashed in at serve time); the engine
    surfaces it as ``Engine.merged_fast_path``.
    """
    cache_kind: str
    style: str
    impl: str
    step: StepFn
    fast_path: bool = False

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.cache_kind, self.style, self.impl)


_REGISTRY: Dict[Tuple[str, str, str], AttentionBackend] = {}


def register_backend(cache_kind: str, style: str, step: StepFn, *,
                     impls: Tuple[str, ...] = IMPLS,
                     fast_path: bool = False) -> None:
    """Register ``step`` under (cache_kind, style) for each impl in
    ``impls``.  Re-registration overwrites (latest wins), so downstream
    code can swap in a tuned backend without forking the model."""
    for impl in impls:
        _REGISTRY[(cache_kind, style, impl)] = AttentionBackend(
            cache_kind=cache_kind, style=style, impl=impl, step=step,
            fast_path=fast_path)


def get_backend(cache_kind: str, style: str, impl: str) -> AttentionBackend:
    """Look up the backend for one combo; unknown combos raise KeyError
    naming the offending key and every registered one (no silent
    fallback)."""
    key = (cache_kind, style, impl)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"no AttentionBackend registered for (cache_kind={cache_kind!r}, "
            f"style={style!r}, impl={impl!r}); registered combos: "
            f"{registered_backends()}") from None


def registered_backends() -> List[Tuple[str, str, str]]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# prefill: whole-sequence programs, same (cache_kind, style, impl) key
# ---------------------------------------------------------------------------

# run(params, cfg, inputs, dest, ctx) -> (last_logits, filled destination)
PrefillFn = Callable[..., Tuple]


@dataclasses.dataclass(frozen=True)
class PrefillBackend:
    """One registered (cache_kind, style, impl) prefill route.

    Unlike decode (a per-layer step), a prefill backend is the whole
    program: run the stack over the prompt, collect per-layer KV, and
    write it into ``dest`` — a ``DecodeCache`` under construction for
    "dense", mapped pool pages for "paged".  ``fast_path`` is True when
    the program reads no Q or P weights (the paper's merged qp layout
    cashed in at prefill time); the engine surfaces it as
    ``Engine.merged_prefill_fast_path``.
    """
    cache_kind: str
    style: str
    impl: str
    run: PrefillFn
    fast_path: bool = False

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.cache_kind, self.style, self.impl)


_PREFILL_REGISTRY: Dict[Tuple[str, str, str], PrefillBackend] = {}


def register_prefill_backend(cache_kind: str, style: str, run: PrefillFn, *,
                             impls: Tuple[str, ...] = IMPLS,
                             fast_path: bool = False) -> None:
    """Register ``run`` under (cache_kind, style) for each impl in
    ``impls``.  Re-registration overwrites (latest wins)."""
    for impl in impls:
        _PREFILL_REGISTRY[(cache_kind, style, impl)] = PrefillBackend(
            cache_kind=cache_kind, style=style, impl=impl, run=run,
            fast_path=fast_path)


def get_prefill_backend(cache_kind: str, style: str,
                        impl: str) -> PrefillBackend:
    """Look up the prefill backend for one combo; unknown combos raise
    KeyError naming the offending key and every registered one (no silent
    fallback)."""
    key = (cache_kind, style, impl)
    try:
        return _PREFILL_REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"no PrefillBackend registered for (cache_kind={cache_kind!r}, "
            f"style={style!r}, impl={impl!r}); registered prefill combos: "
            f"{registered_prefill_backends()}") from None


def registered_prefill_backends() -> List[Tuple[str, str, str]]:
    return sorted(_PREFILL_REGISTRY)


# ---------------------------------------------------------------------------
# chunk: fixed-size prompt-slice prefill programs, same key space
# ---------------------------------------------------------------------------

# run(params, cfg, chunk, dest, ctx) -> (last_logits, filled destination)
ChunkFn = Callable[..., Tuple]


@dataclasses.dataclass(frozen=True)
class ChunkBackend:
    """One registered (cache_kind, style, impl) chunked-prefill route.

    A chunk backend runs the stack over ONE fixed-size slice ``[start,
    start+C)`` of a single prompt, attending to everything the slot has
    accumulated so far (earlier chunks + the slice itself), and writes the
    slice's KV into the batched cache / pool pages in place.  The static
    chunk width C means one compiled program serves every chunk of every
    prompt — the scheduler never pays a bucket compile at admission.
    ``fast_path`` is True when the program reads no Q or P weights (the
    merged qp layout cashed in chunk-by-chunk).
    """
    cache_kind: str
    style: str
    impl: str
    run: ChunkFn
    fast_path: bool = False

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.cache_kind, self.style, self.impl)


_CHUNK_REGISTRY: Dict[Tuple[str, str, str], ChunkBackend] = {}


def register_chunk_backend(cache_kind: str, style: str, run: ChunkFn, *,
                           impls: Tuple[str, ...] = IMPLS,
                           fast_path: bool = False) -> None:
    """Register ``run`` under (cache_kind, style) for each impl in
    ``impls``.  Re-registration overwrites (latest wins)."""
    for impl in impls:
        _CHUNK_REGISTRY[(cache_kind, style, impl)] = ChunkBackend(
            cache_kind=cache_kind, style=style, impl=impl, run=run,
            fast_path=fast_path)


def get_chunk_backend(cache_kind: str, style: str, impl: str) -> ChunkBackend:
    """Look up the chunk backend for one combo; unknown combos raise
    KeyError naming the offending key and every registered one (no silent
    fallback)."""
    key = (cache_kind, style, impl)
    try:
        return _CHUNK_REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"no ChunkBackend registered for (cache_kind={cache_kind!r}, "
            f"style={style!r}, impl={impl!r}); registered chunk combos: "
            f"{registered_chunk_backends()}") from None


def registered_chunk_backends() -> List[Tuple[str, str, str]]:
    return sorted(_CHUNK_REGISTRY)
