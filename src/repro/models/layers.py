"""Shared layer primitives: dtypes, inits, norms, RoPE, embeddings.

All modules in this framework are pure functions over pytree params:
``init_x(key, ...) -> params`` and ``apply_x(params, inputs, ...) -> out``.
No module framework is used (flax is unavailable in the target container and
pure pytrees keep the lowered HLO fully under our control).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "float64": jnp.float64,
}


def dtype_of(name: str):
    return _DTYPES[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, fan_in: int, fan_out: int, dtype=jnp.float32, scale: float = 1.0):
    """Lecun-normal style init, variance 1/fan_in (times scale^2)."""
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, (fan_in, fan_out)) * std).astype(dtype)


def orthogonal_init(key, fan_in: int, fan_out: int, dtype=jnp.float32,
                    scale: float = 1.0):
    """(Semi-)orthogonal init: exactly norm-preserving linear maps.

    The natural init for skipless stacks (no residual to re-center scale;
    see He et al.) — and it makes every Q/K/V well-conditioned (cond ≈ 1),
    which keeps the paper's merged form numerically pristine at runtime
    (the (u·Q)(Q⁻¹K) error scales with cond(Q)·eps)."""
    big = max(fan_in, fan_out)
    a = jax.random.normal(key, (big, min(fan_in, fan_out)))
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diagonal(r))[None, :]  # fix sign convention
    w = q[:fan_in, :fan_out] if fan_in >= fan_out else q[:fan_out, :fan_in].T
    return (w * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(d_rot: int, theta: float) -> np.ndarray:
    """inv_freq for a rotated sub-dimension of size d_rot (must be even)."""
    assert d_rot % 2 == 0
    return 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float64) / d_rot))


def rope_cos_sin(positions: jnp.ndarray, d_rot: int, theta: float):
    """positions (...,) int32 -> cos/sin of shape (..., d_rot//2), fp32."""
    inv_freq = jnp.asarray(rope_frequencies(d_rot, theta), jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., d_rot/2)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    style: str = "half",
    theta: float = 10_000.0,
    fraction: float = 1.0,
) -> jnp.ndarray:
    """Apply rotary embedding.

    x: (..., seq, n_heads, d_head); positions: broadcastable to (..., seq).
    style "half": llama layout — rotate (x1, x2) = split-in-half pairs.
    style "chatglm2d": interleaved-pair layout on the first ``fraction`` of
      d_head (ChatGLM's partial 2D rotary); remainder passes through.
    style "none": identity.
    """
    if style == "none":
        return x
    d_head = x.shape[-1]
    d_rot = int(d_head * fraction)
    d_rot -= d_rot % 2
    cos, sin = rope_cos_sin(positions, d_rot, theta)  # (..., seq, d_rot/2)
    # broadcast over the heads axis: (..., seq, 1, d_rot/2)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    xr32 = xr.astype(jnp.float32)
    if style == "half":
        x1, x2 = jnp.split(xr32, 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    elif style == "chatglm2d":
        x1 = xr32[..., 0::2]
        x2 = xr32[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        out = jnp.stack([r1, r2], axis=-1).reshape(xr32.shape)
    else:
        raise ValueError(f"unknown rope style {style!r}")
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if d_rot < d_head else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": embed_init(key, vocab, dim, dtype)}


def apply_embedding(params, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16):
    return params["table"].astype(compute_dtype)[tokens]


def apply_unembedding(params, x: jnp.ndarray):
    """Logits in fp32 (loss numerics) WITHOUT materializing an fp32 copy of
    the (V, d) table: multiply in the table's dtype, accumulate fp32
    (preferred_element_type). With bf16 serving weights this saves a
    V·d·4-byte temp per step (§Perf H7 diagnosis)."""
    t = params["table"]
    return jnp.einsum("...d,vd->...v", x.astype(t.dtype), t,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# hubert-style depthwise conv positional embedding (encoder, rope_style none)
# ---------------------------------------------------------------------------

def init_conv_pos(key, dim: int, width: int, dtype=jnp.float32):
    # depthwise conv: (width, 1, dim) feature-group-count = dim
    std = 1.0 / np.sqrt(width)
    k = (jax.random.normal(key, (width, 1, dim)) * std).astype(dtype)
    return {"kernel": k, "bias": jnp.zeros((dim,), dtype)}


def apply_conv_pos(params, x: jnp.ndarray):
    """x (B, S, D) -> x + gelu(depthwise_conv(x)) (wav2vec2 positional conv)."""
    dt = x.dtype
    dim = x.shape[-1]
    width = params["kernel"].shape[0]
    pad = (width // 2, width - 1 - width // 2)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        params["kernel"].astype(jnp.float32),
        window_strides=(1,),
        padding=(pad,),
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=dim,
    )
    y = jax.nn.gelu(y + params["bias"].astype(jnp.float32))
    return x + y.astype(dt)
