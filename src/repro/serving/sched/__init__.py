"""Continuous-batching scheduler: token-budget iteration plans that
interleave fixed-width chunk-prefill with batched decode (see
``sched.plan`` for the policy, ``sched.engine`` for the execution)."""
from repro.serving.sched.engine import ScheduledEngine
from repro.serving.sched.plan import (ChunkPlan, PrefillJob, SchedConfig,
                                      Schedule, plan_iteration)

__all__ = [
    "ChunkPlan",
    "PrefillJob",
    "SchedConfig",
    "Schedule",
    "ScheduledEngine",
    "plan_iteration",
]
