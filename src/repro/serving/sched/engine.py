"""ScheduledEngine: continuous batching with chunked prefill.

The base ``Engine.submit`` runs a whole-prompt, batch-of-1 prefill
SYNCHRONOUSLY at admission: every arrival freezes all in-flight decode
streams for a full (bucket-compiled) prefill.  ``ScheduledEngine`` splits
the two halves of submit apart:

  * ``submit`` only ENQUEUES — validation, rid assignment (arrival
    order), and a :class:`~repro.serving.sched.plan.PrefillJob` on the
    waiting queue.  No device work; no prefill program ever traces on
    the submit path (lint: ``NoSyncPrefillInSubmit``).
  * ``step`` runs one planned ITERATION: admit waiting jobs into free
    slots (FCFS; resumes first), ask the planner for this iteration's
    decode/chunk mix under the token budget, execute the chunks, run the
    base batched decode step, then activate newly-completed prefills.

Chunks execute against the SHARED batched cache while other slots keep
decoding; mid-prefill slots are protected per cache kind (dense: host
lengths park the decode write at the chunk frontier; paged: the slot's
table row ships masked to -1 so decode writes drop — see
``serving.adapters``).  Activation happens AFTER the iteration's decode
dispatched: an unshielded slot sharing its trailing partial page with a
live request must not take decode writes until ``_make_appendable`` has
had a chance to copy-on-write that page (next iteration, once active).

Adapters that cannot chunk (dense with a BINDING sliding window — the
ring cache holds no partial prompt) fall back to monolithic whole-prompt
jobs: admission is still asynchronous and budget-charged, the prefill is
just unsplittable.

Token identity: chunked prefill writes bit-identical KV to whole-prompt
prefill (``tests/test_sched.py`` pins the full backend grid), per-request
PRNG streams key off the same (seed, rid) fold, and rids are assigned in
arrival order — so greedy AND sampled continuations match the synchronous
engine exactly.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layer_plan
from repro.serving.adapters import KVCacheAdapter
from repro.serving.engine import (Engine, Request, RequestResult, ServeConfig,
                                  _result_of, _timings_of)
from repro.serving.sched.plan import (ChunkPlan, PrefillJob, SchedConfig,
                                      Schedule, plan_iteration)


class ScheduledEngine(Engine):
    """Engine with queue admission + per-iteration chunk/decode plans."""

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig,
                 scfg: Optional[SchedConfig] = None, mesh=None,
                 impl: str = "xla",
                 cache: Union[None, str, KVCacheAdapter] = None):
        super().__init__(cfg, params, sc, mesh=mesh, impl=impl, cache=cache)
        self.scfg = scfg if scfg is not None else SchedConfig()
        # chunk programs are attention-only (ssm/hybrid state has no
        # mid-prompt checkpoint; vlm interleaves cross-attention) — other
        # families and binding-window dense fall back to monolithic jobs
        self._chunked = (self.kv.supports_chunked
                         and layer_plan(cfg)["kind"] == "attn")
        if self._chunked:
            if sc.max_len % self.scfg.chunk_tokens:
                raise ValueError(
                    f"max_len ({sc.max_len}) must be a multiple of "
                    f"chunk_tokens ({self.scfg.chunk_tokens}): the final "
                    f"chunk's padded tail may not write past the cache")
            self.kv.enable_chunked()
            psh, csh, qkv_sh = self._shardings
            self.kv.build_chunk(self.scfg.chunk_tokens, self.impl,
                                mesh=self.mesh, params_sharding=psh,
                                cache_shardings=csh, qkv_sharding=qkv_sh)
        self.waiting: List[PrefillJob] = []  # FCFS; resumes at the front
        self.prefilling: List[PrefillJob] = []  # admitted, chunks landing
        self.last_schedule: Optional[Schedule] = None
        self._progress = True
        self.n_iterations = 0  # always-on (obs-off) planner telemetry
        self.n_chunks_run = 0

    @property
    def stats(self) -> Dict[str, int]:
        s = Engine.stats.fget(self)  # type: ignore[attr-defined]
        s["sched_iterations"] = self.n_iterations
        s["sched_chunks"] = self.n_chunks_run
        return s

    # ------------------------------------------------------------------
    def submit(self, req: Request,
               vision: Optional[np.ndarray] = None) -> bool:
        """Enqueue ONLY — no prefill runs here (the whole point).  Always
        returns True: admission control moved into ``step``, where a full
        pool defers the queue head instead of bouncing the caller."""
        if vision is not None:
            raise ValueError(
                "ScheduledEngine is attention-only (no vision prefill); "
                "use the base Engine for vlm serving")
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        if self.paged or not self.cfg.sliding_window:
            if len(req.prompt) + req.max_new_tokens > self.sc.max_len:
                raise ValueError(
                    f"prompt ({len(req.prompt)}) + max_new_tokens "
                    f"({req.max_new_tokens}) exceeds max_len "
                    f"({self.sc.max_len})")
        # rid at ENQUEUE, in arrival order — the same (seed, rid) PRNG
        # fold the synchronous engine would assign at its submit
        if req.rid < 0:
            req.rid = self._rid
            self._rid += 1
        resume = bool(req.out_tokens)
        toks = np.asarray(req.prompt, np.int32)
        if resume and len(req.out_tokens) > 1:
            toks = np.concatenate(
                [toks, np.asarray(req.out_tokens[:-1], np.int32)])
        job = PrefillJob(req=req, toks=toks, resume=resume,
                         monolithic=not self._chunked)
        if resume:  # resumes have progress: highest priority
            self.waiting.insert(0, job)
        else:
            self.waiting.append(job)
        return True

    # ------------------------------------------------------------------
    def _admit(self) -> int:
        """Grant free slots to waiting jobs, strictly FCFS: a deferred
        head (pool exhausted) blocks everything behind it — skipping
        ahead is what starves the head."""
        n = 0
        while self.waiting and self.free_slots:
            job = self.waiting[0]
            slot = self.free_slots[0]
            if job.monolithic:
                n_shared = self.kv.admit(slot, job.toks)
            else:
                n_shared = self.kv.admit_chunked(slot, job.toks)
            if n_shared is None:
                self._c_deferred.inc()
                break
            self.free_slots.pop(0)
            self.waiting.pop(0)
            job.slot, job.n_shared = slot, n_shared
            job.cursor = 0
            job.t_slot = self.obs.clock()
            self.prefilling.append(job)
            n += 1
        return n

    def _self_preempt(self, job: PrefillJob) -> None:
        """A chunk's pages cannot map and no decoder is left to evict:
        give this prefill's resources back and retry from scratch once
        something else releases (stall detection catches the pathological
        single-occupant case)."""
        self.kv.release(job.slot)
        job.slot = -1
        job.cursor = 0
        self.waiting.insert(0, job)
        self.prefilling = [j for j in self.prefilling if j is not job]
        self._c_deferred.inc()

    def _run_chunk(self, cp: ChunkPlan):
        """Execute one planned chunk; returns the (1, V) logits of the
        chunk's last real position, or None if the job self-preempted."""
        job = cp.job
        if job.monolithic:
            padded, n = self._bucket_pad(job.toks)
            logits = self.kv.prefill(
                self.params, job.slot,
                self.host_to_device(padded, np.int32)[None], n,
                job.n_shared, None)
            self.kv.set_length(job.slot, n)
            job.cursor = job.total
            return logits
        while not self.kv.chunk_ready(job.slot, cp.start, cp.end):
            if self.active:
                victim = max(self.active,
                             key=lambda s: self.active[s].rid)
                self._preempt(victim)
            else:
                self._self_preempt(job)
                return None
        C = self.scfg.chunk_tokens
        row = np.zeros((C,), np.int32)
        row[:cp.end - cp.start] = job.toks[cp.start:cp.end]
        logits = self.kv.chunk_step(
            self.params, job.slot,
            self.host_to_device(row, np.int32)[None], cp.start, job.total)
        job.cursor = cp.end
        return logits

    def _finish_prefill(self, job: PrefillJob, logits):
        """All of ``job``'s tokens landed: sample/restore the first token
        and stage the request for activation (or finish it outright)."""
        req, slot = job.req, job.slot
        if not job.monolithic:
            self.kv.finish_chunked(slot, job.toks)
        self._slot_keys = self._slot_keys.at[slot].set(
            jnp.asarray(req.key_state) if req.key_state is not None
            else jax.random.fold_in(self.key, req.rid))
        req.slot = slot
        if job.resume:
            tok = req.out_tokens[-1]
        else:
            tok = int(self._sample(logits, [slot])[0])
            req.out_tokens = [tok]
            req.remaining = req.max_new_tokens - 1
            now = time.perf_counter()
            req.t_first = req.t_last = now
        self._last_token[slot] = int(tok)
        self.prefilling = [j for j in self.prefilling if j is not job]
        C = self.scfg.chunk_tokens
        bucket = job.total if job.monolithic else -(-job.total // C) * C
        self.obs.request_admitted(req, slot, n_shared=job.n_shared,
                                  resume=job.resume, bucket_len=bucket,
                                  t_prefill0=job.t_slot)
        if not job.resume and (req.remaining <= 0
                               or tok == self.sc.eos_token):
            # first token already satisfied the budget (or is EOS)
            self.kv.release(slot)
            req.slot = -1
            self.free_slots.append(slot)
            if self.obs.enabled:
                ttft, tok_s = _timings_of(req)
                self.obs.request_finished(req, decode_tok_s=tok_s,
                                          ttft_s=ttft)
            return None
        if job.monolithic:
            # no shield / host-length machinery in play (monolithic ⇒
            # the engine is not in chunked mode): activate NOW — deferred
            # activation would let this iteration's decode advance the
            # parked slot's device length past the inserted prompt
            self.active[slot] = req
            return None
        return (slot, req)

    def step(self) -> Dict[int, int]:
        """One scheduler ITERATION: admit → plan → chunks → decode →
        activate.  Returns slot -> token for the decode portion."""
        while self.preempted:  # re-enter the queue at the front
            self.submit(self.preempted.pop(0))
        t0 = self.obs.clock()
        n_admitted = self._admit()
        schedule = plan_iteration(self.scfg, len(self.active),
                                  self.prefilling)
        self.last_schedule = schedule
        n_chunks = n_chunk_tokens = 0
        activated = []
        for cp in schedule.chunks:
            tc = self.obs.clock()
            logits = self._run_chunk(cp)
            if logits is None:
                continue
            n_chunks += 1
            n_chunk_tokens += cp.cost
            self.obs.chunk_done(cp.job.req, cp.job.slot, cp.start,
                                cp.end - cp.start, tc, self.obs.clock(),
                                final=cp.final)
            if cp.job.done:
                act = self._finish_prefill(cp.job, logits)
                if act is not None:
                    activated.append(act)
        emitted = super().step()
        # activate AFTER the decode dispatched: this iteration's decode
        # program shipped the shielded view, so a shared trailing partial
        # page can't take this slot's writes before CoW sees it
        for slot, req in activated:
            self.active[slot] = req
            self.kv.unshield(slot)
        self._g_peak.set_max(len(self.active))
        self.n_iterations += 1
        self.n_chunks_run += n_chunks
        self.obs.sched_iteration(t0, self.obs.clock(),
                                 n_decode=schedule.n_decode,
                                 n_chunks=n_chunks,
                                 n_chunk_tokens=n_chunk_tokens,
                                 budget_used=schedule.budget_used)
        self._progress = bool(emitted) or n_chunks > 0 or n_admitted > 0
        return emitted

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence[np.ndarray],
                 max_new_tokens: int = 32,
                 vision=None) -> List[RequestResult]:
        """Drain a batch of prompts through the scheduler; same contract
        (and, greedy, the same tokens) as ``Engine.generate``."""
        if vision is not None and any(v is not None for v in vision):
            raise ValueError("ScheduledEngine is attention-only (no vlm)")
        t_gen0 = self.obs.clock()
        t_arrival = time.perf_counter()
        pending = [Request(prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens,
                           t_arrival=t_arrival) for p in prompts]
        results: List[Optional[RequestResult]] = [None] * len(pending)
        order = {id(r): i for i, r in enumerate(pending)}
        for r in pending:
            self.submit(r)
        inflight = list(pending)
        while (self.waiting or self.prefilling or self.active
               or self.preempted):
            self.obs.queue_depth(len(self.waiting) + len(self.prefilling)
                                 + len(self.preempted))
            self.step()
            if not self._progress:
                raise RuntimeError(
                    "serving stalled: no admission, chunk, or decode "
                    "progressed (raise n_blocks/token_budget or shrink "
                    "prompts)")
            for r in list(inflight):
                if r.slot == -1 and r.out_tokens:  # finished
                    results[order[id(r)]] = _result_of(r)
                    # identity removal: Request.__eq__ compares arrays
                    inflight = [x for x in inflight if x is not r]
        if self.obs.enabled:
            self.obs.generate_done(
                t_gen0, self.obs.clock(), n_requests=len(pending),
                n_tokens=sum(r.new_tokens for r in results
                             if r is not None))
        return results  # type: ignore
