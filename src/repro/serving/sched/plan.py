"""Iteration planner for the continuous-batching scheduler.

Everything here is PURE host bookkeeping — no jax, no device state — so
the scheduling policy is unit-testable (and hypothesis-modelable,
``tests/test_sched_properties.py``) without building an engine.

The model (Sarathi/vLLM-style): each engine iteration has a TOKEN BUDGET.
Every active decode slot reserves one token; the leftover admits prefill
CHUNKS — fixed-width slices of queued prompts — in strict FCFS order, at
most ONE chunk per request per iteration.  Strictness is the liveness
argument: the head job never yields to a younger one, so when budget
frees up (actives finish) the head runs first — no request starves.
A job that cannot be split (``monolithic``: dense binding-window configs,
whose ring cache can't hold a partial prompt) charges
``min(total, token_budget)`` — clamped so it can EVER fit; once every
decode drains, the head monolithic job always fits, preserving liveness
at the cost of one oversized iteration.

:func:`plan_iteration` maps (config, active decode count, prefill queue)
to a :class:`Schedule`; the engine executes it and advances each job's
``cursor``.  Invariants the property suite pins:

  * ``budget_used <= token_budget`` whenever ``n_decode <= token_budget``
  * cursors advance monotonically, by exactly one chunk per iteration
  * scheduled chunks are a PREFIX of the (FCFS) queue's unfinished jobs
  * with zero actives, the head job is always scheduled (no starvation)
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """Budget knobs for the continuous-batching scheduler.

    token_budget   max tokens one engine iteration computes: each active
                   decode slot reserves 1, the rest admits prefill chunks
    chunk_tokens   static chunk width C — ONE compiled program serves
                   every chunk of every prompt (`ServeConfig.max_len`
                   must be a multiple; paged caches additionally need a
                   multiple of the block size, ring caches exactly one
                   block)
    """
    token_budget: int = 256
    chunk_tokens: int = 64

    def __post_init__(self):
        if self.chunk_tokens <= 0:
            raise ValueError(
                f"chunk_tokens must be positive, got {self.chunk_tokens}")
        if self.token_budget < self.chunk_tokens:
            raise ValueError(
                f"token_budget ({self.token_budget}) must cover at least "
                f"one chunk ({self.chunk_tokens}) or prefill never runs")


@dataclasses.dataclass(eq=False)  # identity eq: fields hold numpy arrays
class PrefillJob:
    """One queued prompt working its way into the cache chunk by chunk.

    ``toks`` is everything prefill must install (prompt, plus generated
    tokens minus the last on a resume); ``cursor`` is the chunk frontier
    (tokens already landed).  ``slot`` is -1 until admission grants one.
    """
    req: Any  # serving.engine.Request
    toks: np.ndarray
    slot: int = -1
    cursor: int = 0
    monolithic: bool = False
    resume: bool = False
    n_shared: int = 0
    t_slot: float = 0.0  # obs clock at slot grant (queued span ends)

    @property
    def total(self) -> int:
        return len(self.toks)

    @property
    def done(self) -> bool:
        return self.cursor >= self.total


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """One scheduled chunk: run ``job.toks[start:end]`` into ``job.slot``.
    ``cost`` is the budget charge (the full static chunk width — padded
    final chunks still compute C token positions; monolithic jobs charge
    their clamped whole length).  ``final`` marks the chunk that
    completes the prompt (its logits seed the first sampled token)."""
    job: PrefillJob
    start: int
    end: int
    cost: int
    final: bool


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One iteration's plan: ``n_decode`` reserved decode tokens plus an
    FCFS-prefix of prefill chunks, with the budget accounting that
    admitted them."""
    n_decode: int
    chunks: List[ChunkPlan]
    budget: int
    budget_used: int


def plan_iteration(scfg: SchedConfig, n_decode: int,
                   jobs: List[PrefillJob]) -> Schedule:
    """Plan one engine iteration.

    ``jobs`` is the admitted prefill queue in arrival (FCFS) order; each
    holds a slot.  Walks the queue strictly front-to-back, scheduling at
    most one chunk per job, and STOPS at the first job whose chunk does
    not fit the remaining budget (head-blocking — skipping ahead is what
    starves the head).
    """
    used = n_decode  # one token per active decode slot
    chunks: List[ChunkPlan] = []
    for job in jobs:
        if job.done:
            continue
        assert job.slot >= 0, "planner only sees admitted jobs"
        if job.monolithic:
            cost = min(job.total, scfg.token_budget)
            end = job.total
        else:
            cost = scfg.chunk_tokens
            end = min(job.cursor + scfg.chunk_tokens, job.total)
        if used + cost > scfg.token_budget:
            break
        used += cost
        chunks.append(ChunkPlan(job=job, start=job.cursor, end=end,
                                cost=cost, final=end >= job.total))
    return Schedule(n_decode=n_decode, chunks=chunks,
                    budget=scfg.token_budget, budget_used=used)
