"""Radix tree over block-aligned token runs: the paged prefix cache.

``PagedCacheManager`` used to keep a flat ``Dict[token-prefix, page]``
registry: one full token-tuple key PER REGISTERED BLOCK, so a distinct
L-token prompt cost O(L²/bs) host bytes to register and every
``_match_prefix`` re-sliced O(L²/bs) tuple prefixes — and an entry died
with its page's last sharer, so a hot system prompt was recomputed for
every request lifetime.  This module replaces it (SGLang-style):

  * **Structure** — a path-compressed tree whose edges are block-aligned
    token RUNS.  A node holds one resident page per block of its run,
    children keyed by the first block (``bs`` tokens) of their run, and
    a ``tails`` dict of PARTIAL trailing blocks (registered under the
    leftover sub-block tokens, matched only on an exact whole-prompt
    hit — the flat registry's semantics, kept bit-for-bit).  Matching an
    L-token prompt walks L tokens once: O(L) time, and resident state is
    O(tokens actually cached), not O(L²/bs).

  * **Retention** — the tree holds NO refcount while any live slot maps
    a page (refcounts stay exactly "number of live sharers", as before).
    When the LAST sharer releases, the manager ADOPTS every
    tree-referenced page (``BlockAllocator.retain`` — the tree becomes a
    holder) instead of freeing it, so popular prefixes persist across
    request lifetimes.  Invariant: ``ref[p] == live slots mapping p +
    (1 if p in tree.retained else 0)``.

  * **Eviction** — retained pages are reclaimable: under pool pressure
    the manager asks ``evict(need, evictable)`` for LRU leaf-END pages
    whose only reference is the tree's (a live sharer pins its whole
    prefix chain, so interior pages of anything in use are never
    candidates).  Tails go before their node's last block page; a node
    emptied of pages is unlinked.  ``drop_page`` (ring recycle of a
    registered page whose bytes are being rewritten) removes the page
    AND its now-unreachable subtree, returning any retained descendants
    for the manager to release — registry state can never outlive the
    bytes it describes.

The tree never touches device memory: eviction and retention move page
IDs between host-side sets; the pages' bytes (and, for ``paged_q8``,
their scale rows) are simply left in place until a future write claims
the page through the normal alloc path.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Set, Tuple

TokenRun = Tuple[int, ...]


class _Node:
    """One path-compressed edge: ``run`` is a block-aligned token run,
    ``pages[i]`` the resident page of its i-th block.  ``children`` is
    keyed by the first block (``bs`` tokens) of each child's run;
    ``tails`` maps a partial (sub-block) trailing token run — attached
    at the END of this node's run — to its page."""

    __slots__ = ("run", "pages", "children", "tails", "parent", "last_used")

    def __init__(self, run: TokenRun, pages: List[int],
                 parent: Optional["_Node"]):
        self.run = run
        self.pages = pages
        self.children: Dict[TokenRun, "_Node"] = {}
        self.tails: Dict[TokenRun, int] = {}
        self.parent = parent
        self.last_used = 0

    @property
    def empty(self) -> bool:
        return not (self.pages or self.children or self.tails)


class RadixPrefixTree:
    """Block-aligned radix prefix cache (module docstring).

    The manager owns the lifecycle; the tree only answers:
      ``match``      longest resident chain covering a prompt prefix
      ``insert``     register a prompt's pages (first registration wins)
      ``drop_page``  page bytes rewritten: remove it + its subtree
      ``evict``      reclaim LRU retained leaf-end pages under pressure
      ``references`` is this page resident in the tree?
    """

    def __init__(self, block_size: int):
        self.bs = block_size
        self.root = _Node((), [], None)
        # page -> (node, where): where is an int block index into
        # node.pages, or the TokenRun key of a tail entry
        self._loc: Dict[int, Tuple[_Node, object]] = {}
        # pages whose ONLY holder may be the tree (adopted at the last
        # sharer's release); the manager keeps ref in lockstep
        self.retained: Set[int] = set()
        self._tick = 0  # LRU clock: bumped per match/insert
        # observability (adapters lift these as lazy gauges)
        self.hit_tokens = 0   # prompt tokens served from resident pages
        self.n_evicted = 0    # retained pages reclaimed under pressure
        self.n_nodes = 0      # live interior/leaf nodes (root excluded)

    # -- lookup ----------------------------------------------------------

    def match(self, tokens) -> Tuple[List[int], int]:
        """Longest chain of resident pages covering a prefix of
        ``tokens``: full blocks by content chain, plus the trailing
        partial block on an exact whole-prompt match.  Returns
        ``(pages, n_covered_tokens)`` and touches the walked nodes'
        LRU stamps."""
        toks = tuple(int(t) for t in tokens)
        nb_full = len(toks) // self.bs
        self._tick += 1
        node, pos = self.root, 0  # pos: blocks consumed within node.run
        pages: List[int] = []
        matched = 0
        while matched < nb_full:
            nxt = toks[matched * self.bs:(matched + 1) * self.bs]
            if pos == len(node.pages):
                child = node.children.get(nxt)
                if child is None:
                    break
                node, pos = child, 0
                node.last_used = self._tick
            if node.run[pos * self.bs:(pos + 1) * self.bs] != nxt:
                break
            pages.append(node.pages[pos])
            pos += 1
            matched += 1
        covered = matched * self.bs
        tail = toks[nb_full * self.bs:]
        if tail and matched == nb_full and pos == len(node.pages):
            # a registered tail always sits at a node boundary (insert
            # splits to create one), so ending mid-run means no tail
            bid = node.tails.get(tail)
            if bid is not None:
                pages.append(bid)
                covered = len(toks)
                node.last_used = self._tick
        return pages, covered

    def references(self, page: int) -> bool:
        return page in self._loc

    # -- registration ----------------------------------------------------

    def insert(self, tokens, blocks: List[int]) -> None:
        """Register ``tokens``'s pages (``blocks[i]`` holds block ``i``;
        a trailing partial block's page is last).  First registration
        wins: blocks already resident under the same token run keep
        their incumbent page — exactly the flat registry's
        ``if key not in registry`` rule (two identical prompts in flight
        register once; the loser's pages just die with their request)."""
        toks = tuple(int(t) for t in tokens)
        nb_full = len(toks) // self.bs
        self._tick += 1
        node, pos = self.root, 0
        i = 0  # blocks consumed
        while i < nb_full:
            nxt = toks[i * self.bs:(i + 1) * self.bs]
            if pos == len(node.pages):
                child = node.children.get(nxt)
                if child is None:
                    run = toks[i * self.bs:nb_full * self.bs]
                    child = _Node(run, list(blocks[i:nb_full]), node)
                    child.last_used = self._tick
                    node.children[nxt] = child
                    self.n_nodes += 1
                    for j, bid in enumerate(child.pages):
                        self._loc[bid] = (child, j)
                    node, pos, i = child, len(child.pages), nb_full
                    break
                node, pos = child, 0
                node.last_used = self._tick
                continue
            if node.run[pos * self.bs:(pos + 1) * self.bs] != nxt:
                node = self._split(node, pos)  # divergence mid-run
                pos = len(node.pages)
                continue
            pos += 1
            i += 1
        tail = toks[nb_full * self.bs:]
        if tail and i == nb_full:
            if pos < len(node.pages):
                # the tail needs a boundary here: split the run so the
                # partial block attaches where the prompt actually ends
                node = self._split(node, pos)
            if tail not in node.tails:  # first registration wins
                node.tails[tail] = blocks[nb_full]
                self._loc[blocks[nb_full]] = (node, tail)
            node.last_used = self._tick

    def _split(self, node: _Node, k: int) -> _Node:
        """Split ``node`` after its first ``k`` blocks; returns the upper
        node (run[:k]).  The lower node keeps the children and tails —
        they attach to the END of the original run."""
        assert node.parent is not None and 0 < k < len(node.pages)
        upper = _Node(node.run[:k * self.bs], node.pages[:k], node.parent)
        upper.last_used = node.last_used
        node.parent.children[node.run[:self.bs]] = upper
        node.run = node.run[k * self.bs:]
        node.pages = node.pages[k:]
        node.parent = upper
        upper.children[node.run[:self.bs]] = node
        self.n_nodes += 1
        for j, bid in enumerate(upper.pages):
            self._loc[bid] = (upper, j)
        for j, bid in enumerate(node.pages):
            self._loc[bid] = (node, j)
        return upper

    # -- removal ---------------------------------------------------------

    def drop_page(self, page: int) -> List[int]:
        """Forget ``page`` (its bytes are being rewritten — ring recycle)
        and everything below it: later blocks of its node, tails, and
        the whole child subtree are unreachable without it (a prefix
        chain must be contiguous from block 0).  Returns the RETAINED
        pages removed — the caller must drop the tree's reference on
        each."""
        loc = self._loc.get(page)
        if loc is None:
            return []
        node, where = loc
        dropped: List[int] = []
        if isinstance(where, int):
            for bid in node.pages[where:]:
                self._loc.pop(bid)
                if bid in self.retained:
                    self.retained.discard(bid)
                    dropped.append(bid)
            node.pages = node.pages[:where]
            node.run = node.run[:where * self.bs]
            dropped += self._drop_below(node)
        else:  # a tail entry: no descendants
            node.tails.pop(where)
            self._loc.pop(page)
            if page in self.retained:
                self.retained.discard(page)
                dropped.append(page)
        self._unlink_if_empty(node)
        return dropped

    def _drop_below(self, node: _Node) -> List[int]:
        """Remove every tail and child subtree under ``node``; returns
        the retained pages removed."""
        dropped: List[int] = []
        for bid in node.tails.values():
            self._loc.pop(bid)
            if bid in self.retained:
                self.retained.discard(bid)
                dropped.append(bid)
        node.tails.clear()
        for child in node.children.values():
            for bid in child.pages:
                self._loc.pop(bid)
                if bid in self.retained:
                    self.retained.discard(bid)
                    dropped.append(bid)
            dropped += self._drop_below(child)
            self.n_nodes -= 1
        node.children.clear()
        return dropped

    def _unlink_if_empty(self, node: _Node) -> _Node:
        """Unlink ``node`` (and any ancestors emptied by that) from the
        tree; returns the first SURVIVING node on the path to the root —
        eviction watches it, since losing its last child may have just
        made its own last page a leaf-end candidate."""
        while node.parent is not None and node.empty:
            parent = node.parent
            for key, child in list(parent.children.items()):
                if child is node:
                    del parent.children[key]
                    self.n_nodes -= 1
                    break
            node = parent
        return node

    # -- eviction --------------------------------------------------------

    def evict(self, need: int,
              evictable: Callable[[int], bool]) -> List[int]:
        """Reclaim up to ``need`` retained pages, LRU leaf-END first:
        only a node's LAST page (and only when the node has no children
        and no tails — nothing below depends on it) or a tail entry is
        a candidate, so a resident chain is always consumed back to
        front and never broken in the middle.  ``evictable(p)`` is the
        manager's refcount guard (tree-only reference); the caller
        releases the returned pages.

        ONE traversal collects every candidate into a heap ordered by
        ``(last_used, tail_first)``; victims pop cheapest-first, each
        re-validated against the live tree (a popped entry may be stale:
        its node shrank or unlinked since the push).  Evicting a page
        EXPOSES at most one new candidate — the node's next page up, or
        the first surviving ancestor's last page once the emptied node
        unlinks — which is pushed as it appears.  Total host work is
        O(nodes + reclaimed·log(candidates)) per call, not one full DFS
        per reclaimed page."""
        out: List[int] = []
        if need <= 0:
            return out
        # entries: (last_used, tail_first, seq, node, where, page) — seq
        # is the traversal/exposure order, so ties pop first-seen-first
        # (matching the old full-scan's DFS first-win) and heapq never
        # compares _Node objects
        heap: List[Tuple[int, int, int, _Node, object, int]] = []
        seq = 0

        def push(node: _Node, where, bid: int, tail_first: int) -> None:
            nonlocal seq
            heapq.heappush(
                heap, (node.last_used, tail_first, seq, node, where, bid))
            seq += 1

        def push_leaf_end(node: _Node) -> None:
            if node.pages and not node.children and not node.tails:
                push(node, len(node.pages) - 1, node.pages[-1], 1)

        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            # the root holds no pages but CAN hold tails (prompts
            # shorter than one block register on the root itself)
            for key, bid in node.tails.items():
                push(node, key, bid, 0)
            push_leaf_end(node)
        while len(out) < need and heap:
            _, _, _, node, where, bid = heapq.heappop(heap)
            if isinstance(where, int):
                live = (not node.children and not node.tails
                        and node.pages and node.pages[-1] == bid)
            else:
                live = node.tails.get(where) == bid
            if not live or bid not in self.retained or not evictable(bid):
                # stale entry, or pinned by a live sharer — a pinned
                # leaf-end stays put and (as before) shields the rest
                # of its node's chain for the duration of this call
                continue
            if isinstance(where, int):
                node.pages.pop()
                node.run = node.run[:len(node.pages) * self.bs]
            else:
                node.tails.pop(where)
            self._loc.pop(bid)
            self.retained.discard(bid)
            push_leaf_end(self._unlink_if_empty(node))
            self.n_evicted += 1
            out.append(bid)
        return out

    # -- introspection ---------------------------------------------------

    @property
    def n_pages(self) -> int:
        """Resident pages (retained or live-shared)."""
        return len(self._loc)

    def pages(self) -> Set[int]:
        return set(self._loc)
