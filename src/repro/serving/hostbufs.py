"""64-byte-aligned host buffer allocation.

jax's CPU backend zero-copies a numpy array into a device buffer ONLY
when the host buffer is 64-byte aligned (and dtype/layout match);
otherwise it silently copies.  numpy's own allocator gives whatever
malloc gives, so whether a host buffer aliases device state is decided
by the allocator — the nastiest possible failure mode for the aliasing
bug class: a missing ``.copy()`` corrupts serving state only on the runs
where malloc happened to hand back an aligned block.

Allocating every host-MUTABLE serving buffer (block tables, lengths,
refcounts, last-token row) through this module pins that coin-flip:
zero-copy ingestion of these buffers always happens when the code path
permits it, so (a) a latent missing-copy bug fails on the FIRST run, not
the unlucky one, and (b) the ``repro.lint.aliasing`` audit's
shared-memory checks are deterministic.
"""
from __future__ import annotations

import numpy as np

ALIGN = 64  # bytes: XLA CPU's zero-copy import requirement


def aligned_empty(shape, dtype) -> np.ndarray:
    """An uninitialized C-contiguous array whose data pointer is 64-byte
    aligned (a view into a slightly-overallocated byte buffer)."""
    dtype = np.dtype(dtype)
    shape = tuple(int(s) for s in np.atleast_1d(np.asarray(shape)).ravel()) \
        if not np.isscalar(shape) else (int(shape),)
    nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    raw = np.empty(nbytes + ALIGN, np.uint8)
    start = (-raw.ctypes.data) % ALIGN
    return raw[start:start + nbytes].view(dtype).reshape(shape)


def aligned_zeros(shape, dtype) -> np.ndarray:
    out = aligned_empty(shape, dtype)
    out[...] = 0
    return out


def aligned_full(shape, fill, dtype) -> np.ndarray:
    out = aligned_empty(shape, dtype)
    out[...] = fill
    return out
