"""DENSE-cache slot operations over the batched DecodeCache.

This is the worst-case-length serving backend (driven through
``serving.adapters.DenseCacheAdapter``): every slot owns a fixed
``max_len`` stretch of one batched cache, so inserts/evicts are O(1)
dynamic slices but concurrency is capped at ``HBM / (L · max_len · Hkv ·
Dh)`` slots regardless of actual sequence lengths.  The alternative is
``paged_kv_cache`` (``Engine(cache="paged")``): block-pool pages mapped
on demand, which trades the simple slot arithmetic for strictly more
concurrent streams per HBM byte on mixed-length traffic.

The cache produced by ``models.init_cache`` is batched over serving slots;
these utilities insert a freshly-prefilled single-request cache into slot
``i`` and evict finished slots, using dynamic_update_slice so the engine's
jitted update is in-place (donated) on device.

Merged (Q/P-removed) models use the SAME cache layout: prefill writes
K* = x·(Q⁻¹K) and V* = x·(Q⁻¹V) into the same (L, B, Sc, Hkv, Dh) buffers,
and the merged decode kernel reads them untransposed (its blocking is
native to this layout) — so slot insert/evict below is style-agnostic.

Batch axis position by field:
  k/v            (L, B, Sc, Hkv, Dh)   axis 1
  kv_pos         (B, Sc)               axis 0
  length         (B,)                  axis 0
  ssm.ssm        (L, B, H, P, N)       axis 1
  ssm.conv       (L, B, W-1, C)        axis 1
  cross_k/v      (ng, B, nv, Hkv, Dh)  axis 1
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer import DecodeCache
from repro.models import mamba2 as m2

_FIELD_AXIS = {"k": 1, "v": 1, "kv_pos": 0, "length": 0,
               "cross_k": 1, "cross_v": 1}


def _insert_one(dst, src, slot, axis):
    if dst is None:
        return None
    # src has batch size 1 on `axis`; write it at index `slot`
    start = [jnp.int32(0)] * dst.ndim
    start[axis] = slot
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(start))


@partial(jax.jit, donate_argnums=(0,))
def insert_request(cache: DecodeCache, one: DecodeCache, slot: jnp.ndarray
                   ) -> DecodeCache:
    """Insert a batch-1 cache ``one`` into slot ``slot`` of ``cache``."""
    upd = {}
    for f, axis in _FIELD_AXIS.items():
        upd[f] = _insert_one(getattr(cache, f), getattr(one, f), slot, axis)
    if cache.ssm is not None:
        upd["ssm"] = m2.SSMState(
            ssm=_insert_one(cache.ssm.ssm, one.ssm.ssm, slot, 1),
            conv=_insert_one(cache.ssm.conv, one.ssm.conv, slot, 1))
    else:
        upd["ssm"] = None
    return DecodeCache(**upd)


@partial(jax.jit, donate_argnums=(0,))
def clear_slot(cache: DecodeCache, slot: jnp.ndarray) -> DecodeCache:
    """Mark a slot idle: zero its length and invalidate kv positions.

    Jitted with the cache DONATED so the two ``.at[].set()`` updates write
    in place: undonated they would copy the full multi-MB cache per
    finished request, on the hot serving loop.

    SSM state need not be cleared here: inserting the next request
    overwrites the slot's state wholesale (insert_request writes every
    stateful field), and idle slots are never read by the engine.
    """
    new = cache
    if cache.kv_pos is not None:
        new = new._replace(kv_pos=cache.kv_pos.at[slot].set(-1))
    new = new._replace(length=cache.length.at[slot].set(0))
    return new
