"""Serving layer: continuous-batching engine over two cache backends.

``kv_cache``       — dense slot cache ops (worst-case length per slot).
``paged_kv_cache`` — block-pool cache: free-list page allocator, per-slot
                     block tables, prefix sharing with copy-on-write.
``engine``         — prefill/decode driver; ``ServeConfig.cache_kind``
                     selects the backend ("dense" | "paged").
"""
from repro.serving.engine import Engine, Request, ServeConfig
from repro.serving import kv_cache
from repro.serving import paged_kv_cache

__all__ = ["Engine", "Request", "ServeConfig", "kv_cache", "paged_kv_cache"]
