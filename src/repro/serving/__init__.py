"""Serving layer: one engine, two seams — cache adapters × attention backends.

The engine (``engine.Engine``) never special-cases a cache layout or a
projection style.  It drives:

  * ``adapters.KVCacheAdapter`` — the CACHE seam.  An adapter owns its
    layout end to end: device state (``device_cache``/``update``), shapes
    (``spec``) and mesh partition specs (``pspecs``), admission control
    (``admit``), the prefill-insert path (``prefill``) and slot lifecycle
    (``ensure_appendable``/``advance``/``release``).  Shipped adapters:

      ``DenseCacheAdapter``  worst-case-length slot cache over the batched
                             ``DecodeCache`` (``kv_cache`` ops); every
                             family (attn/ssm/hybrid/vlm).
      ``PagedCacheAdapter``  block-pool cache (``paged_kv_cache``):
                             free-list pages, per-slot block tables,
                             prefix sharing with copy-on-write, deferral +
                             preemption-with-exact-resume; attention-only.
                             Prefill writes prompt KV DIRECT-TO-PAGE from
                             inside the prefill program — no worst-case-
                             length intermediate, no scatter pass.

  * ``models.backends`` — the ATTENTION seam, for BOTH serving phases.
    Registries keyed on (cache_kind, style, impl) supply the per-layer
    decode step that the single jitted ``models.forward_step`` runs AND
    the whole-sequence prefill program that the single
    ``models.forward_prefill`` dispatcher runs.  Fast paths today:

      (dense|paged, merged, *)   Q/P-removed "qp" models: attention reads
                                 only K*/V* weights, at decode
                                 (``Engine.merged_fast_path``) and at
                                 prefill (stream-as-query flash kernel,
                                 ``Engine.merged_prefill_fast_path``).
      (dense|paged, generic, *)  everything else, including the kp/vp
                                 merged variants (their eliminated
                                 projection is an identity inside the
                                 projection helper) — token-identical to
                                 the unmerged model, no fast-path route.

    impl ∈ {xla, pallas, pallas_interpret}; the pallas kernels behind each
    combo are listed in ``kernels.ops.ATTENTION_KERNELS``, keyed
    (phase, cache_kind, style).

Extending: a new cache layout = subclass ``KVCacheAdapter`` + register its
attention steps with ``models.backends.register_backend(cache_kind, style,
step)`` (steps get ``(lp, cfg, u1, k_store, v_store, ctx)``) and its
prefill program with ``register_prefill_backend(cache_kind, style, run)``
(runs get ``(params, cfg, inputs, dest, ctx)``); then serve it with
``Engine(cfg, params, sc, cache=MyAdapter(...))``.  Unregistered combos
raise KeyError at Engine construction.

Selecting a shipped backend: ``Engine(..., cache="dense"|"paged")`` or an
adapter instance (``PagedCacheAdapter(block_size=16, n_blocks=256)``).
``ServeConfig.cache_kind`` and ``models.forward_decode[_paged]`` remain as
deprecated shims over this API.

Continuous batching (``serving.sched``): ``ScheduledEngine`` replaces the
synchronous whole-prompt prefill in ``submit`` with queue admission plus
per-iteration token-budget plans that interleave fixed-width prefill
CHUNKS (a third registered program per cache kind,
``models.forward_prefill_chunk``) with the batched decode step —
``SchedConfig(token_budget, chunk_tokens)`` are the knobs.
"""
from repro.serving.engine import Engine, Request, RequestResult, ServeConfig
from repro.serving.adapters import (DenseCacheAdapter, KVCacheAdapter,
                                    PagedCacheAdapter, PagedQ8CacheAdapter,
                                    make_adapter)
from repro.serving import kv_cache
from repro.serving import paged_kv_cache
from repro.serving.sched import SchedConfig, Schedule, ScheduledEngine

__all__ = [
    "Engine", "Request", "RequestResult", "ServeConfig",
    "KVCacheAdapter", "DenseCacheAdapter", "PagedCacheAdapter",
    "PagedQ8CacheAdapter", "make_adapter", "kv_cache", "paged_kv_cache",
    "SchedConfig", "Schedule", "ScheduledEngine",
]
