from repro.serving.engine import Engine, Request, ServeConfig
from repro.serving import kv_cache

__all__ = ["Engine", "Request", "ServeConfig", "kv_cache"]
