"""KVCacheAdapter: the cache side of the serving API seam.

The engine speaks to its cache through ONE interface, so cache layouts
stop leaking into the scheduling code: ``DenseCacheAdapter`` owns the
per-slot ring-buffer ``DecodeCache`` and ``PagedCacheAdapter`` owns the
block-pool ``PagedDecodeCache`` (wrapping ``paged_kv_cache``'s host-side
manager).  Each adapter owns its cache's

  * ``spec()`` / ``pspecs(rules)``  — shapes for jit input specs and the
    mesh partition specs (the shape logic the engine used to re-derive),
  * ``init()`` / ``device_cache()`` / ``update(new)`` — the device state
    the jitted ``forward_step`` consumes and returns (donated),
  * request lifecycle — ``admit`` (admission control; dense always
    admits, paged defers when the pool is exhausted), ``prefill`` (runs
    the adapter's own jitted prefill program: dense inserts a batch-1
    ``DecodeCache`` into the slot; paged writes prompt KV DIRECT-TO-PAGE
    via ``forward_prefill(dest=PagedPrefillDest(…))`` — no
    worst-case-length intermediate
    and no scatter pass), ``ensure_appendable`` / ``advance`` /
    ``release``.

Selecting a backend is then data, not code: ``Engine(cfg, params, sc,
cache=PagedCacheAdapter(block_size=16))`` or ``cache="paged"`` — and a new
cache layout is a new adapter plus its registered attention backends
(``models.backends``), with zero engine changes.

Chunked prefill (``repro.serving.sched``) adds a THIRD program per
adapter: ``build_chunk`` compiles one fixed-width slice program
(``models.forward_prefill_chunk``) and ``admit_chunked`` / ``chunk_ready``
/ ``chunk_step`` / ``finish_chunked`` / ``unshield`` drive a prompt into
the SHARED batched cache chunk by chunk while decode keeps stepping the
other slots.  The mid-prefill safety contracts differ per kind:

  * dense — the adapter keeps HOST lengths for every slot and overrides
    ``device_cache().length`` from them, so the batched decode step's
    write for a mid-prefill slot parks AT the chunk frontier (the next
    chunk overwrites it) and never advances the slot;
  * paged — the manager SHIELDS mid-prefill slots (their block-table rows
    ship as -1, decode writes drop on the floor) while the chunk program
    receives the true row; ``unshield`` flips the slot live only after
    the activating decode step has dispatched.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distribution import sharding as shd
from repro.models import (DenseChunkDest, DensePrefillDest, PagedChunkDest,
                          PagedPrefillDest, PagedQ8ChunkDest,
                          PagedQ8PrefillDest, forward_prefill,
                          forward_prefill_chunk, init_cache)
from repro.serving import hostbufs
from repro.serving import kv_cache as kvc
from repro.serving import paged_kv_cache as pkv


class KVCacheAdapter:
    """Interface the engine drives; see module docstring.  Subclasses set
    ``kind`` to the cache_kind axis of the backend-registry key."""

    kind: str = "?"

    #: prompts handed to prefill must be padded to a multiple of this
    #: (the engine's ``_bucket_pad`` rounds its power-of-two bucket up).
    #: paged_q8 overrides it with the page size: pages are quantized
    #: whole on write, so a prefill may not end mid-page.
    bucket_align: int = 1

    # -- lifecycle ------------------------------------------------------
    def init(self, cfg: ModelConfig, sc) -> None:
        """Allocate the device cache for (cfg, ServeConfig)."""
        raise NotImplementedError

    def build_prefill(self, impl: str, mesh=None, params_sharding=None,
                      cache_shardings=None, qkv_sharding=None) -> None:
        """Compile-wrap this cache kind's prefill program (a
        ``models.forward_prefill`` dispatch — the cache kind picks the
        destination, the model config picks the generic/merged style).
        ``qkv_sharding`` re-anchors TP head sharding for merged layouts
        under a mesh (no wq matmul to propagate it from)."""
        raise NotImplementedError

    # -- device state ---------------------------------------------------
    def spec(self):
        """ShapeDtypeStruct tree of ``device_cache()`` (jit input specs)."""
        return jax.eval_shape(self.device_cache)

    def pspecs(self, rules):
        """PartitionSpec tree matching ``spec()`` (mesh serving)."""
        return shd.serving_cache_pspecs(self.cfg, rules, self.spec())

    def device_cache(self):
        raise NotImplementedError

    def update(self, new) -> None:
        """Absorb the (donated) cache returned by the jitted step."""
        raise NotImplementedError

    @property
    def cache_bytes(self) -> int:
        raise NotImplementedError

    # -- request lifecycle ---------------------------------------------
    def admit(self, slot: int, tokens: np.ndarray) -> Optional[int]:
        """Admission control.  Returns the number of prefix-shared pages
        (0 where the concept doesn't apply), or None to DEFER the request
        (resource-exhausted; the engine retries after others finish)."""
        raise NotImplementedError

    def prefill(self, params, slot: int, padded_row, true_n: int,
                n_shared: int, vision):
        """Prefill ``padded_row`` (1, S) and install its KV for ``slot``;
        returns the last real position's logits (1, V)."""
        raise NotImplementedError

    def ensure_appendable(self, slot: int) -> bool:
        """Make the next token's write target safely writable; False means
        resource-exhausted (the engine preempts)."""
        return True

    def advance(self, slot: int) -> None:
        """Host-side length bookkeeping after a decoded token (the device
        cache advances inside the jitted step)."""

    def release(self, slot: int) -> None:
        """Return a finished/preempted request's cache resources."""
        raise NotImplementedError

    # -- chunked prefill (repro.serving.sched) --------------------------
    @property
    def supports_chunked(self) -> bool:
        """True when this adapter (as initialised) can run chunked
        prefill.  False routes every request through the scheduler's
        monolithic whole-prompt fallback (still asynchronous admission —
        just one unsplittable job per prompt)."""
        return False

    def enable_chunked(self) -> None:
        """Switch the adapter into chunked mode (host-side bookkeeping
        only; must precede the first ``device_cache()`` the scheduled
        engine ships)."""
        raise NotImplementedError

    def build_chunk(self, chunk_tokens: int, impl: str, mesh=None,
                    params_sharding=None, cache_shardings=None,
                    qkv_sharding=None) -> None:
        """Compile-wrap this cache kind's fixed-width chunk program
        (``models.forward_prefill_chunk``): ONE program serves chunk
        ``[start, start+chunk_tokens)`` of every prompt."""
        raise NotImplementedError

    def admit_chunked(self, slot: int, tokens: np.ndarray) -> Optional[int]:
        """Admission control for a chunked prefill: reserve ``slot`` for
        ``tokens`` without running anything.  Returns prefix-shared pages
        (0 where the concept doesn't apply) or None to DEFER."""
        raise NotImplementedError

    def chunk_ready(self, slot: int, start: int, end: int) -> bool:
        """Make the chunk's write targets safely writable (paged: map the
        covering pages / recycle ring pages); False means
        resource-exhausted (the scheduler preempts)."""
        return True

    def chunk_step(self, params, slot: int, chunk_row, start: int,
                   true_len: int):
        """Run ONE chunk of ``slot``'s prompt through the compiled chunk
        program; returns the chunk-local last real position's logits
        (1, V) — meaningful only on the final chunk."""
        raise NotImplementedError

    def finish_chunked(self, slot: int, tokens: np.ndarray) -> None:
        """All chunks landed: publish the slot's full length (paged:
        register the prompt's pages for prefix sharing)."""
        raise NotImplementedError

    def unshield(self, slot: int) -> None:
        """Expose the slot to batched decode writes (paged shield off).
        Call AFTER the activating decode step has dispatched — a shared
        trailing partial page must not take this slot's decode writes
        while an in-flight program still reads it."""

    def set_length(self, slot: int, n: int) -> None:
        """Sync host-side length bookkeeping after a MONOLITHIC prefill
        installed ``n`` tokens into ``slot`` (the scheduler's fallback
        path for unsplittable prompts on a chunked adapter)."""

    # -- introspection --------------------------------------------------
    def compiled_prefill(self, params, bucket_len: int):
        """Lower + compile the prefill program for one prompt bucket (no
        execution) — benchmarks read its cost_analysis (prefill HBM
        traffic, e.g. dense-vs-paged TTFT bytes)."""
        raise NotImplementedError

    def host_mutable_buffers(self) -> Dict[str, np.ndarray]:
        """Named host-side numpy buffers this adapter mutates across steps
        (``repro.lint.aliasing`` checks jit inputs against them).  Dense
        caches live entirely on device: nothing to declare."""
        return {}

    def obs_gauges(self):
        """name -> (zero-arg callable, help) of this cache's telemetry,
        lifted into ``Engine.metrics`` as LAZY gauges — evaluated only at
        ``collect()`` time, never on the serving path.  Dense caches have
        no pool to report."""
        return {}


class DenseCacheAdapter(KVCacheAdapter):
    """Worst-case-length slot cache: every slot owns a ``max_len`` stretch
    of one batched ``DecodeCache``; insert/evict are O(1) dynamic slices
    (``serving.kv_cache``).  Supports every family (attn/ssm/hybrid/vlm)."""

    kind = "dense"

    def init(self, cfg, sc):
        self.cfg, self.sc = cfg, sc
        self._cache = init_cache(cfg, sc.n_slots, sc.max_len)
        self._chunked = False

    def build_prefill(self, impl, mesh=None, params_sharding=None,
                      cache_shardings=None, qkv_sharding=None):
        cfg = self.cfg
        dest = DensePrefillDest(cache_len=self.sc.max_len)

        def fn(p, tk, vs, tl):
            return forward_prefill(
                p, cfg, tk, dest, vision=vs, impl=impl, true_len=tl,
                qkv_sharding=qkv_sharding)

        if mesh is not None:
            self._prefill = jax.jit(
                fn, in_shardings=(params_sharding, None, None, None))
        else:
            self._prefill = jax.jit(fn)

    def device_cache(self):
        if not self._chunked:
            return self._cache
        # chunked mode: HOST lengths are authoritative.  A mid-prefill
        # slot's length is its chunk frontier, so the batched decode
        # step's write for that slot parks AT the frontier (the next
        # chunk overwrites it) instead of advancing past real positions.
        # .copy() before ingestion: _lengths is engine-mutated host state
        # and jnp.asarray of an aligned buffer is zero-copy (lint:
        # aliasing audit).
        return self._cache._replace(
            length=jnp.asarray(self._lengths.copy()))

    def update(self, new):
        self._cache = new

    @property
    def cache_bytes(self):
        k = self._cache.k
        return int(k.size + self._cache.v.size) * k.dtype.itemsize

    def admit(self, slot, tokens):
        return 0

    def prefill(self, params, slot, padded_row, true_n, n_shared, vision):
        tl = jnp.full((1,), true_n, jnp.int32)
        logits, one = self._prefill(params, padded_row, vision, tl)
        self._cache = kvc.insert_request(self._cache, one, jnp.int32(slot))
        return logits

    def advance(self, slot):
        if self._chunked:
            self._lengths[slot] += 1

    def release(self, slot):
        self._cache = kvc.clear_slot(self._cache, jnp.int32(slot))
        if self._chunked:
            self._lengths[slot] = 0

    def host_mutable_buffers(self):
        if self._chunked:
            return {"dense._lengths": self._lengths}
        return {}

    # -- chunked prefill ------------------------------------------------
    @property
    def supports_chunked(self):
        # a BINDING sliding window (window < max_len) makes the dense
        # cache a window-sized ring that cannot hold a partial prompt at
        # absolute positions; the scheduler falls back to monolithic
        # whole-prompt jobs there.  Non-binding windows chunk exactly
        # like window-free configs.
        w = self.cfg.sliding_window
        return not (w and w < self.sc.max_len)

    def enable_chunked(self):
        self._chunked = True
        self._lengths = hostbufs.aligned_zeros((self.sc.n_slots,), np.int32)

    def build_chunk(self, chunk_tokens, impl, mesh=None, params_sharding=None,
                    cache_shardings=None, qkv_sharding=None):
        cfg, max_len = self.cfg, self.sc.max_len
        self._chunk_tokens = chunk_tokens

        def fn(p, tk, s, tl, slot, cache):
            return forward_prefill_chunk(
                p, cfg, tk, DenseChunkDest(cache, slot), start=s,
                true_len=tl, impl=impl, qkv_sharding=qkv_sharding,
                max_len=max_len)

        if mesh is not None:
            self._chunk = jax.jit(
                fn, donate_argnums=(5,),
                in_shardings=(params_sharding, None, None, None, None,
                              cache_shardings),
                out_shardings=(None, cache_shardings))
        else:
            self._chunk = jax.jit(fn, donate_argnums=(5,))

    def admit_chunked(self, slot, tokens):
        self._lengths[slot] = 0
        return 0

    def chunk_step(self, params, slot, chunk_row, start, true_len):
        s = jnp.full((1,), start, jnp.int32)
        tl = jnp.full((1,), true_len, jnp.int32)
        sl = jnp.full((1,), slot, jnp.int32)
        logits, new_cache = self._chunk(params, chunk_row, s, tl, sl,
                                        self.device_cache())
        self._cache = new_cache
        self._lengths[slot] = min(start + self._chunk_tokens, true_len)
        return logits

    def finish_chunked(self, slot, tokens):
        self._lengths[slot] = len(tokens)

    def set_length(self, slot, n):
        if self._chunked:
            self._lengths[slot] = n

    def compiled_prefill(self, params, bucket_len):
        pshape = jax.eval_shape(lambda: params)
        tk = jax.ShapeDtypeStruct((1, bucket_len), jnp.int32)
        tl = jax.ShapeDtypeStruct((1,), jnp.int32)
        return self._prefill.lower(pshape, tk, None, tl).compile()


class PagedCacheAdapter(KVCacheAdapter):
    """Block-pool cache: slots map variable numbers of fixed-size physical
    pages (free-list allocation, prefix sharing, copy-on-write, admission
    control — ``serving.paged_kv_cache``).  Attention-only stacks.

    ``block_size``/``n_blocks`` default to the ServeConfig's values at
    ``init`` (n_blocks 0 ⇒ dense-equivalent HBM: n_slots·max_len/bs pages).
    Prefill writes prompt KV directly into the mapped pages from inside
    the prefill program (``forward_prefill(dest=PagedPrefillDest(…))``):
    the jit is donated
    on the pools, so submit-time cache traffic is ONLY the prompt's own
    pages — no max_len-sized intermediate buffer, no second scatter pass.
    """

    kind = "paged"

    def __init__(self, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None,
                 prefix_retention: bool = True):
        self._block_size, self._n_blocks = block_size, n_blocks
        self._prefix_retention = prefix_retention

    def init(self, cfg, sc):
        self.cfg, self.sc = cfg, sc
        bs = self._block_size or sc.block_size
        n_blocks = self._n_blocks or sc.n_blocks \
            or sc.n_slots * (sc.max_len // bs)
        self.pm = pkv.PagedCacheManager(
            cfg, n_slots=sc.n_slots, max_len=sc.max_len,
            block_size=bs, n_blocks=n_blocks,
            prefix_retention=self._prefix_retention)

    def build_prefill(self, impl, mesh=None, params_sharding=None,
                      cache_shardings=None, qkv_sharding=None):
        cfg = self.cfg

        def fn(p, tk, tl, kp, vp, bids):
            return forward_prefill(
                p, cfg, tk, PagedPrefillDest(kp, vp, bids), impl=impl,
                true_len=tl, qkv_sharding=qkv_sharding)

        if mesh is not None:
            pool_k, pool_v = cache_shardings.k, cache_shardings.v
            self._prefill = jax.jit(
                fn, donate_argnums=(3, 4),
                in_shardings=(params_sharding, None, None, pool_k, pool_v,
                              None),
                out_shardings=(None, (pool_k, pool_v)))
        else:
            self._prefill = jax.jit(fn, donate_argnums=(3, 4))

    def device_cache(self):
        return self.pm.device_cache()

    def update(self, new):
        self.pm.update_pools(new)

    def host_mutable_buffers(self):
        return self.pm.host_mutable_buffers()

    @property
    def cache_bytes(self):
        return self.pm.pool_bytes

    def admit(self, slot, tokens):
        return self.pm.admit(slot, tokens)

    def prefill(self, params, slot, padded_row, true_n, n_shared, vision):
        assert vision is None, "paged serving is attention-only (no vlm)"
        bids = self.pm.prefill_block_ids(slot, padded_row.shape[1])
        tl = jnp.full((1,), true_n, jnp.int32)
        logits, (k, v) = self._prefill(params, padded_row, tl,
                                       self.pm.k, self.pm.v,
                                       jnp.asarray(bids))
        self.pm.k, self.pm.v = k, v
        return logits

    def ensure_appendable(self, slot):
        return self.pm.ensure_appendable(slot)

    def advance(self, slot):
        self.pm.advance(slot)

    def release(self, slot):
        self.pm.release(slot)

    # -- chunked prefill ------------------------------------------------
    @property
    def supports_chunked(self):
        return True

    def enable_chunked(self):
        pass  # shield/frontier machinery lives in the manager, always on

    def build_chunk(self, chunk_tokens, impl, mesh=None, params_sharding=None,
                    cache_shardings=None, qkv_sharding=None):
        cfg = self.cfg
        if chunk_tokens % self.pm.bs:
            raise ValueError(
                f"chunk_tokens ({chunk_tokens}) must be a multiple of the "
                f"block size ({self.pm.bs})")
        if self.pm.ring and chunk_tokens != self.pm.bs:
            raise ValueError(
                f"ring (windowed) paged chunking pins chunk_tokens to one "
                f"block ({self.pm.bs}); got {chunk_tokens}")
        self._chunk_tokens = chunk_tokens

        def fn(p, tk, s, tl, kp, vp, trow, bids):
            return forward_prefill_chunk(
                p, cfg, tk, PagedChunkDest(kp, vp, trow, bids), start=s,
                true_len=tl, impl=impl, qkv_sharding=qkv_sharding)

        if mesh is not None:
            pool_k, pool_v = cache_shardings.k, cache_shardings.v
            self._chunk = jax.jit(
                fn, donate_argnums=(4, 5),
                in_shardings=(params_sharding, None, None, None, pool_k,
                              pool_v, None, None),
                out_shardings=(None, (pool_k, pool_v)))
        else:
            self._chunk = jax.jit(fn, donate_argnums=(4, 5))

    def admit_chunked(self, slot, tokens):
        return self.pm.admit_chunked(slot, tokens)

    def chunk_ready(self, slot, start, end):
        return self.pm.ensure_chunk(slot, start, end)

    def chunk_step(self, params, slot, chunk_row, start, true_len):
        C = self._chunk_tokens
        bids = self.pm.chunk_block_ids(slot, start, start + C, true_len)
        s = jnp.full((1,), start, jnp.int32)
        tl = jnp.full((1,), true_len, jnp.int32)
        # the TRUE table row (the decode view masks shielded slots to -1);
        # .copy() before ingestion — tables is host-mutated (aliasing)
        trow = jnp.asarray(self.pm.tables[slot:slot + 1].copy())
        logits, (k, v) = self._chunk(params, chunk_row, s, tl,
                                     self.pm.k, self.pm.v, trow,
                                     jnp.asarray(bids))
        self.pm.k, self.pm.v = k, v
        self.pm.set_frontier(slot, min(start + C, true_len))
        return logits

    def finish_chunked(self, slot, tokens):
        self.pm.finish_chunked(slot, tokens)

    def unshield(self, slot):
        self.pm.unshield(slot)

    def compiled_prefill(self, params, bucket_len):
        pshape = jax.eval_shape(lambda: params)
        tk = jax.ShapeDtypeStruct((1, bucket_len), jnp.int32)
        tl = jax.ShapeDtypeStruct((1,), jnp.int32)
        kp = jax.eval_shape(lambda: self.pm.k)
        vp = jax.eval_shape(lambda: self.pm.v)
        nbk = -(-bucket_len // self.pm.bs)
        bids = jax.ShapeDtypeStruct((nbk,), jnp.int32)
        return self._prefill.lower(pshape, tk, tl, kp, vp, bids).compile()

    def obs_gauges(self):
        a = self.pm.allocator
        return {
            "pool_blocks_used": (lambda: a.n_used, "pages mapped now"),
            "pool_blocks_free": (lambda: a.n_free, "pages on the free list"),
            "pool_peak_used": (lambda: a.peak_used,
                               "pool occupancy high-water (pages)"),
            "pool_recycled": (lambda: a.n_recycled,
                              "ring pages recycled in place"),
            "pool_cow": (lambda: a.n_cow, "copy-on-write page splits"),
            "pool_prefix_hits": (lambda: a.n_shared_hits,
                                 "prefix pages shared at admit"),
            "prefix_tree_nodes": (lambda: self.pm.tree.n_nodes,
                                  "radix prefix-tree nodes resident"),
            "prefix_retained_pages": (
                lambda: len(self.pm.tree.retained),
                "pages held only by the prefix tree (retention)"),
            "prefix_hit_tokens": (lambda: self.pm.tree.hit_tokens,
                                  "prompt tokens served from the prefix "
                                  "cache"),
            "prefix_evicted": (lambda: self.pm.tree.n_evicted,
                               "retained pages evicted under pressure"),
        }


class PagedQ8CacheAdapter(PagedCacheAdapter):
    """Quantized block-pool cache: the paged layout with int8 pages and
    per-(page, kv-head) float32 scales (``pkv.PagedQ8CacheManager``).

    Everything host-side — allocator, block tables, CoW, ring recycle,
    prefix registry, shields — is inherited UNCHANGED: a page id means the
    same thing, its scale rows just travel with it (``copy_block_q8``
    copies all four arrays).  What changes is the device programs: prefill
    and chunk ship the scale arrays next to the pools (donated together)
    and the destinations are the q8 variants, which quantize-on-write; the
    decode step reads ``PagedQ8DecodeCache`` and the registered
    ``paged_q8`` backends dequantize in-kernel.  HBM for the pools is
    ~quarter of an fp32 pool (int8 pages + one f32 scale pair per
    (page, head)), which is where the equal-HBM stream-count win in
    ``benchmarks.bench_paged_serving`` comes from.
    """

    kind = "paged_q8"

    @property
    def bucket_align(self) -> int:
        # pages quantize whole on write: prefill may not end mid-page
        return self.pm.bs

    def init(self, cfg, sc):
        self.cfg, self.sc = cfg, sc
        bs = self._block_size or sc.block_size
        n_blocks = self._n_blocks or sc.n_blocks \
            or sc.n_slots * (sc.max_len // bs)
        self.pm = pkv.PagedQ8CacheManager(
            cfg, n_slots=sc.n_slots, max_len=sc.max_len,
            block_size=bs, n_blocks=n_blocks,
            prefix_retention=self._prefix_retention)

    def build_prefill(self, impl, mesh=None, params_sharding=None,
                      cache_shardings=None, qkv_sharding=None):
        cfg = self.cfg

        def fn(p, tk, tl, kp, vp, ks, vs, bids):
            return forward_prefill(
                p, cfg, tk, PagedQ8PrefillDest(kp, vp, ks, vs, bids),
                impl=impl, true_len=tl, qkv_sharding=qkv_sharding)

        if mesh is not None:
            cs = cache_shardings
            self._prefill = jax.jit(
                fn, donate_argnums=(3, 4, 5, 6),
                in_shardings=(params_sharding, None, None, cs.k, cs.v,
                              cs.k_scale, cs.v_scale, None),
                out_shardings=(None, (cs.k, cs.v, cs.k_scale, cs.v_scale)))
        else:
            self._prefill = jax.jit(fn, donate_argnums=(3, 4, 5, 6))

    def prefill(self, params, slot, padded_row, true_n, n_shared, vision):
        assert vision is None, "paged serving is attention-only (no vlm)"
        bids = self.pm.prefill_block_ids(slot, padded_row.shape[1])
        tl = jnp.full((1,), true_n, jnp.int32)
        logits, (k, v, ks, vs) = self._prefill(
            params, padded_row, tl, self.pm.k, self.pm.v,
            self.pm.k_scale, self.pm.v_scale, jnp.asarray(bids))
        self.pm.k, self.pm.v = k, v
        self.pm.k_scale, self.pm.v_scale = ks, vs
        return logits

    def build_chunk(self, chunk_tokens, impl, mesh=None, params_sharding=None,
                    cache_shardings=None, qkv_sharding=None):
        cfg = self.cfg
        if chunk_tokens % self.pm.bs:
            raise ValueError(
                f"chunk_tokens ({chunk_tokens}) must be a multiple of the "
                f"block size ({self.pm.bs})")
        if self.pm.ring and chunk_tokens != self.pm.bs:
            raise ValueError(
                f"ring (windowed) paged chunking pins chunk_tokens to one "
                f"block ({self.pm.bs}); got {chunk_tokens}")
        self._chunk_tokens = chunk_tokens

        def fn(p, tk, s, tl, kp, vp, ks, vs, trow, bids):
            return forward_prefill_chunk(
                p, cfg, tk, PagedQ8ChunkDest(kp, vp, ks, vs, trow, bids),
                start=s, true_len=tl, impl=impl, qkv_sharding=qkv_sharding)

        if mesh is not None:
            cs = cache_shardings
            self._chunk = jax.jit(
                fn, donate_argnums=(4, 5, 6, 7),
                in_shardings=(params_sharding, None, None, None, cs.k, cs.v,
                              cs.k_scale, cs.v_scale, None, None),
                out_shardings=(None, (cs.k, cs.v, cs.k_scale, cs.v_scale)))
        else:
            self._chunk = jax.jit(fn, donate_argnums=(4, 5, 6, 7))

    def chunk_step(self, params, slot, chunk_row, start, true_len):
        C = self._chunk_tokens
        bids = self.pm.chunk_block_ids(slot, start, start + C, true_len)
        s = jnp.full((1,), start, jnp.int32)
        tl = jnp.full((1,), true_len, jnp.int32)
        # the TRUE table row (the decode view masks shielded slots to -1);
        # .copy() before ingestion — tables is host-mutated (aliasing)
        trow = jnp.asarray(self.pm.tables[slot:slot + 1].copy())
        logits, (k, v, ks, vs) = self._chunk(
            params, chunk_row, s, tl, self.pm.k, self.pm.v,
            self.pm.k_scale, self.pm.v_scale, trow, jnp.asarray(bids))
        self.pm.k, self.pm.v = k, v
        self.pm.k_scale, self.pm.v_scale = ks, vs
        self.pm.set_frontier(slot, min(start + C, true_len))
        return logits

    def compiled_prefill(self, params, bucket_len):
        pshape = jax.eval_shape(lambda: params)
        tk = jax.ShapeDtypeStruct((1, bucket_len), jnp.int32)
        tl = jax.ShapeDtypeStruct((1,), jnp.int32)
        kp = jax.eval_shape(lambda: self.pm.k)
        vp = jax.eval_shape(lambda: self.pm.v)
        ks = jax.eval_shape(lambda: self.pm.k_scale)
        vs = jax.eval_shape(lambda: self.pm.v_scale)
        nbk = -(-bucket_len // self.pm.bs)
        bids = jax.ShapeDtypeStruct((nbk,), jnp.int32)
        return self._prefill.lower(pshape, tk, tl, kp, vp, ks, vs,
                                   bids).compile()

    def obs_gauges(self):
        g = dict(super().obs_gauges())
        pm = self.pm

        def q8_bytes():
            return pm.pool_bytes

        def saved_vs_fp16():
            elems = int(pm.k.size) + int(pm.v.size)  # int8: 1 byte each
            return elems * 2 - pm.pool_bytes

        g.update({
            "q8_pool_bytes": (q8_bytes,
                              "int8 pool + scale bytes resident"),
            "q8_bytes_saved_vs_fp16": (
                saved_vs_fp16,
                "HBM saved vs an fp16 pool of the same page count"),
        })
        return g


def make_adapter(kind: str, sc) -> KVCacheAdapter:
    """Adapter for a cache_kind name (the string form of the new API, and
    the target of the deprecated ``ServeConfig.cache_kind``)."""
    if kind == "dense":
        return DenseCacheAdapter()
    if kind == "paged":
        return PagedCacheAdapter(block_size=sc.block_size,
                                 n_blocks=sc.n_blocks)
    if kind == "paged_q8":
        return PagedQ8CacheAdapter(block_size=sc.block_size,
                                   n_blocks=sc.n_blocks)
    raise ValueError(
        f"unknown cache kind {kind!r}; expected 'dense', 'paged', "
        "'paged_q8', or a KVCacheAdapter instance")
