"""Serving engine: prefill + batched decode with continuous batching.

Design (vLLM-style, TPU/JAX-native):
  * a fixed number of serving SLOTS share one batched DecodeCache; the
    decode step advances every active slot in a single jitted call
    (``serve_step`` — the function the decode_* dry-run cells lower);
  * new requests are prefilled (batch-1) and inserted into free slots with
    dynamic_update_slice (``kv_cache.insert_request``); finished slots are
    invalidated and reused — no reallocation, no recompilation;
  * per-slot lengths live in the cache (`length`, `kv_pos`), so mixed
    progress is handled by the attention masks, not by padding logic;
  * sampling: greedy / temperature / top-k, per-slot PRNG streams.

The engine is mesh-aware: given a mesh it shards params/caches with the
distribution-layer rules and jits with explicit shardings.

Merged (Q/P-removed) models are first-class: for ``skipless_merged`` /
``residual_qpfree`` configs with the "qp" variant, ``serve_step`` routes
through the merged decode fast path (``models.transformer._attn_step_merged``
-> ``kernels.decode_attention_merged``) — per-token attention reads only the
K*/V* weights, the stream is the query, and the output lands directly in
the FFN-input basis.  Prefill and slot insert are layout-identical to the
unmerged case (the cache holds K*/V* in the same (L, B, Sc, Hkv, Dh)
buffers), so continuous batching needs no merged-specific plumbing.  Under
a mesh the engine re-anchors TP head sharding on q/k/v explicitly (merged
layouts have no wq matmul to propagate it from).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import sharding as shd
from repro.models import forward_decode, forward_prefill, init_cache
from repro.models.transformer import DecodeCache
from repro.serving import kv_cache as kvc


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0
    eos_token: int = -1  # -1 => run to max_new_tokens
    seed: int = 0


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None
    slot: int = -1
    remaining: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None,
                 impl: str = "xla"):
        assert cfg.causal, "serving requires a decoder"
        cfg.validate_style()  # merged styles need a square Q basis
        self.cfg, self.sc, self.mesh = cfg, sc, mesh
        self.params = params
        self.impl = impl
        self.cache = init_cache(cfg, sc.n_slots, sc.max_len)
        self.free_slots = list(range(sc.n_slots))
        self.active: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(sc.seed)

        prefill = partial(forward_prefill, cfg=cfg, cache_len=sc.max_len,
                          impl=impl)
        decode = partial(forward_decode, cfg=cfg, impl=impl)

        if mesh is not None:
            rules = shd.make_rules(mesh, batch=sc.n_slots)
            pshape = jax.eval_shape(lambda: params)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               shd.evenly(shd.param_pspecs(pshape, rules),
                                          pshape, mesh))
            self.params = jax.device_put(params, psh)
            cshape = jax.eval_shape(lambda: self.cache)
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.evenly(_trim_cache_spec(shd.cache_pspecs(cfg, rules),
                                            self.cache), cshape, mesh))
            qkv_sh = None
            if self.merged_fast_path:
                # K*/V*-only layout: re-anchor TP head sharding explicitly
                qkv_sh = NamedSharding(
                    mesh, P(rules.dp, None, rules.axis("heads"), None))
            self._decode = jax.jit(
                lambda p, t, c: forward_decode(p, self.cfg, t, c, impl=impl,
                                               qkv_sharding=qkv_sh),
                donate_argnums=(2,),
                in_shardings=(psh, NamedSharding(mesh, P()), csh),
                out_shardings=(None, csh))
            self._prefill = jax.jit(
                lambda p, tk, vs: forward_prefill(
                    p, self.cfg, tk, cache_len=sc.max_len, vision=vs, impl=impl),
                in_shardings=(psh, None, None))
        else:
            self._decode = jax.jit(
                lambda p, t, c: forward_decode(p, self.cfg, t, c, impl=impl),
                donate_argnums=(2,))
            self._prefill = jax.jit(
                lambda p, tk, vs: forward_prefill(
                    p, self.cfg, tk, cache_len=sc.max_len, vision=vs, impl=impl))

        self._last_token = np.zeros((sc.n_slots,), np.int32)

    # ------------------------------------------------------------------
    @property
    def merged_fast_path(self) -> bool:
        """True when serve_step routes through the merged (Q/P-removed)
        decode fast path: no Q or P weights exist, so per-token attention
        streams only K*/V* from HBM."""
        return (self.cfg.has_attention
                and self.cfg.block_style in ("skipless_merged",
                                             "residual_qpfree")
                and self.cfg.merged_variant == "qp")

    def compiled_decode(self):
        """Lower + compile serve_step for inspection (no execution).

        Used by benchmarks to read ``cost_analysis()`` / HLO of the exact
        program the engine runs — e.g. HBM bytes/token with and without
        the eliminated Q/P weight reads."""
        pshape = jax.eval_shape(lambda: self.params)
        tshape = jax.ShapeDtypeStruct((self.sc.n_slots,), jnp.int32)
        cshape = jax.eval_shape(lambda: self.cache)
        return self._decode.lower(pshape, tshape, cshape).compile()

    # ------------------------------------------------------------------
    def submit(self, req: Request, vision: Optional[np.ndarray] = None) -> bool:
        """Prefill a request into a free slot. Returns False if saturated."""
        if not self.free_slots:
            return False
        slot = self.free_slots.pop(0)
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        vs = None if vision is None else jnp.asarray(vision)[None]
        logits, one_cache = self._prefill(self.params, toks, vs)
        self.cache = kvc.insert_request(self.cache, one_cache,
                                        jnp.int32(slot))
        tok = self._sample(logits)[0]
        req.slot = slot
        req.out_tokens = [int(tok)]
        req.remaining = req.max_new_tokens - 1
        self.active[slot] = req
        self._last_token[slot] = int(tok)
        return True

    def step(self) -> Dict[int, int]:
        """One batched decode step for all active slots; returns slot->token."""
        if not self.active:
            return {}
        tokens = jnp.asarray(self._last_token, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tokens = np.asarray(self._sample(logits))
        emitted: Dict[int, int] = {}
        for slot, req in list(self.active.items()):
            tok = int(next_tokens[slot])
            req.out_tokens.append(tok)
            req.remaining -= 1
            self._last_token[slot] = tok
            emitted[slot] = tok
            done = req.remaining <= 0 or tok == self.sc.eos_token
            if done:
                self.cache = kvc.clear_slot(self.cache, jnp.int32(slot))
                del self.active[slot]
                self.free_slots.append(slot)
        return emitted

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 vision: Optional[Sequence[np.ndarray]] = None) -> List[List[int]]:
        """Continuous batching driver: keeps slots full until all done."""
        pending = [Request(prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens) for p in prompts]
        results: List[Optional[List[int]]] = [None] * len(pending)
        order = {id(r): i for i, r in enumerate(pending)}
        queue = list(pending)
        inflight: List[Request] = []
        vis = list(vision) if vision is not None else [None] * len(pending)
        vqueue = list(vis)
        while queue or self.active:
            while queue and self.free_slots:
                r = queue.pop(0)
                v = vqueue.pop(0)
                self.submit(r, vision=v)
                inflight.append(r)
            self.step()
            for r in list(inflight):
                if r.slot not in self.active:
                    results[order[id(r)]] = r.out_tokens
                    inflight.remove(r)
        return results  # type: ignore

    # ------------------------------------------------------------------
    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        sc = self.sc
        if logits.shape[-1] > self.cfg.vocab_size:  # mask padded vocab ids
            pad_mask = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        if sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        scaled = logits / sc.temperature
        if sc.top_k > 0:
            vals, _ = jax.lax.top_k(scaled, sc.top_k)
            kth = vals[..., -1:]
            scaled = jnp.where(scaled < kth, -1e30, scaled)
        return jax.random.categorical(sub, scaled).astype(jnp.int32)


def _trim_cache_spec(spec_cache: DecodeCache, like: DecodeCache) -> DecodeCache:
    """Drop spec entries for fields that are None in the actual cache."""
    return DecodeCache(*[
        None if getattr(like, f) is None else getattr(spec_cache, f)
        for f in DecodeCache._fields])
