"""Serving engine: prefill + batched decode with continuous batching.

Design (vLLM-style, TPU/JAX-native):
  * a fixed number of serving SLOTS share one batched cache; the decode
    step advances every active slot in a single jitted call (``serve_step``
    — the function the decode_* dry-run cells lower);
  * TWO cache kinds (``ServeConfig.cache_kind``):
      - "dense": every slot owns a worst-case (max_len) stretch of one
        batched DecodeCache.  New requests prefill batch-1 and insert with
        dynamic_update_slice (``kv_cache.insert_request``); finished slots
        are invalidated in place (``kv_cache.clear_slot``, jitted+donated)
        and reused — no reallocation, no recompilation.
      - "paged": slots map variable numbers of fixed-size physical pages
        out of a shared block pool (``paged_kv_cache``), with free-list
        allocation, prefix sharing (identical prompt prefixes reference
        the same pages, copy-on-write on append) and ADMISSION CONTROL:
        ``submit`` defers a request while the pool is exhausted instead of
        capping concurrency at a worst-case slot count, and ``step``
        preempts the youngest request (resubmitted later, stream intact)
        if appends outrun the pool.  At equal HBM the pool sustains
        strictly more concurrent streams on mixed-length traffic — which
        is what amortizes the merged fast path's K*/V*-only weight reads.
  * prompt lengths are BUCKETED (padded to the next power of two, exact
    logits/cache via ``forward_prefill(true_len=…)``) so a realistic
    traffic mix compiles O(log max_len) prefill programs, not one per
    distinct prompt length;
  * sampling: greedy / temperature / top-k with PER-SLOT PRNG streams —
    each request's key is derived from (engine seed, submission index) and
    advances only with that request's samples, so sampled continuations
    are reproducible regardless of co-scheduled traffic.

The engine is mesh-aware: given a mesh it shards params/caches with the
distribution-layer rules and jits with explicit shardings.

Merged (Q/P-removed) models are first-class: for ``skipless_merged`` /
``residual_qpfree`` configs with the "qp" variant, ``serve_step`` routes
through the merged decode fast path (``models.transformer._attn_step_merged``
or ``_attn_step_paged_merged`` -> ``kernels.decode_attention_merged`` /
``decode_attention_paged_merged``) — per-token attention reads only the
K*/V* weights, the stream is the query, and the output lands in the
FFN-input basis.  The kp/vp merged variants (MHA-only, paper Fig 1c/d)
serve through the generic path: ``_project_qkv`` treats the eliminated
projection as identity, so they decode token-identically to their
unmerged source model without fast-path plumbing.  Under a mesh the
engine re-anchors TP head sharding on q/k/v explicitly (merged layouts
have no wq matmul to propagate it from).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import sharding as shd
from repro.models import (forward_decode, forward_decode_paged,
                          forward_prefill, init_cache, layer_plan)
from repro.models.transformer import DecodeCache, PagedDecodeCache
from repro.serving import kv_cache as kvc
from repro.serving import paged_kv_cache as pkv


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0
    eos_token: int = -1  # -1 => run to max_new_tokens
    seed: int = 0
    cache_kind: str = "dense"  # "dense" | "paged"
    block_size: int = 16  # paged: tokens per physical page
    n_blocks: int = 0  # paged pool size; 0 => dense-equivalent HBM
    bucket_prompts: bool = True  # pad prompts to power-of-two buckets


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None
    slot: int = -1  # >=0 active; -1 idle/finished; -2 preempted
    remaining: int = 0
    rid: int = -1  # submission index (per-request PRNG stream id)
    key_state: Optional[np.ndarray] = None  # advanced PRNG key (preemption)


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None,
                 impl: str = "xla"):
        assert cfg.causal, "serving requires a decoder"
        assert sc.cache_kind in ("dense", "paged"), sc.cache_kind
        cfg.validate_style()  # merged styles need a square Q basis
        self.cfg, self.sc, self.mesh = cfg, sc, mesh
        self.params = params
        self.impl = impl
        self.paged = sc.cache_kind == "paged"
        self.free_slots = list(range(sc.n_slots))
        self.active: Dict[int, Request] = {}
        self.preempted: List[Request] = []
        self.key = jax.random.PRNGKey(sc.seed)
        self._slot_keys = jnp.zeros((sc.n_slots, 2), jnp.uint32)
        self._rid = 0
        self.stats = {"peak_active": 0, "n_preempted": 0, "n_deferred": 0}
        # bucketing needs positions to be paddable: causal attention masks
        # padded tails, but SSM prefill state is not position-masked, and a
        # dense sliding-window cache is a window-sized ring that would drop
        # real positions when the padded tail pushes them out (the paged
        # cache stores absolute positions, so it buckets window configs too)
        self._bucketing = (sc.bucket_prompts and cfg.has_attention
                           and not cfg.ssm_state
                           and (self.paged or not cfg.sliding_window))

        if self.paged:
            n_blocks = sc.n_blocks or sc.n_slots * (sc.max_len // sc.block_size)
            self.pm = pkv.PagedCacheManager(
                cfg, n_slots=sc.n_slots, max_len=sc.max_len,
                block_size=sc.block_size, n_blocks=n_blocks)
            self.cache = None  # device view lives in self.pm
        else:
            self.cache = init_cache(cfg, sc.n_slots, sc.max_len)

        if mesh is not None:
            self._build_steps_mesh(mesh)
        else:
            self._build_steps()

        self._last_token = np.zeros((sc.n_slots,), np.int32)
        if sc.temperature > 0:
            self._sample_rows = jax.jit(partial(
                _sample_rows, temperature=sc.temperature, top_k=sc.top_k,
                vocab_size=cfg.vocab_size))

    # ------------------------------------------------------------------
    def _build_steps(self):
        sc, impl = self.sc, self.impl
        if self.paged:
            self._decode = jax.jit(
                lambda p, t, c: forward_decode_paged(p, self.cfg, t, c,
                                                     impl=impl),
                donate_argnums=(2,))
        else:
            self._decode = jax.jit(
                lambda p, t, c: forward_decode(p, self.cfg, t, c, impl=impl),
                donate_argnums=(2,))
        self._prefill = jax.jit(
            lambda p, tk, vs, tl: forward_prefill(
                p, self.cfg, tk, cache_len=sc.max_len, vision=vs, impl=impl,
                true_len=tl, full_cache=self.paged))

    def _build_steps_mesh(self, mesh):
        sc, impl = self.sc, self.impl
        rules = shd.make_rules(mesh, batch=sc.n_slots)
        pshape = jax.eval_shape(lambda: self.params)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shd.evenly(shd.param_pspecs(pshape, rules),
                                      pshape, mesh))
        self.params = jax.device_put(self.params, psh)
        qkv_sh = None
        if self.merged_fast_path:
            # K*/V*-only layout: re-anchor TP head sharding explicitly
            qkv_sh = NamedSharding(
                mesh, P(rules.dp, None, rules.axis("heads"), None))
        if self.paged:
            cshape = jax.eval_shape(self.pm.device_cache)
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.evenly(shd.paged_cache_pspecs(self.cfg, rules),
                           cshape, mesh))
            fwd = lambda p, t, c: forward_decode_paged(
                p, self.cfg, t, c, impl=impl, qkv_sharding=qkv_sh)
        else:
            cshape = jax.eval_shape(lambda: self.cache)
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.evenly(_trim_cache_spec(shd.cache_pspecs(self.cfg, rules),
                                            self.cache), cshape, mesh))
            fwd = lambda p, t, c: forward_decode(
                p, self.cfg, t, c, impl=impl, qkv_sharding=qkv_sh)
        self._decode = jax.jit(
            fwd, donate_argnums=(2,),
            in_shardings=(psh, NamedSharding(mesh, P()), csh),
            out_shardings=(None, csh))
        self._prefill = jax.jit(
            lambda p, tk, vs, tl: forward_prefill(
                p, self.cfg, tk, cache_len=sc.max_len, vision=vs, impl=impl,
                true_len=tl, full_cache=self.paged),
            in_shardings=(psh, None, None, None))

    # ------------------------------------------------------------------
    @property
    def merged_fast_path(self) -> bool:
        """True when serve_step routes through the merged (Q/P-removed)
        decode fast path: no Q or P weights exist, so per-token attention
        streams only K*/V* from HBM.  kp/vp merged variants return False —
        they serve through the generic path (still token-exact)."""
        return (self.cfg.has_attention
                and self.cfg.block_style in ("skipless_merged",
                                             "residual_qpfree")
                and self.cfg.merged_variant == "qp")

    def compiled_decode(self):
        """Lower + compile serve_step for inspection (no execution).

        Used by benchmarks to read ``cost_analysis()`` / HLO of the exact
        program the engine runs — e.g. HBM bytes/token with and without
        the eliminated Q/P weight reads, or the dense-vs-paged cache
        traffic."""
        pshape = jax.eval_shape(lambda: self.params)
        tshape = jax.ShapeDtypeStruct((self.sc.n_slots,), jnp.int32)
        if self.paged:
            cshape = jax.eval_shape(self.pm.device_cache)
        else:
            cshape = jax.eval_shape(lambda: self.cache)
        return self._decode.lower(pshape, tshape, cshape).compile()

    # ------------------------------------------------------------------
    def _bucket_pad(self, toks: np.ndarray) -> Tuple[np.ndarray, int]:
        """Right-pad to the next power-of-two bucket (>= 8) so the prefill
        jit compiles O(log max_len) programs; true length is passed to
        ``forward_prefill`` so logits and cache are exact."""
        n = len(toks)
        if not self._bucketing or n >= self.sc.max_len:
            return toks, n
        b = 8
        while b < n:
            b *= 2
        b = min(b, self.sc.max_len)
        if b == n:
            return toks, n
        return np.concatenate([toks, np.zeros((b - n,), np.int32)]), n

    def submit(self, req: Request, vision: Optional[np.ndarray] = None) -> bool:
        """Prefill a request into a free slot.  Returns False when no slot
        is free or (paged) the block pool can't admit the prompt — the
        caller retries after other requests finish (admission control).

        A request with ``out_tokens`` already populated is a RESUME
        (preempted earlier): its generated tokens re-prefill with the
        prompt and decoding continues where it left off.
        """
        if not self.free_slots:
            return False
        # fail FAST on a request that cannot finish: decode would run past
        # max_len mid-serve (paged: off the block table; dense non-window:
        # silently wrapping the cache over live positions).  Dense sliding-
        # window rings legitimately outlive max_len — the window masks.
        if self.paged or not self.cfg.sliding_window:
            if len(req.prompt) + req.max_new_tokens > self.sc.max_len:
                raise ValueError(
                    f"prompt ({len(req.prompt)}) + max_new_tokens "
                    f"({req.max_new_tokens}) exceeds max_len "
                    f"({self.sc.max_len})")
        resume = bool(req.out_tokens)
        toks = np.asarray(req.prompt, np.int32)
        if resume and len(req.out_tokens) > 1:
            toks = np.concatenate(
                [toks, np.asarray(req.out_tokens[:-1], np.int32)])
        slot = self.free_slots[0]
        n_shared = 0
        if self.paged:
            admitted = self.pm.admit(slot, toks)
            if admitted is None:
                self.stats["n_deferred"] += 1
                return False
            n_shared = admitted
        self.free_slots.pop(0)

        padded, n = self._bucket_pad(toks)
        tl = jnp.full((1,), n, jnp.int32)
        vs = None if vision is None else jnp.asarray(vision)[None]
        logits, one_cache = self._prefill(
            self.params, jnp.asarray(padded, jnp.int32)[None], vs, tl)
        if self.paged:
            self.pm.insert_prefill(slot, one_cache.k[:, 0], one_cache.v[:, 0],
                                   n, n_shared)
        else:
            self.cache = kvc.insert_request(self.cache, one_cache,
                                            jnp.int32(slot))

        if req.rid < 0:
            req.rid = self._rid
            self._rid += 1
        # per-request PRNG stream: key = f(engine seed, submission index);
        # a preempted request resumes from its ADVANCED key, not the start
        # of its stream — replayed draws would make the continuation depend
        # on whether preemption happened
        self._slot_keys = self._slot_keys.at[slot].set(
            jnp.asarray(req.key_state) if req.key_state is not None
            else jax.random.fold_in(self.key, req.rid))
        req.slot = slot
        if resume:
            tok = req.out_tokens[-1]
        else:
            tok = int(self._sample(logits, [slot])[0])
            req.out_tokens = [tok]
            req.remaining = req.max_new_tokens - 1
        self.active[slot] = req
        self._last_token[slot] = int(tok)
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        len(self.active))
        return True

    def step(self) -> Dict[int, int]:
        """One batched decode step for all active slots; returns slot->token."""
        if not self.active:
            return {}
        if self.paged:
            self._make_appendable()
            if not self.active:
                return {}
        tokens = jnp.asarray(self._last_token, jnp.int32)
        if self.paged:
            logits, new_cache = self._decode(self.params, tokens,
                                             self.pm.device_cache())
            self.pm.update_pools(new_cache)
        else:
            logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tokens = np.asarray(self._sample(
            logits, np.arange(self.sc.n_slots)))
        emitted: Dict[int, int] = {}
        for slot, req in list(self.active.items()):
            tok = int(next_tokens[slot])
            req.out_tokens.append(tok)
            req.remaining -= 1
            self._last_token[slot] = tok
            emitted[slot] = tok
            if self.paged:
                self.pm.advance(slot)
            done = req.remaining <= 0 or tok == self.sc.eos_token
            if done:
                if self.paged:
                    self.pm.release(slot)
                else:
                    self.cache = kvc.clear_slot(self.cache, jnp.int32(slot))
                req.slot = -1
                del self.active[slot]
                self.free_slots.append(slot)
        return emitted

    def _make_appendable(self):
        """Guarantee every active slot can write its next token's page,
        preempting the youngest request(s) when the pool is exhausted."""
        while True:
            blocked = [s for s in sorted(self.active)
                       if not self.pm.ensure_appendable(s)]
            if not blocked:
                return
            if len(self.active) == 1:
                raise RuntimeError(
                    "paged pool too small for a single request; raise "
                    "ServeConfig.n_blocks")
            victim = max(self.active, key=lambda s: self.active[s].rid)
            self._preempt(victim)

    def _preempt(self, slot: int):
        req = self.active.pop(slot)
        self.pm.release(slot)
        self.free_slots.append(slot)
        req.slot = -2
        req.key_state = np.asarray(self._slot_keys[slot])  # resume in place
        self.preempted.append(req)
        self.stats["n_preempted"] += 1

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 vision: Optional[Sequence[np.ndarray]] = None) -> List[List[int]]:
        """Continuous batching driver: keeps slots full until all done."""
        pending = [Request(prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens) for p in prompts]
        results: List[Optional[List[int]]] = [None] * len(pending)
        order = {id(r): i for i, r in enumerate(pending)}
        queue = list(pending)
        inflight: List[Request] = []
        vis = list(vision) if vision is not None else [None] * len(pending)
        vqueue = list(vis)
        while queue or self.active or self.preempted:
            while self.free_slots:
                if self.preempted:  # resumes have progress: highest priority
                    if not self.submit(self.preempted[0]):
                        break
                    self.preempted.pop(0)
                elif queue:
                    if not self.submit(queue[0], vision=vqueue[0]):
                        break
                    inflight.append(queue.pop(0))
                    vqueue.pop(0)
                else:
                    break
            if not self.active:
                if queue or self.preempted:
                    raise RuntimeError(
                        "serving stalled: pool cannot admit any pending "
                        "request (raise n_blocks or max_len)")
                break
            self.step()
            for r in list(inflight):
                if r.slot == -1:  # finished (not preempted, not active)
                    results[order[id(r)]] = r.out_tokens
                    inflight.remove(r)
        return results  # type: ignore

    # ------------------------------------------------------------------
    def _sample(self, logits: jnp.ndarray, slots) -> jnp.ndarray:
        """Sample one token per row of ``logits``; ``slots`` names the slot
        each row belongs to so temperature sampling draws from that slot's
        private PRNG stream."""
        if self.sc.temperature <= 0.0:
            if logits.shape[-1] > self.cfg.vocab_size:  # mask padded ids
                pad_mask = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
                logits = jnp.where(pad_mask, logits, -1e30)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sl = jnp.asarray(np.asarray(slots, np.int32))
        toks, new_keys = self._sample_rows(logits, self._slot_keys[sl])
        self._slot_keys = self._slot_keys.at[sl].set(new_keys)
        return toks


def _sample_rows(logits: jnp.ndarray, keys: jnp.ndarray, *,
                 temperature: float, top_k: int, vocab_size: int):
    """Temperature/top-k sampling, one private PRNG key per row.

    Returns (tokens, advanced keys) — each row's key advances only when
    that row samples, so a request's continuation is a pure function of
    (params, prompt, engine seed, submission index)."""
    if logits.shape[-1] > vocab_size:  # mask padded vocab ids
        pad_mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    scaled = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(scaled, top_k)
        scaled = jnp.where(scaled < vals[..., -1:], -1e30, scaled)
    split = jax.vmap(jax.random.split)(keys)  # (R, 2, 2)
    toks = jax.vmap(jax.random.categorical)(split[:, 1], scaled)
    return toks.astype(jnp.int32), split[:, 0]


def _trim_cache_spec(spec_cache: DecodeCache, like: DecodeCache) -> DecodeCache:
    """Drop spec entries for fields that are None in the actual cache."""
    return DecodeCache(*[
        None if getattr(like, f) is None else getattr(spec_cache, f)
        for f in DecodeCache._fields])
