"""Serving engine: prefill + batched decode with continuous batching.

Design (vLLM-style, TPU/JAX-native): the engine schedules requests over a
fixed number of serving SLOTS and drives exactly TWO seams —

  * a ``KVCacheAdapter`` (``serving.adapters``) owning the cache: its
    device state, shapes/partition specs, admission control and the
    prefill-insert path.  Two adapters ship: "dense" (every slot owns a
    worst-case ``max_len`` stretch of one batched ``DecodeCache``) and
    "paged" (slots map fixed-size pages from a shared block pool with
    free-list allocation, prefix sharing + copy-on-write, deferral and
    youngest-preemption-with-exact-resume — at equal HBM the pool
    sustains strictly more concurrent streams on mixed-length traffic,
    which is what amortizes the merged fast path's K*/V*-only weight
    reads).  Paged prefill writes prompt KV DIRECT-TO-PAGE from inside
    the prefill program (``forward_prefill(dest=PagedPrefillDest(…))``,
    pools donated): no worst-case-length intermediate cache, no
    post-prefill scatter.
  * the ``models.backends`` registries, keyed (cache_kind, style, impl)
    for BOTH serving phases: the jitted ``serve_step`` is ONE function,
    ``models.forward_step``, which looks up its per-layer attention route
    in the ``AttentionBackend`` registry, and the adapter's prefill
    program is ONE dispatcher, ``models.forward_prefill``, which looks up
    its whole-sequence route in the ``PrefillBackend`` registry.  Merged
    (Q/P-removed) "qp" models take the fast path in both phases — the
    stream is the query, attention reads only the K*/V* weights, the
    output lands in the FFN-input basis (``merged_fast_path`` /
    ``merged_prefill_fast_path``); kp/vp merged variants route through
    the generic backends (their eliminated projection is an identity
    inside ``_project_qkv``) token-identically to their unmerged source
    model.  Unknown combos fail at Engine construction with the
    registry's KeyError, not mid-serve.

Scheduling facts (unchanged by the redesign): prompt lengths are BUCKETED
(padded to the next power of two, exact logits/cache via ``true_len``) so
a realistic traffic mix compiles O(log max_len) prefill programs; sampling
is greedy / temperature / top-k with PER-SLOT PRNG streams (each request's
key derives from (engine seed, submission index) and advances only with
its own samples, so sampled continuations are traffic-independent and
preemption-exact).  Under a mesh the engine shards params/caches with the
distribution-layer rules (the adapter supplies its cache's specs) and
re-anchors TP head sharding on q/k/v for merged layouts (no wq matmul to
propagate it from).

``generate`` returns per-request :class:`RequestResult`s — a list of
token ids that also carries (prompt_len, new_tokens, ttft_s,
decode_tok_s), so time-to-first-token wins (e.g. paged direct-to-page
prefill) are readable without the benchmark harness.

Backward compatibility: ``ServeConfig(cache_kind=…)`` still works as a
deprecated alias for ``Engine(…, cache=…)``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import sharding as shd
from repro.models import (backends, forward_step, prefill_style_key,
                          serving_style_key)
from repro.obs import NULL, MetricsRegistry, Observer
from repro.serving import hostbufs
from repro.serving.adapters import KVCacheAdapter, make_adapter


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0
    eos_token: int = -1  # -1 => run to max_new_tokens
    seed: int = 0
    cache_kind: Optional[str] = None  # DEPRECATED: use Engine(cache=…)
    block_size: int = 16  # paged: tokens per physical page
    n_blocks: int = 0  # paged pool size; 0 => dense-equivalent HBM
    bucket_prompts: bool = True  # pad prompts to power-of-two buckets
    # observability (repro.obs).  False (default) => the engine's observer
    # is the shared NullObserver: every hook a no-op, clock() == 0.0 — the
    # zero-overhead-off guarantee.  True => a fresh Observer (metrics +
    # trace ring); an Observer instance is adopted as-is (its registry
    # becomes Engine.metrics).
    obs: Any = False


# eq=False: requests are identities, not values.  The generated __eq__
# would compare the prompt ARRAYS, and ``inflight.remove(r)`` /
# ``preempted.remove(r)`` then raise on any ragged out-of-order finish
# (numpy refuses to broadcast (40,) against (24,)).
@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None
    slot: int = -1  # >=0 active; -1 idle/finished; -2 preempted
    remaining: int = 0
    rid: int = -1  # submission index (per-request PRNG stream id)
    key_state: Optional[np.ndarray] = None  # advanced PRNG key (preemption)
    # serving telemetry (host wall-clock, seconds)
    t_arrival: Optional[float] = None  # entered the engine's queue
    t_first: Optional[float] = None  # first token emitted (prefill sample)
    t_last: Optional[float] = None  # most recent token emitted


class RequestResult(list):
    """A finished request's generated token ids — it IS the token list
    (equality/len/slicing behave like before) — plus per-request stats:

      prompt_len    tokens in the submitted prompt
      new_tokens    tokens generated (== len(self))
      ttft_s        arrival -> first token, queueing + prefill included
      decode_tok_s  steady-state decode rate after the first token —
                    None for single-token requests (there IS no steady
                    state to measure; a 0.0 here would pollute means)
    """

    def __init__(self, tokens, *, prompt_len: int, ttft_s: float,
                 decode_tok_s: Optional[float]):
        super().__init__(tokens)
        self.prompt_len = prompt_len
        self.new_tokens = len(tokens)
        self.ttft_s = ttft_s
        self.decode_tok_s = decode_tok_s

    @property
    def stats(self) -> Dict[str, Any]:
        return {"prompt_len": self.prompt_len, "new_tokens": self.new_tokens,
                "ttft_s": self.ttft_s, "decode_tok_s": self.decode_tok_s}


def _timings_of(req: Request) -> Tuple[float, Optional[float]]:
    """(ttft_s, decode_tok_s) from a request's host timestamps.

    decode_tok_s is None — NOT 0.0 — when there is no decode phase to
    rate (single-token requests, missing timestamps): the histogram
    excludes it (``n_excluded``) instead of averaging in a zero."""
    ttft = (req.t_first - req.t_arrival
            if req.t_first is not None and req.t_arrival is not None else 0.0)
    n = len(req.out_tokens)
    tok_s = None
    if n > 1 and req.t_last is not None and req.t_first is not None \
            and req.t_last > req.t_first:
        tok_s = (n - 1) / (req.t_last - req.t_first)
    return ttft, tok_s


def _result_of(req: Request) -> RequestResult:
    ttft, tok_s = _timings_of(req)
    return RequestResult(req.out_tokens, prompt_len=len(req.prompt),
                         ttft_s=ttft, decode_tok_s=tok_s)


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None,
                 impl: str = "xla",
                 cache: Union[None, str, KVCacheAdapter] = None):
        assert cfg.causal, "serving requires a decoder"
        cfg.validate_style()  # merged styles need a square Q basis
        self.cfg, self.sc, self.mesh = cfg, sc, mesh
        self.params = params
        self.impl = impl

        if sc.cache_kind is not None:
            warnings.warn(
                "ServeConfig.cache_kind is deprecated; pass "
                "Engine(..., cache='dense'|'paged') or a KVCacheAdapter "
                "instance", DeprecationWarning, stacklevel=2)
            if cache is None:
                cache = sc.cache_kind
        if cache is None:
            cache = "dense"
        self.kv: KVCacheAdapter = (make_adapter(cache, sc)
                                   if isinstance(cache, str) else cache)
        # resolve BOTH phases' backends NOW: an unknown (cache_kind,
        # style, impl) combo must fail at construction, not mid-serve
        self.backend = backends.get_backend(self.kv.kind,
                                            serving_style_key(cfg), impl)
        self.prefill_backend = backends.get_prefill_backend(
            self.kv.kind, prefill_style_key(cfg), impl)

        self.free_slots = list(range(sc.n_slots))
        self.active: Dict[int, Request] = {}
        self.preempted: List[Request] = []
        self.key = jax.random.PRNGKey(sc.seed)
        self._slot_keys = jnp.zeros((sc.n_slots, 2), jnp.uint32)
        self._rid = 0
        # observability: the engine ALWAYS owns a MetricsRegistry (the
        # always-on scheduler counters below cost one attribute update,
        # same as the dict they replaced — Engine.stats reads through
        # them).  Heavier telemetry (timestamps, histograms, spans) is
        # the Observer's, off by default (NULL: every hook a no-op).
        if isinstance(sc.obs, Observer):
            self.obs = sc.obs
            self.metrics = sc.obs.metrics
        elif sc.obs:
            self.obs = Observer()
            self.metrics = self.obs.metrics
        else:
            self.obs = NULL
            self.metrics = MetricsRegistry()
        self._g_peak = self.metrics.gauge(
            "serve_peak_active", "most slots concurrently decoding")
        self._c_preempted = self.metrics.counter(
            "serve_preempted", "requests evicted mid-decode")
        self._c_deferred = self.metrics.counter(
            "serve_deferred", "admissions deferred (pool exhausted)")
        # bucketing needs positions to be paddable: causal attention masks
        # padded tails, but SSM prefill state is not position-masked, and a
        # dense sliding-window cache is a window-sized ring that would drop
        # real positions when the padded tail pushes them out (the paged
        # cache stores absolute positions, so it buckets window configs too)
        self._bucketing = (sc.bucket_prompts and cfg.has_attention
                           and not cfg.ssm_state
                           and (self.paged or not cfg.sliding_window))

        self.kv.init(cfg, sc)
        self._build_steps()

        # aligned: deterministically on jax's zero-copy path, so a missing
        # copy at ingestion fails every run (serving.hostbufs rationale)
        self._last_token = hostbufs.aligned_zeros((sc.n_slots,), np.int32)
        if sc.temperature > 0:
            self._sample_rows = jax.jit(partial(
                _sample_rows, temperature=sc.temperature, top_k=sc.top_k,
                vocab_size=cfg.vocab_size))
        # lifts the adapter/pool telemetry in as LAZY gauges (no-op off)
        self.obs.attach_engine(self)

    @property
    def stats(self) -> Dict[str, int]:
        """Read-through view of the always-on scheduler counters (the
        pre-obs ``Engine.stats`` dict, now backed by ``self.metrics``)."""
        return {"peak_active": int(self._g_peak.high_water),
                "n_preempted": int(self._c_preempted.value),
                "n_deferred": int(self._c_deferred.value)}

    # ------------------------------------------------------------------
    def _build_steps(self):
        """Wire the jitted serve_step + the adapter's prefill: both are
        registry/adapter lookups — no per-cache-kind engine code."""
        impl, mesh = self.impl, self.mesh
        psh = csh = qkv_sh = None
        if mesh is not None:
            rules = shd.make_rules(mesh, batch=self.sc.n_slots)
            pshape = jax.eval_shape(lambda: self.params)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               shd.evenly(shd.param_pspecs(pshape, rules),
                                          pshape, mesh))
            self.params = jax.device_put(self.params, psh)
            if self.merged_fast_path:
                # K*/V*-only layout: re-anchor TP head sharding explicitly
                qkv_sh = NamedSharding(
                    mesh, P(rules.dp, None, rules.axis("heads"), None))
            cshape = self.kv.spec()
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               shd.evenly(self.kv.pspecs(rules), cshape,
                                          mesh))

        def fwd(p, t, c):
            return forward_step(p, self.cfg, t, c, impl=impl,
                                qkv_sharding=qkv_sh)

        if mesh is not None:
            self._decode = jax.jit(
                fwd, donate_argnums=(2,),
                in_shardings=(psh, NamedSharding(mesh, P()), csh),
                out_shardings=(None, csh))
        else:
            self._decode = jax.jit(fwd, donate_argnums=(2,))
        self.kv.build_prefill(impl, mesh=mesh, params_sharding=psh,
                              cache_shardings=csh, qkv_sharding=qkv_sh)
        # stashed for additional adapter programs (e.g. the scheduler's
        # chunk program) built after construction
        self._shardings = (psh, csh, qkv_sh)
        # introspection alias (tests count compiled prefill buckets here)
        self._prefill = self.kv._prefill

    # ------------------------------------------------------------------
    @staticmethod
    def host_to_device(x, dtype=None) -> jnp.ndarray:
        """The ONE host→device ingestion seam: always copies.

        ``jnp.asarray`` of an aligned dtype-matching numpy array is
        ZERO-copy on CPU, and dispatch is async — ingesting a
        caller-owned buffer (a prompt) or engine-mutated state without a
        copy lets an in-flight program read memory the owner has since
        rewritten.  ``repro.lint.aliasing`` audits this seam; keep every
        numpy→device conversion of externally-owned data routed here."""
        return jnp.asarray(np.array(x, dtype=dtype, copy=True))

    def host_mutable_buffers(self) -> Dict[str, np.ndarray]:
        """Named host-side numpy buffers this engine mutates across steps
        — the ``repro.lint.aliasing`` detector checks every jitted call's
        inputs for shared memory with these."""
        named = {"engine._last_token": self._last_token}
        named.update(self.kv.host_mutable_buffers())
        return named

    @property
    def paged(self) -> bool:
        """True for every block-pool cache kind (fp "paged" AND the int8
        "paged_q8") — scheduling semantics (absolute positions, admission
        control, preemption) are the pool's, not the quantization's."""
        return self.kv.kind != "dense"

    @property
    def cache(self):
        """Dense adapters' batched DecodeCache (None for other kinds) —
        kept for callers that inspect the cache directly."""
        return self.kv.device_cache() if self.kv.kind == "dense" else None

    @property
    def pm(self):
        """Paged adapters' host-side PagedCacheManager (telemetry)."""
        return self.kv.pm

    @property
    def merged_fast_path(self) -> bool:
        """True when serve_step routes through the merged (Q/P-removed)
        decode fast path: no Q or P weights exist, so per-token attention
        streams only K*/V* from HBM.  kp/vp merged variants return False —
        they serve through the generic backend (still token-exact)."""
        return self.backend.fast_path

    @property
    def merged_prefill_fast_path(self) -> bool:
        """True when this engine's prefill routes through the merged
        (Q/P-removed) PREFILL fast path: every self-attention layer of the
        prompt forward runs the stream-as-query flash core — no Q or P
        weight reads, no head-major transposes — cutting prefill HBM
        traffic and TTFT.  kp/vp merged variants and non-attention stacks
        return False (generic prefill backend, still token-exact)."""
        return self.prefill_backend.fast_path

    def compiled_decode(self):
        """Lower + compile serve_step for inspection (no execution).

        Used by benchmarks to read ``cost_analysis()`` / HLO of the exact
        program the engine runs — e.g. HBM bytes/token with and without
        the eliminated Q/P weight reads, or the dense-vs-paged cache
        traffic."""
        pshape = jax.eval_shape(lambda: self.params)
        tshape = jax.ShapeDtypeStruct((self.sc.n_slots,), jnp.int32)
        t0 = self.obs.clock()
        compiled = self._decode.lower(pshape, tshape, self.kv.spec()).compile()
        self._compile_event("decode", None, compiled, t0)
        return compiled

    def compiled_prefill(self, bucket_len: int):
        """Lower + compile this engine's prefill program for one prompt
        bucket (no execution) — e.g. to read the prefill HBM bytes that
        direct-to-page paged prefill saves over dense."""
        t0 = self.obs.clock()
        compiled = self.kv.compiled_prefill(self.params, bucket_len)
        self._compile_event("prefill", bucket_len, compiled, t0)
        return compiled

    def _compile_event(self, phase: str, bucket_len: Optional[int],
                       compiled, t0: float) -> None:
        """Emit a compile metric/span — obs-on only (``as_text`` is
        expensive; the off path must never pay for it)."""
        if not self.obs.enabled:
            return
        t1 = self.obs.clock()
        try:
            hlo_bytes = len(compiled.as_text())
        except Exception:
            hlo_bytes = 0  # backends without HLO text introspection
        self.obs.compile_event(phase, bucket_len, hlo_bytes, t1 - t0)

    # ------------------------------------------------------------------
    def _bucket_pad(self, toks: np.ndarray) -> Tuple[np.ndarray, int]:
        """Right-pad to the next power-of-two bucket (>= 8) so the prefill
        jit compiles O(log max_len) programs; true length is passed to
        ``forward_prefill`` so logits and cache are exact."""
        n = len(toks)
        align = self.kv.bucket_align
        if not self._bucketing or n >= self.sc.max_len:
            # even unbucketed prompts must honor the adapter's alignment
            # (paged_q8 pages quantize whole: no prefill may end mid-page)
            b = -(-n // align) * align
            if b == n:
                return toks, n
            return np.concatenate([toks, np.zeros((b - n,), np.int32)]), n
        b = 8
        while b < n:
            b *= 2
        b = min(b, self.sc.max_len)
        b = -(-b // align) * align
        if b == n:
            return toks, n
        return np.concatenate([toks, np.zeros((b - n,), np.int32)]), n

    def submit(self, req: Request, vision: Optional[np.ndarray] = None) -> bool:
        """Prefill a request into a free slot.  Returns False when no slot
        is free or the adapter can't admit the prompt (paged: pool
        exhausted) — the caller retries after other requests finish
        (admission control).

        A request with ``out_tokens`` already populated is a RESUME
        (preempted earlier): its generated tokens re-prefill with the
        prompt and decoding continues where it left off.
        """
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        if not self.free_slots:
            return False
        # fail FAST on a request that cannot finish: decode would run past
        # max_len mid-serve (paged: off the block table; dense non-window:
        # silently wrapping the cache over live positions).  Dense sliding-
        # window rings legitimately outlive max_len — the window masks.
        if self.paged or not self.cfg.sliding_window:
            if len(req.prompt) + req.max_new_tokens > self.sc.max_len:
                raise ValueError(
                    f"prompt ({len(req.prompt)}) + max_new_tokens "
                    f"({req.max_new_tokens}) exceeds max_len "
                    f"({self.sc.max_len})")
        resume = bool(req.out_tokens)
        toks = np.asarray(req.prompt, np.int32)
        if resume and len(req.out_tokens) > 1:
            toks = np.concatenate(
                [toks, np.asarray(req.out_tokens[:-1], np.int32)])
        slot = self.free_slots[0]
        n_shared = self.kv.admit(slot, toks)
        if n_shared is None:
            self._c_deferred.inc()
            return False
        self.free_slots.pop(0)
        t_p0 = self.obs.clock()  # slot granted: queued span ends here

        padded, n = self._bucket_pad(toks)
        # host_to_device (copy), NOT jnp.asarray: for a bucket-exact int32
        # prompt, `padded` IS the caller's buffer, and the async prefill
        # would read it after submit() returns — a caller reusing its
        # prompt array corrupts an in-flight program (the PR 5 race, at
        # the engine's public boundary)
        vs = None if vision is None else self.host_to_device(vision)[None]
        logits = self.kv.prefill(self.params, slot,
                                 self.host_to_device(padded, np.int32)[None],
                                 n, n_shared, vs)

        if req.rid < 0:
            req.rid = self._rid
            self._rid += 1
        # per-request PRNG stream: key = f(engine seed, submission index);
        # a preempted request resumes from its ADVANCED key, not the start
        # of its stream — replayed draws would make the continuation depend
        # on whether preemption happened
        self._slot_keys = self._slot_keys.at[slot].set(
            jnp.asarray(req.key_state) if req.key_state is not None
            else jax.random.fold_in(self.key, req.rid))
        req.slot = slot
        if resume:
            tok = req.out_tokens[-1]
        else:
            tok = int(self._sample(logits, [slot])[0])
            req.out_tokens = [tok]
            req.remaining = req.max_new_tokens - 1
            now = time.perf_counter()
            req.t_first = req.t_last = now
        self.active[slot] = req
        self._last_token[slot] = int(tok)
        self._g_peak.set_max(len(self.active))
        self.obs.request_admitted(req, slot, n_shared=n_shared,
                                  resume=resume, bucket_len=len(padded),
                                  t_prefill0=t_p0)
        if not resume and (req.remaining <= 0 or tok == self.sc.eos_token):
            # the prefill-sampled token already satisfied the budget (or
            # is EOS): finish now — a decode step would overshoot
            # max_new_tokens by one
            self.kv.release(slot)
            req.slot = -1
            del self.active[slot]
            self.free_slots.append(slot)
            if self.obs.enabled:  # terminal hook: exactly once
                ttft, tok_s = _timings_of(req)
                self.obs.request_finished(req, decode_tok_s=tok_s,
                                          ttft_s=ttft)
        return True

    def step(self) -> Dict[int, int]:
        """One batched decode step for all active slots; returns slot->token."""
        if not self.active:
            return {}
        t0 = self.obs.clock()  # step span includes appendability/preempts
        self._make_appendable()
        if not self.active:
            return {}
        # host_to_device copies: jax CPU zero-copies numpy buffers, and
        # _last_token is mutated in place right after this step dispatches
        tokens = self.host_to_device(self._last_token, np.int32)
        logits, new_cache = self._decode(self.params, tokens,
                                         self.kv.device_cache())
        self.kv.update(new_cache)
        next_tokens = np.asarray(self._sample(
            logits, np.arange(self.sc.n_slots)))
        now = time.perf_counter()
        emitted: Dict[int, int] = {}
        for slot, req in list(self.active.items()):
            tok = int(next_tokens[slot])
            req.out_tokens.append(tok)
            req.remaining -= 1
            req.t_last = now
            self._last_token[slot] = tok
            emitted[slot] = tok
            self.kv.advance(slot)
            done = req.remaining <= 0 or tok == self.sc.eos_token
            if done:
                self.kv.release(slot)
                req.slot = -1
                del self.active[slot]
                self.free_slots.append(slot)
                if self.obs.enabled:  # terminal hook: exactly once
                    ttft, tok_s = _timings_of(req)
                    self.obs.request_finished(req, decode_tok_s=tok_s,
                                              ttft_s=ttft)
        self.obs.step_done(t0, self.obs.clock(), n_active=len(self.active),
                           n_tokens=len(emitted))
        return emitted

    def _make_appendable(self):
        """Guarantee every active slot can write its next token (paged:
        map/CoW the target page), preempting the youngest request(s) when
        the adapter is out of resources.  Dense adapters always succeed."""
        while True:
            blocked = [s for s in sorted(self.active)
                       if not self.kv.ensure_appendable(s)]
            if not blocked:
                return
            if len(self.active) == 1:
                raise RuntimeError(
                    "paged pool too small for a single request; raise "
                    "ServeConfig.n_blocks")
            victim = max(self.active, key=lambda s: self.active[s].rid)
            self._preempt(victim)

    def _preempt(self, slot: int):
        req = self.active.pop(slot)
        self.kv.release(slot)
        self.free_slots.append(slot)
        req.slot = -2
        # np.array (copy), NOT np.asarray: asarray of a device array is a
        # READ-ONLY view that pins the device buffer into host state — the
        # request must own its resume key (lint: NoHostViewOfDeviceBuffer)
        req.key_state = np.array(self._slot_keys[slot])  # resume in place
        self.preempted.append(req)
        self._c_preempted.inc()
        self.obs.request_preempted(req, slot)

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 vision: Optional[Sequence[np.ndarray]] = None
                 ) -> List[RequestResult]:
        """Continuous batching driver: keeps slots full until all done.

        Returns one :class:`RequestResult` per prompt — the generated
        token ids (list semantics preserved) plus prompt_len / new_tokens
        / ttft_s / decode_tok_s."""
        t_gen0 = self.obs.clock()
        t_arrival = time.perf_counter()
        pending = [Request(prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens,
                           t_arrival=t_arrival) for p in prompts]
        results: List[Optional[RequestResult]] = [None] * len(pending)
        order = {id(r): i for i, r in enumerate(pending)}
        queue = list(pending)
        inflight: List[Request] = []
        vis = list(vision) if vision is not None else [None] * len(pending)
        vqueue = list(vis)
        while queue or self.active or self.preempted:
            self.obs.queue_depth(len(queue) + len(self.preempted))
            while self.free_slots:
                if self.preempted:  # resumes have progress: highest priority
                    if not self.submit(self.preempted[0]):
                        break
                    self.preempted.pop(0)
                elif queue:
                    if not self.submit(queue[0], vision=vqueue[0]):
                        break
                    inflight.append(queue.pop(0))
                    vqueue.pop(0)
                else:
                    break
            if not self.active:
                if queue or self.preempted:
                    raise RuntimeError(
                        "serving stalled: pool cannot admit any pending "
                        "request (raise n_blocks or max_len)")
                break
            self.step()
            for r in list(inflight):
                if r.slot == -1:  # finished (not preempted, not active)
                    results[order[id(r)]] = _result_of(r)
                    inflight.remove(r)
        for r in inflight:  # finished at submit time on the final pass
            if r.slot == -1:
                results[order[id(r)]] = _result_of(r)
        if self.obs.enabled:
            self.obs.generate_done(
                t_gen0, self.obs.clock(), n_requests=len(pending),
                n_tokens=sum(r.new_tokens for r in results if r is not None))
        return results  # type: ignore

    # ------------------------------------------------------------------
    def _sample(self, logits: jnp.ndarray, slots) -> jnp.ndarray:
        """Sample one token per row of ``logits``; ``slots`` names the slot
        each row belongs to so temperature sampling draws from that slot's
        private PRNG stream."""
        if self.sc.temperature <= 0.0:
            if logits.shape[-1] > self.cfg.vocab_size:  # mask padded ids
                pad_mask = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
                logits = jnp.where(pad_mask, logits, -1e30)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sl = jnp.asarray(np.asarray(slots, np.int32))
        toks, new_keys = self._sample_rows(logits, self._slot_keys[sl])
        self._slot_keys = self._slot_keys.at[sl].set(new_keys)
        return toks


def _sample_rows(logits: jnp.ndarray, keys: jnp.ndarray, *,
                 temperature: float, top_k: int, vocab_size: int):
    """Temperature/top-k sampling, one private PRNG key per row.

    Returns (tokens, advanced keys) — each row's key advances only when
    that row samples, so a request's continuation is a pure function of
    (params, prompt, engine seed, submission index)."""
    if logits.shape[-1] > vocab_size:  # mask padded vocab ids
        pad_mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    scaled = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(scaled, top_k)
        scaled = jnp.where(scaled < vals[..., -1:], -1e30, scaled)
    split = jax.vmap(jax.random.split)(keys)  # (R, 2, 2)
    toks = jax.vmap(jax.random.categorical)(split[:, 1], scaled)
    return toks.astype(jnp.int32), split[:, 0]
