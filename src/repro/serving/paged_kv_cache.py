"""Paged KV cache: a block pool + free-list allocator + per-slot block tables.

vLLM-style serving memory for the merged decode fast path.  The dense
DecodeCache sizes every slot for the WORST-CASE sequence, so slot count —
and with it the batch that amortizes the per-token K*/V* weight stream —
is capped by ``HBM / (L · max_len · Hkv · Dh)``.  Here physical memory is
a pool of fixed (block_size, Hkv, Dh) pages per layer and each request
maps only the pages its sequence actually occupies, so the same HBM
sustains strictly more concurrent streams on any realistic (mixed-length)
traffic.

Division of labor:
  * DEVICE — the page pools (``PagedDecodeCache.k/v``).  Prompt KV is
    written DIRECTLY into mapped pages by the prefill program itself
    (``models.transformer.forward_prefill(pages=…)`` — no worst-case-
    length intermediate cache, no post-prefill scatter pass); per-token
    appends are inside the jitted decode step (models.transformer); and
    ``copy_block`` (jitted, donated) implements copy-on-write.  Nothing
    here reallocates or recompiles.  ``scatter_prefill_blocks`` is the
    LEGACY insert path (dense intermediate + scatter), retained so the
    benchmark can measure the before/after prefill traffic.
  * HOST — ``BlockAllocator`` (free list + per-page refcounts) and
    ``PagedCacheManager`` (block tables, admission, prefix sharing,
    copy-on-write policy, eviction).  Tables/lengths are tiny int32
    arrays shipped to the device each step.

Prefix sharing: requests with identical prompt prefixes map the same
physical pages.  Full prompt blocks are registered under the token prefix
they contain and are immutable once written (appends land in later
blocks), so sharing them is always exact.  The trailing PARTIAL prompt
block is registered under the entire prompt; its content may later be
extended by the owner's decoded tokens, which is safe because (a) a
sharer's causal mask hides positions beyond its own length, and (b) any
append into a page with refcount > 1 first copies it (copy-on-write), and
decode always writes position ``length`` before attending to it.

The registry is a RADIX TREE over block-aligned token runs
(``serving.radix_tree``): matching an L-token prompt walks its tokens
once (O(L), vs the flat dict's O(L²/bs) tuple-prefix slicing) and —
with ``prefix_retention`` on (the default) — pages of a released
request that the tree references are ADOPTED instead of freed
(``BlockAllocator.retain``: the tree becomes a holder), so popular
prefixes survive their last sharer and later requests hit them warm.
Retained pages are reclaimable: ``_alloc`` evicts LRU leaf-end
tree-only pages under pool pressure before deferring a request.  The
refcount invariant extends to ``ref[p] == live slots mapping p +
(1 if tree-retained)``; every write path already copies-on-write at
ref > 1, so a retained-and-reshared page can never be mutated in place
(the detach-on-shared rule covers tree holds for free).

Sliding-window ring-of-pages: when the config has a sliding window, a
request's block table is a bounded RING of ``ceil(window/bs)+1`` slots
(absolute block b at slot b % ring — ``kernels.paging``), so a windowed
request can never hold more pages than its window needs:

  * ``admit`` maps (and prefix-shares) only the LIVE window's blocks of
    the prompt — blocks every future query has already slid past are
    never allocated, and ``prefill_block_ids`` marks them -1 so the
    direct-to-page scatter drops their KV;
  * on entering a new absolute block, ``ensure_appendable`` RECYCLES the
    ring slot's stale page in place (no alloc, no free, no device copy:
    every offset of the recycled page reconstructs to a position beyond
    the query until decode overwrites it, exactly the dense ring-buffer
    invariant).  A stale page that is still prefix-SHARED is detached
    instead — the ring variant of copy-on-write: release our reference
    (the peer keeps the original bytes) and take a fresh page, so a
    sharer's window rolling forward can never corrupt a slower peer;
  * recycling a solely-owned page drops its prefix-registry entries (its
    bytes no longer hold the registered prefix), so later prompts can
    never share a rolled-over page.  Prompts longer than the window
    register nothing: a prefix chain must start at block 0, which such a
    prompt no longer maps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving import hostbufs
from repro.serving.radix_tree import RadixPrefixTree
from repro.models.transformer import (PagedDecodeCache, PagedQ8DecodeCache,
                                      init_paged_cache, init_paged_q8_cache,
                                      layer_plan, paged_table_blocks)


# ---------------------------------------------------------------------------
# jitted device ops (donated: update in place, no pool-sized copies)
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0, 1))
def scatter_prefill_blocks(k_pool, v_pool, k_blocks, v_blocks, block_ids):
    """LEGACY prefill insert: write a dense-prefilled request's pages into
    the pool.  The engine now prefills direct-to-page
    (``forward_prefill(pages=…)``); this op remains as the before-path for
    ``benchmarks/bench_paged_serving.py``'s prefill-traffic comparison.

    k_blocks/v_blocks: (L, nb, bs, Hkv, Dh) — the request's kv reshaped to
    pages; block_ids: (nb,) int32 physical destinations.  One compiled
    program per distinct nb (bounded by prompt-length bucketing).
    """
    k_pool = k_pool.at[:, block_ids].set(k_blocks.astype(k_pool.dtype))
    v_pool = v_pool.at[:, block_ids].set(v_blocks.astype(v_pool.dtype))
    return k_pool, v_pool


@partial(jax.jit, donate_argnums=(0, 1))
def copy_block(k_pool, v_pool, src, dst):
    """Copy-on-write: duplicate physical page ``src`` into ``dst``."""
    k_pool = k_pool.at[:, dst].set(k_pool[:, src])
    v_pool = v_pool.at[:, dst].set(v_pool[:, src])
    return k_pool, v_pool


@partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def copy_block_q8(k_pool, v_pool, k_scale, v_scale, src, dst):
    """Quantized copy-on-write: a page's int8 bytes and its per-(page,
    kv-head) scale rows are one unit — CoW moves both or the copy
    dequantizes under the wrong scale."""
    k_pool = k_pool.at[:, dst].set(k_pool[:, src])
    v_pool = v_pool.at[:, dst].set(v_pool[:, src])
    k_scale = k_scale.at[:, dst].set(k_scale[:, src])
    v_scale = v_scale.at[:, dst].set(v_scale[:, src])
    return k_pool, v_pool, k_scale, v_scale


# ---------------------------------------------------------------------------
# host-side free-list allocator
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Free list + refcounts over ``n_blocks`` physical pages.

    Refcount > 1 means the page is prefix-shared; writers must
    copy-on-write (the manager enforces this, the allocator only counts).
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks))
        self.ref = hostbufs.aligned_zeros((n_blocks,), np.int32)
        # observability: the benchmark and tests read these
        self.peak_used = 0
        self.n_cow = 0
        self.n_shared_hits = 0
        self.n_recycled = 0  # windowed ring: stale pages reused in place

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages (refcount 1 each); None if the pool is exhausted."""
        if n > len(self._free):
            return None
        ids, self._free = self._free[:n], self._free[n:]
        for i in ids:
            self.ref[i] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return ids

    def fork(self, ids: List[int]) -> None:
        """Share pages with another request (refcount += 1)."""
        for i in ids:
            assert self.ref[i] > 0, f"fork of free page {i}"
            self.ref[i] += 1
        self.n_shared_hits += len(ids)

    def retain(self, ids: List[int]) -> None:
        """Take a reference without counting a shared hit — the prefix
        tree adopting a released request's pages, or an admit pinning
        its matched chain against eviction before it knows the
        admission will succeed."""
        for i in ids:
            assert self.ref[i] > 0, f"retain of free page {i}"
            self.ref[i] += 1

    def release(self, ids: List[int]) -> List[int]:
        """Drop one reference per page; returns the pages that became free."""
        freed = []
        for i in ids:
            assert self.ref[i] > 0, f"release of free page {i}"
            self.ref[i] -= 1
            if self.ref[i] == 0:
                self._free.append(i)
                freed.append(i)
        return freed


class RequestPageHwm:
    """Running max / count / last of per-request page high-water marks.

    Replaces an unbounded per-release ``List[int]`` (a host-memory leak
    in a long-running server): every consumer only ever asked for the
    MAX (obs export), the LAST (tests) or emptiness, so the state is
    three ints — O(1) in requests served."""

    __slots__ = ("max", "count", "last")

    def __init__(self):
        self.max = 0
        self.count = 0
        self.last = 0

    def record(self, hwm: int) -> None:
        if hwm > self.max:
            self.max = hwm
        self.last = hwm
        self.count += 1

    def __bool__(self) -> bool:
        return self.count > 0

    def __repr__(self):
        return (f"RequestPageHwm(max={self.max}, last={self.last}, "
                f"count={self.count})")


# ---------------------------------------------------------------------------
# manager: tables + admission + prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SlotInfo:
    blocks: List[int]  # physical pages: logical order (absolute mode) or
    #                    ring-slot order with -1 unmapped (ring mode)
    abs_blocks: Optional[List[int]] = None  # ring mode: absolute block
    #                    currently held per ring slot (-1 = never entered)
    first_owned: int = 0  # first absolute block NOT prefix-shared (the
    #                       prefill scatter writes from here)
    hwm: int = 0  # most pages this request ever mapped at once


class PagedCacheManager:
    """Owns the device pools and every host-side paging decision.

    The engine calls, per request lifecycle:
      ``admit(slot, tokens)``        admission control + prefix sharing
      ``prefill_block_ids(slot, …)`` per-logical-block destinations for
                                     the direct-to-page prefill scatter
      ``ensure_appendable(slot)``    map/recycle/CoW the page ``length``
                                     falls in
      ``advance(slot)`` / ``release(slot)``
    and per decode step ``device_cache()`` / ``update_pools(new_cache)``.

    With a sliding window, tables are bounded rings of ``ring`` slots and
    out-of-window pages are recycled (module docstring); ``ring_bound`` /
    ``request_page_hwm`` expose the per-request page cap and the measured
    high-water marks.
    """

    def __init__(self, cfg: ModelConfig, *, n_slots: int, max_len: int,
                 block_size: int, n_blocks: int,
                 prefix_retention: bool = True):
        assert layer_plan(cfg)["kind"] == "attn", (
            "paged serving supports attention-only stacks")
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg = cfg
        self.bs = block_size
        self.max_blocks = -(-max_len // block_size)  # admission bound
        # table width: the ring bound when a sliding window makes it
        # strictly smaller than the absolute table (kernels.paging derives
        # ring addressing from this width — one rule for writer + readers)
        self.table_blocks = paged_table_blocks(cfg, block_size, max_len)
        self.ring = self.table_blocks if self.table_blocks < self.max_blocks \
            else 0
        self.n_slots = n_slots
        self._init_pools(cfg, n_blocks, block_size, n_slots, max_len)
        # aligned: host-mutable state always HITS jax's zero-copy path, so
        # a missing .copy() at device ingestion fails deterministically
        # (serving.hostbufs) instead of only on lucky malloc alignments
        self.tables = hostbufs.aligned_full(
            (n_slots, self.table_blocks), -1, np.int32)
        self.lengths = hostbufs.aligned_zeros((n_slots,), np.int32)
        self.allocator = BlockAllocator(n_blocks)
        self._slots: Dict[int, _SlotInfo] = {}
        # slots mid-CHUNKED-prefill: their table rows are masked to -1 in
        # device_cache() so concurrent batched decode steps drop their
        # garbage KV write instead of corrupting half-prefilled or
        # prefix-shared pages (repro.serving.sched)
        self.shielded: set = set()
        self.request_page_hwm = RequestPageHwm()
        # prefix registry: radix tree over block-aligned token runs.  With
        # prefix_retention the tree ADOPTS a released request's registered
        # pages (becomes a refcount holder) instead of letting them free,
        # and _alloc evicts them LRU leaf-end first under pool pressure;
        # without it the tree is a drop-in replacement for the old flat
        # dict (entries die with their page's last sharer).
        self.prefix_retention = prefix_retention
        self.tree = RadixPrefixTree(block_size)

    @property
    def ring_bound(self) -> int:
        """Most pages one request may ever hold: ``ceil(window/bs)+1``
        under a sliding window, else the full table."""
        return self.ring or self.max_blocks

    # -- pool representation hooks (overridden by PagedQ8CacheManager; the
    # allocator / CoW / ring-recycle / prefix-registry logic above and
    # below never looks inside a page, so a new pool layout only supplies
    # these) ------------------------------------------------------------

    def _init_pools(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                    n_slots: int, max_len: int) -> None:
        cache = init_paged_cache(cfg, n_blocks, block_size, n_slots, max_len)
        self.k, self.v = cache.k, cache.v

    def _copy_block_device(self, src: int, dst: int) -> None:
        self.k, self.v = copy_block(self.k, self.v,
                                    jnp.int32(src), jnp.int32(dst))

    # -- device view ----------------------------------------------------

    def device_cache(self) -> PagedDecodeCache:
        # COPY the host bookkeeping before handing it to the device:
        # jax's CPU backend zero-copies suitably-aligned numpy arrays, so
        # jnp.asarray(self.tables) would ALIAS a buffer this manager keeps
        # mutating in place — an asynchronously-dispatched decode step
        # could then read next step's table and scatter KV into the wrong
        # physical page (timing-dependent corruption).
        tbl = self.tables.copy()
        if self.shielded:
            # mid-chunked-prefill slots: every decode-step write against
            # them must DROP (blk -1 clamps out of range in the scatter),
            # and whatever garbage the step attends for them is discarded
            # by the engine — the true mapping stays host-side and feeds
            # the chunk programs directly
            tbl[sorted(self.shielded), :] = -1
        return PagedDecodeCache(
            k=self.k, v=self.v,
            block_tables=jnp.asarray(tbl),
            length=jnp.asarray(self.lengths.copy()))

    def update_pools(self, new: PagedDecodeCache) -> None:
        self.k, self.v = new.k, new.v

    def host_mutable_buffers(self):
        """Named numpy buffers this manager mutates in place between steps
        — the ones ``device_cache`` must copy before device ingestion and
        ``repro.lint.aliasing`` checks every jit input against."""
        return {"pm.tables": self.tables, "pm.lengths": self.lengths,
                "pm.allocator.ref": self.allocator.ref}

    @property
    def pool_bytes(self) -> int:
        return int(self.k.size + self.v.size) * self.k.dtype.itemsize

    # -- prefix sharing (radix tree) -------------------------------------

    def _drop_page(self, bid: int) -> None:
        """Page ``bid``'s bytes are being rewritten (ring recycle) or have
        been freed: registry state must die with them.  The tree also
        removes the page's now-unreachable subtree; any RETAINED pages
        that fall out with it lose the tree's reference here, so a
        retained page can never outlive its resident chain.  An orphan is
        not necessarily FREED by that release: a live slot whose ring
        already rolled past ``bid`` may still map a retained descendant
        (its window covers the orphan but no longer the dropped
        ancestor), in which case ``ref`` simply falls back to the
        live-sharer count and the page dies with its last slot.
        (``allocator.release`` asserts each orphan actually held the
        reference being dropped.)"""
        orphans = self.tree.drop_page(bid)
        if orphans:
            self.allocator.release(orphans)

    def _alloc(self, n: int) -> Optional[List[int]]:
        """``allocator.alloc`` with retention-aware admission: under pool
        pressure, reclaim LRU retained leaf-end pages (whose only
        reference is the tree's) before reporting exhaustion.  Pages a
        live slot maps have ref >= 1 from the slot, so the ref == 1
        guard means eviction can only ever free tree-only pages."""
        ids = self.allocator.alloc(n)
        if ids is not None or not self.prefix_retention:
            return ids
        evicted = self.tree.evict(
            n - self.allocator.n_free,
            lambda p: int(self.allocator.ref[p]) == 1)
        if evicted:
            freed = self.allocator.release(evicted)
            assert len(freed) == len(evicted), (
                "evicted page had holders beyond the tree")
        return self.allocator.alloc(n)

    def drop_prefix_cache(self) -> int:
        """Evict EVERY reclaimable retained page (tests / benchmarks:
        return the pool to live-requests-only state).  Retained pages
        pinned by a live sharer stay.  Returns pages reclaimed."""
        evicted = self.tree.evict(
            self.allocator.n_blocks,
            lambda p: int(self.allocator.ref[p]) == 1)
        if evicted:
            self.allocator.release(evicted)
        return len(evicted)

    # -- request lifecycle ----------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.bs)

    def _first_live_block(self, n_tokens: int) -> int:
        """First absolute block any FUTURE query can still attend: the
        next decode query sits at position ``n_tokens`` and reaches back
        to ``n_tokens - window + 1``; earlier blocks are dead at admit
        time and never mapped (0 without a window)."""
        if not self.ring:
            return 0
        return max(0, n_tokens - self.cfg.sliding_window + 1) // self.bs

    def admit(self, slot: int, tokens: np.ndarray) -> Optional[int]:
        """Try to map ``tokens`` into ``slot``.  Returns the number of
        prefix-SHARED pages (the engine skips writing those), or None when
        the prompt doesn't fit / the pool is exhausted (admission control —
        the caller retries after other requests finish).

        Ring mode maps only the live window's blocks (module docstring);
        a prompt longer than the window shares and registers nothing — a
        prefix chain must start at block 0, which it no longer maps."""
        nb = self.blocks_for(len(tokens))
        if nb > self.max_blocks:
            raise ValueError(
                f"prompt of {len(tokens)} tokens exceeds max_len "
                f"({self.max_blocks * self.bs})")
        b_min = self._first_live_block(len(tokens))
        shared, covered = self.tree.match(tokens) if b_min == 0 else ([], 0)
        # pin the matched chain BEFORE allocating: _alloc may evict
        # retained pages under pressure, and the pages just matched must
        # not be candidates while this admission is in flight
        self.allocator.retain(shared)
        fresh = self._alloc(nb - b_min - len(shared))
        if fresh is None:
            dropped = self.allocator.release(shared)  # unpin
            assert not dropped, "pinned tree page had no other holder"
            return None
        self.allocator.n_shared_hits += len(shared)
        self.tree.hit_tokens += covered
        chain = shared + fresh  # absolute blocks b_min..nb-1, in order
        if self.ring:
            pages = [-1] * self.ring
            absb = [-1] * self.ring
            for i, bid in enumerate(chain):
                pages[(b_min + i) % self.ring] = bid
                absb[(b_min + i) % self.ring] = b_min + i
            info = _SlotInfo(blocks=pages, abs_blocks=absb,
                             first_owned=b_min + len(shared), hwm=len(chain))
        else:
            info = _SlotInfo(blocks=chain, first_owned=len(shared),
                             hwm=len(chain))
        self._slots[slot] = info
        self.tables[slot, :] = -1
        mapped = np.asarray(info.blocks, np.int32)
        self.tables[slot, :len(mapped)] = mapped
        self.lengths[slot] = len(tokens)
        if b_min == 0:
            self.tree.insert(tokens, chain)
        return len(shared)

    def prefill_block_ids(self, slot: int, padded_len: int) -> np.ndarray:
        """Physical destination per logical (absolute) block of a (bucket-
        padded) prefill, for ``forward_prefill(pages=…)``'s direct-to-page
        scatter.  (The skip-shared start is the slot's own
        ``first_owned`` recorded at admit — callers no longer pass it.)

        Entries are -1 (the scatter DROPS them) for (a) prefix-SHARED
        pages — they already hold the prefix, and their in-page tail may
        be another live request's decoded tokens, so they must never be
        rewritten — (b) bucket-padding blocks past the prompt, which this
        slot doesn't own, and (c) under a sliding window, prompt blocks
        already out of every future query's window (never mapped).
        """
        info = self._slots[slot]
        nb = self.blocks_for(int(self.lengths[slot]))
        nbk = -(-padded_len // self.bs)
        assert nbk >= nb, (padded_len, self.lengths[slot])
        ids = np.full((nbk,), -1, np.int32)
        if self.ring:
            for b in range(info.first_owned, nb):
                ids[b] = info.blocks[b % self.ring]
        else:
            ids[info.first_owned:nb] = info.blocks[info.first_owned:nb]
        return ids

    def _cow(self, slot: int, info: _SlotInfo, idx: int, *,
             copy: bool) -> bool:
        """Detach table entry ``idx`` from its shared page onto a fresh
        one.  ``copy`` devices-copies the bytes (mid-block append: earlier
        offsets are live shared content); a windowed recycle skips the
        copy — every offset of the new block is rewritten before any query
        can attend it."""
        bid = info.blocks[idx]
        # _alloc may evict retained pages; the CoW source is safe — its
        # ref > 1 (that's why we're detaching) fails the eviction guard
        fresh = self._alloc(1)
        if fresh is None:
            return False
        if copy:
            self._copy_block_device(bid, fresh[0])
        self.allocator.release([bid])
        info.blocks[idx] = fresh[0]
        self.tables[slot, idx] = fresh[0]
        self.allocator.n_cow += 1
        return True

    def _ensure_ring_block(self, slot: int, info: _SlotInfo, b: int) -> bool:
        """Make absolute block ``b``'s ring slot safely writable: map it if
        never entered, CoW if shared at the same block, and RECYCLE the
        slot's out-of-window page when the window rolled past it (in place
        when solely owned; detached via ``_cow`` when a prefix-sharing
        peer still holds it).  Shared by ``ensure_appendable`` (decode,
        b = length // bs) and ``ensure_chunk`` (chunked prefill's
        progressive ring mapping).  Returns False on pool exhaustion."""
        rs = b % self.ring
        bid = info.blocks[rs]
        if bid < 0:  # ring slot never entered: map a fresh page
            fresh = self._alloc(1)
            if fresh is None:
                return False
            info.blocks[rs] = fresh[0]
            info.abs_blocks[rs] = b
            self.tables[slot, rs] = fresh[0]
            info.hwm = max(info.hwm,
                           sum(1 for p in info.blocks if p >= 0))
            return True
        if info.abs_blocks[rs] == b:  # current block: append in place
            if self.allocator.ref[bid] > 1 and \
                    not self._cow(slot, info, rs, copy=True):
                return False
            return True
        # window rolled past the slot's old block: recycle
        if self.allocator.ref[bid] > 1:
            # a prefix-sharing peer OR the tree's retention still holds
            # the old bytes: detach, never rewrite in place
            if not self._cow(slot, info, rs, copy=False):
                return False
        else:
            self._drop_page(bid)  # bytes no longer hold the prefix
        self.allocator.n_recycled += 1
        info.abs_blocks[rs] = b
        return True

    def ensure_appendable(self, slot: int) -> bool:
        """Make the page that position ``lengths[slot]`` falls into safely
        writable: map it if unmapped, copy-on-write if prefix-shared, and
        under a sliding window RECYCLE the ring slot's out-of-window page
        (in place when solely owned; detached via ``_cow`` when a prefix-
        sharing peer still holds it).  Returns False when the pool is
        exhausted (caller preempts)."""
        info = self._slots[slot]
        li = int(self.lengths[slot]) // self.bs  # absolute block of write
        if li >= self.max_blocks:
            raise ValueError(f"slot {slot} hit max_len; request too long")
        if self.ring:
            return self._ensure_ring_block(slot, info, li)
        if li >= len(info.blocks):
            fresh = self._alloc(1)
            if fresh is None:
                return False
            info.blocks.append(fresh[0])
            self.tables[slot, li] = fresh[0]
            info.hwm = max(info.hwm, len(info.blocks))
            return True
        if self.allocator.ref[info.blocks[li]] > 1:
            # shared page: copy before writing
            return self._cow(slot, info, li, copy=True)
        return True

    def advance(self, slot: int) -> None:
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        """Return a finished/preempted request's pages (shared pages stay
        resident for their other holders).  With ``prefix_retention``,
        pages the radix tree references are ADOPTED first — the tree
        takes a reference (``retained``) so registered prefixes survive
        their last sharer until pool pressure evicts them."""
        info = self._slots.pop(slot, None)
        self.shielded.discard(slot)
        if info is None:
            return
        self.request_page_hwm.record(info.hwm)
        live = [p for p in info.blocks if p >= 0]
        if self.prefix_retention:
            for p in live:
                if p not in self.tree.retained and self.tree.references(p):
                    self.allocator.retain([p])
                    self.tree.retained.add(p)
        for bid in self.allocator.release(live):
            self._drop_page(bid)
        self.tables[slot, :] = -1
        self.lengths[slot] = 0

    # -- chunked prefill (repro.serving.sched) ---------------------------

    def admit_chunked(self, slot: int, tokens: np.ndarray) -> Optional[int]:
        """Admission for CHUNKED prefill: like ``admit``, except

        * ``lengths[slot]`` tracks the chunk FRONTIER (0 now, advanced by
          ``set_frontier`` after each chunk, total at ``finish_chunked``);
        * prefix REGISTRATION is deferred to ``finish_chunked`` — a sharer
          admitted mid-prefill would attend pages whose chunks haven't run
          (consequence: two identical prompts in flight simultaneously
          don't share with each other, only with finished residents);
        * ring (sliding-window) mode maps NOTHING up front — early chunks
          need blocks that are dead at final-query time but live for their
          own queries, so ``ensure_chunk`` maps each chunk's block
          progressively and the ring recycles them as the window rolls;
          ring mode neither shares nor registers (as at ``admit`` for
          longer-than-window prompts, now for every chunked prompt);
        * the slot is SHIELDED: ``device_cache`` masks its table row to -1
          so interleaved batched decode steps drop their garbage write.
          The scheduler unshields when it activates the slot for decode.

        Returns the number of prefix-shared pages, or None when the pool
        can't hold the prompt's fresh pages right now (caller re-queues).
        """
        nb = self.blocks_for(len(tokens))
        if nb > self.max_blocks:
            raise ValueError(
                f"prompt of {len(tokens)} tokens exceeds max_len "
                f"({self.max_blocks * self.bs})")
        if self.ring:
            info = _SlotInfo(blocks=[-1] * self.ring,
                             abs_blocks=[-1] * self.ring,
                             first_owned=0, hwm=0)
            shared: List[int] = []
        else:
            shared, covered = self.tree.match(tokens)
            self.allocator.retain(shared)  # pin vs eviction (see admit)
            fresh = self._alloc(nb - len(shared))
            if fresh is None:
                dropped = self.allocator.release(shared)  # unpin
                assert not dropped, "pinned tree page had no other holder"
                return None
            self.allocator.n_shared_hits += len(shared)
            self.tree.hit_tokens += covered
            info = _SlotInfo(blocks=shared + fresh,
                             first_owned=len(shared),
                             hwm=nb)
        self._slots[slot] = info
        self.tables[slot, :] = -1
        mapped = np.asarray(info.blocks, np.int32)
        self.tables[slot, :len(mapped)] = mapped
        self.lengths[slot] = 0  # chunk frontier
        self.shielded.add(slot)
        return len(shared)

    def ensure_chunk(self, slot: int, start: int, end: int) -> bool:
        """Make the pages for chunk [start, end) writable.  Absolute mode
        maps the whole prompt at ``admit_chunked``, so this is a no-op;
        ring mode maps/recycles each of the chunk's blocks in turn (the
        scheduler pins the chunk width to one block, but the loop is
        general).  Returns False on pool exhaustion (caller preempts)."""
        info = self._slots[slot]
        if not self.ring:
            return True
        for b in range(start // self.bs, -(-end // self.bs)):
            if not self._ensure_ring_block(slot, info, b):
                return False
        return True

    def chunk_block_ids(self, slot: int, start: int, end: int,
                        n_tokens: int) -> np.ndarray:
        """Physical destination per logical block of chunk [start, end) of
        an ``n_tokens``-long prompt — the per-chunk slice of the
        ``prefill_block_ids`` contract: -1 (the scatter DROPS the write)
        for prefix-shared pages and for padding blocks wholly past the
        prompt on a padded final chunk."""
        info = self._slots[slot]
        b0, b1 = start // self.bs, -(-end // self.bs)
        nb = self.blocks_for(n_tokens)
        ids = np.full((b1 - b0,), -1, np.int32)
        for b in range(b0, min(b1, nb)):
            if self.ring:
                if info.abs_blocks[b % self.ring] == b:
                    ids[b - b0] = info.blocks[b % self.ring]
            elif b >= info.first_owned:
                ids[b - b0] = info.blocks[b]
        return ids

    def set_frontier(self, slot: int, n: int) -> None:
        """Advance the chunk frontier: tokens [0, n) of the slot's prompt
        are now resident (= the next chunk's start)."""
        self.lengths[slot] = n

    def finish_chunked(self, slot: int, tokens: np.ndarray) -> None:
        """Chunked prefill complete: publish the full length and (absolute
        mode) register the now-fully-written pages for prefix sharing.
        The shield stays ON — the scheduler drops it only when it
        activates the slot for decode, so a decode step dispatched in the
        same iteration still can't write into a shared trailing page."""
        info = self._slots[slot]
        self.lengths[slot] = len(tokens)
        if not self.ring:
            self.tree.insert(tokens, info.blocks)

    def unshield(self, slot: int) -> None:
        """Expose the slot's true table row to decode steps again (called
        at decode activation, after the iteration's decode dispatch)."""
        self.shielded.discard(slot)


# ---------------------------------------------------------------------------
# quantized pool: int8 pages + per-(page, kv-head) scales
# ---------------------------------------------------------------------------

class PagedQ8CacheManager(PagedCacheManager):
    """``PagedCacheManager`` over int8 pools with per-(page, kv-head)
    float32 scale arrays (``kernels.quant`` layout).

    Every host-side paging decision — admission, prefix sharing, CoW,
    ring recycle, shields, the registry — is inherited untouched: those
    move PAGES, and a q8 page is just (int8 bytes, scale row) instead of
    fp bytes.  Only the pool-representation hooks differ, so the scales
    provably travel with their page through every lifecycle transition:

      * ``_init_pools``        allocates int8 pools + zero scale arrays;
      * ``_copy_block_device`` CoW copies bytes AND scale rows atomically
        (``copy_block_q8``) — a detached page dequantizes identically;
      * recycle / fresh map touch no device state here, exactly like the
        fp manager: decode's quantize-on-write resets a page's scale when
        it enters the page at offset 0 (``kernels.quant.q8_append_token``),
        so a stale recycled scale is garbage that is never read, same as
        the stale page bytes.
    """

    def _init_pools(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                    n_slots: int, max_len: int) -> None:
        cache = init_paged_q8_cache(cfg, n_blocks, block_size, n_slots,
                                    max_len)
        self.k, self.v = cache.k, cache.v
        self.k_scale, self.v_scale = cache.k_scale, cache.v_scale

    def _copy_block_device(self, src: int, dst: int) -> None:
        self.k, self.v, self.k_scale, self.v_scale = copy_block_q8(
            self.k, self.v, self.k_scale, self.v_scale,
            jnp.int32(src), jnp.int32(dst))

    def device_cache(self) -> PagedQ8DecodeCache:
        # same copy-before-ingest + shield masking discipline as the base
        # manager (see its device_cache comments)
        tbl = self.tables.copy()
        if self.shielded:
            tbl[sorted(self.shielded), :] = -1
        return PagedQ8DecodeCache(
            k=self.k, v=self.v,
            k_scale=self.k_scale, v_scale=self.v_scale,
            block_tables=jnp.asarray(tbl),
            length=jnp.asarray(self.lengths.copy()))

    def update_pools(self, new: PagedQ8DecodeCache) -> None:
        self.k, self.v = new.k, new.v
        self.k_scale, self.v_scale = new.k_scale, new.v_scale

    @property
    def pool_bytes(self) -> int:
        return (int(self.k.size + self.v.size) * self.k.dtype.itemsize
                + int(self.k_scale.size + self.v_scale.size)
                * self.k_scale.dtype.itemsize)
