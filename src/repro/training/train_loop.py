"""Distributed train step + resumable Trainer.

``make_train_step`` builds the jitted (donated) step:
  * bf16 compute / fp32 params & optimizer (mixed precision),
  * remat (activation checkpointing) around each block scan step,
  * gradient-accumulation microbatching (lax.scan over microbatches — also
    the compute/comm overlap lever: XLA overlaps microbatch i's DP
    all-reduce with microbatch i+1's compute),
  * MoE aux-loss weighting.

``Trainer`` owns mesh/shardings, checkpoint/resume, preemption, straggler
monitoring, and metrics logging — the full single-controller production
loop, parameterized by (config, mesh) so tests drive it on tiny meshes.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import sharding as shd
from repro.models import forward_seq, init_params, lm_loss
from repro.training import checkpoint as ckpt_lib
from repro.training.data import DataConfig, make_dataset
from repro.training.fault_tolerance import (
    PREEMPTION_EXIT_CODE, PreemptionHandler, StragglerMonitor)
from repro.training.optimizer import make_optimizer


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 1e-2,
                 remat: bool = True, impl: str = "xla", unroll: bool = False,
                 logits_sharding=None, stream_sharding=None,
                 qkv_sharding=None):
    def loss_fn(params, batch):
        logits, aux, _ = forward_seq(params, cfg, batch["inputs"],
                                     vision=batch.get("vision"),
                                     impl=impl, remat=remat, unroll=unroll,
                                     stream_sharding=stream_sharding,
                                     qkv_sharding=qkv_sharding)
        if logits_sharding is not None:
            # §Perf H1: keep the fp32 logits/loss sharded over (dp, vocab-tp)
            # instead of letting GSPMD gather a (B, S, V) fp32 buffer
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        loss = lm_loss(logits, batch["labels"], vocab_size=cfg.vocab_size)
        return loss + aux_weight * aux, {"loss": loss, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, *, grad_accum: int = 1,
                    aux_weight: float = 1e-2, remat: bool = True,
                    impl: str = "xla", unroll: bool = False,
                    logits_sharding=None, stream_sharding=None,
                    qkv_sharding=None):
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, remat=remat, impl=impl,
                           unroll=unroll, logits_sharding=logits_sharding,
                           stream_sharding=stream_sharding,
                           qkv_sharding=qkv_sharding)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            B = batch["inputs"].shape[0]
            assert B % grad_accum == 0, (B, grad_accum)
            mb = B // grad_accum

            def slice_mb(i, x):
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc_grads, acc_metrics = carry
                micro = jax.tree.map(partial(slice_mb, i), batch)
                (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro)
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                acc_metrics = jax.tree.map(jnp.add, acc_metrics, metrics)
                return (acc_grads, acc_metrics), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"loss": jnp.float32(0), "aux_loss": jnp.float32(0)}
            (grads, msum), _ = jax.lax.scan(
                body, (zero_g, zero_m), jnp.arange(grad_accum))
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m / grad_accum, msum)

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1
    grad_accum: int = 1
    optimizer: str = "adamw"
    seed: int = 0
    remat: bool = True
    straggler_factor: float = 3.0
    stop_after: Optional[int] = None  # pause mid-schedule (e.g. simulated
    # preemption windows in tests); LR schedule still spans `steps`


class Trainer:
    """Single-controller resumable trainer (production loop in miniature)."""

    def __init__(self, cfg: ModelConfig, tc: TrainerConfig, dc: DataConfig,
                 mesh=None, corpus_path: Optional[str] = None):
        self.cfg, self.tc, self.dc = cfg, tc, dc
        self.mesh = mesh
        self.dataset = make_dataset(cfg, dc, corpus_path)
        self.optimizer = make_optimizer(tc.optimizer, tc.lr, tc.warmup, tc.steps,
                                        tc.weight_decay)
        self.preempt = PreemptionHandler()
        self.straggler = StragglerMonitor(tc.straggler_factor)
        self.ckpt = ckpt_lib.AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep_ckpts)
        self.metrics_log: list = []

        key = jax.random.PRNGKey(tc.seed)
        params = init_params(key, cfg)
        opt_state = self.optimizer.init(params)
        self.start_step = 0

        # resume from the latest checkpoint if present
        latest = ckpt_lib.latest_step(tc.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(tc.ckpt_dir, latest,
                                     {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            self.start_step = latest

        step_fn = make_train_step(cfg, self.optimizer,
                                  grad_accum=tc.grad_accum, remat=tc.remat)
        if mesh is not None:
            rules = shd.make_rules(mesh, batch=dc.global_batch)
            pshape = jax.eval_shape(lambda: params)
            pspec = shd.evenly(shd.param_pspecs(pshape, rules), pshape, mesh)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
            oshape = jax.eval_shape(lambda: opt_state)
            osh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.evenly(_opt_pspecs(opt_state, pspec, mesh), oshape, mesh))
            bsh = {k: NamedSharding(mesh, P(rules.dp, *([None] * (v.ndim - 1))))
                   for k, v in self.dataset.batch_at(0).items()}
            self._jit_step = jax.jit(step_fn,
                                     in_shardings=(psh, osh, bsh),
                                     out_shardings=(psh, osh, None),
                                     donate_argnums=(0, 1))
            params = jax.device_put(params, psh)
            opt_state = jax.device_put(opt_state, osh)
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        self.params, self.opt_state = params, opt_state

    def run(self) -> Dict[str, Any]:
        tc = self.tc
        self.preempt.install()
        final_metrics: Dict[str, Any] = {}
        step = self.start_step
        stop = tc.steps if tc.stop_after is None else min(tc.steps, tc.stop_after)
        while step < stop:
            self.straggler.step_start()
            batch = self.dataset.batch_at(step)
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch)
            step += 1
            dt = self.straggler.step_end(step)
            if step % tc.log_every == 0 or step == tc.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, sec_per_step=round(dt, 4))
                self.metrics_log.append(m)
                print(json.dumps(m), flush=True)
                final_metrics = m
            if step % tc.ckpt_every == 0 or step == stop:
                self._save(step)
            if self.preempt.preempted:
                self._save(step)
                self.ckpt.wait()
                print(f"preempted at step {step}; checkpointed; exiting "
                      f"{PREEMPTION_EXIT_CODE}", flush=True)
                sys.exit(PREEMPTION_EXIT_CODE)
        self.ckpt.wait()
        return final_metrics

    def _save(self, step: int):
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       metadata={"config": self.cfg.name, "step": step,
                                 "data_seed": self.dc.seed})


def _opt_pspecs(opt_state, param_pspec, mesh, zero1: bool = False,
                dp_axes=None):
    """Optimizer-state specs: mu/nu follow the params; scalars replicate.

    ``zero1=True`` additionally shards each mu/nu tensor over the data axis
    on its first unsharded dim (ZeRO-1): GSPMD then reduce-scatters the
    gradients, computes the update shard-locally, and all-gathers only the
    updated params — cutting both optimizer memory (÷|dp|) and gradient
    collective bytes (all-reduce -> reduce-scatter + small all-gather)."""
    def like(path, leaf):
        # AdamWState(step, mu, nu): NamedTuple fields appear in the path
        names = [getattr(p, "name", getattr(p, "key", "")) for p in path]
        if names and names[0] == "step":
            return P()
        # strip the leading field name and look up the param spec
        sub = param_pspec
        for n in names[1:]:
            sub = sub[n]
        if zero1 and dp_axes:
            parts = list(tuple(sub) + (None,) * (leaf.ndim - len(sub)))
            for d in range(leaf.ndim):
                if parts[d] is None:
                    parts[d] = dp_axes
                    break
            return P(*parts)
        return sub

    return jax.tree_util.tree_map_with_path(like, opt_state)
