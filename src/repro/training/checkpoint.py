"""Sharded, atomic, async checkpointing with resume (fault tolerance core).

Layout:
  <dir>/step_00000100/
      manifest.json        tree structure + shapes/dtypes + metadata
      arrays.npz           leaf arrays, keyed by flattened path
  <dir>/LATEST             text file containing "step_00000100" (atomic)

Guarantees:
  * atomic: writes go to ``<dir>/.tmp.step_X`` then os.replace() — a crash
    mid-save never corrupts the latest checkpoint;
  * restartable: ``restore_latest`` finds LATEST (or scans) and rebuilds the
    exact pytree (params, optimizer state, data step, rng);
  * async: ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread so the train loop is not blocked;
  * bounded: ``keep`` newest checkpoints are retained, older ones GC'd.

On a multi-host fleet each process writes its addressable shards under
``shard_<process>/`` with the same manifest; this container is one process,
so the code path writes a single shard but the layout is fleet-shaped.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy's npz format cannot round-trip ml_dtypes (bfloat16, …); encode them
# as same-width unsigned ints and restore via the manifest dtype.
_ENCODED = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> np.ndarray:
    enc = _ENCODED.get(str(arr.dtype))
    return arr.view(enc) if enc is not None else arr


def _decode(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _ENCODED:
        return arr.view(getattr(ml_dtypes, dtype))
    return arr


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_name(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _name(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, *, metadata: Optional[dict] = None,
         keep: int = 3, process_index: int = 0) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp.{name}.{process_index}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: _encode(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX

    # atomic LATEST pointer
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-then-write-in-background; at most one write in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        # snapshot to host memory now (device buffers may be donated later)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, metadata=metadata,
                     keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, ValueError, IndexError):
        steps = sorted(d for d in os.listdir(ckpt_dir)
                       if d.startswith("step_")) if os.path.isdir(ckpt_dir) else []
        return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Rebuild a pytree with the same structure as ``like``."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = read_manifest(ckpt_dir, step)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: _decode(z[k], manifest["dtypes"][k]) for k in z.files}

    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = "/".join(_name(x) for x in p)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(ckpt_dir: str, like: Any) -> Tuple[Optional[int], Any]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None, like
    return step, restore(ckpt_dir, step, like)


def read_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
