"""Deterministic, restartable data pipelines.

Every pipeline is a pure function of (seed, step, host_shard) — no hidden
iterator state — so checkpoint/restart and elastic re-sharding are exact:
the loader's "state" is just the integer step, which is stored in the
checkpoint.  ``host_id``/``n_hosts`` shard the global batch across processes
(on this container n_hosts=1; the sharding logic is unit-tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Deterministic token stream: tokens = PRNG(seed, step, position).

    Not i.i.d. noise — a light Markov structure (next token depends on the
    previous token and a per-sequence key) so a model can actually reduce
    loss on it, which the training example and tests rely on.
    """

    def __init__(self, dc: DataConfig, vocab_size: int, family: str = "dense",
                 d_model: int = 0, n_vision_tokens: int = 0):
        self.dc = dc
        self.vocab = vocab_size
        self.family = family
        self.d_model = d_model
        self.n_vision_tokens = n_vision_tokens

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.RandomState(
            (dc.seed * 1_000_003 + step) % (2**31 - 1))
        # skip rows belonging to other hosts deterministically
        all_tokens = rng.randint(
            0, self.vocab, size=(dc.global_batch, dc.seq_len + 1), dtype=np.int64)
        # Markov-ify: t[i+1] = (t[i] + noise % 17) % vocab — a local additive
        # drift, so a model that attends to the previous token drops from
        # ln(V) to ~ln(17) loss quickly (the learnability contract that
        # tests/examples rely on)
        noise = all_tokens
        tok = np.empty_like(noise)
        tok[:, 0] = noise[:, 0]
        for i in range(1, tok.shape[1]):
            tok[:, i] = (tok[:, i - 1] + noise[:, i] % 17) % self.vocab
        lo = dc.host_id * dc.host_batch
        tok = tok[lo:lo + dc.host_batch]
        batch = {
            "inputs": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32),
        }
        if self.family == "audio":
            frames = rng.standard_normal(
                (dc.global_batch, dc.seq_len, self.d_model)).astype(np.float32)
            batch["inputs"] = frames[lo:lo + dc.host_batch]
            batch["labels"] = batch["labels"]
        if self.family == "vlm":
            vis = rng.standard_normal(
                (dc.global_batch, self.n_vision_tokens, self.d_model)).astype(np.float32)
            batch["vision"] = vis[lo:lo + dc.host_batch]
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteCorpus:
    """Byte-level LM over a real text file, deterministic per (seed, step).

    The file is mapped once; batches are fixed-length windows at positions
    drawn from a per-step PRNG, sharded across hosts by interleaving.
    """

    def __init__(self, dc: DataConfig, path: str, vocab_size: int = 256):
        self.dc = dc
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(self.data) > dc.seq_len + 2, "corpus too small"
        self.vocab = vocab_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.RandomState((dc.seed * 7_368_787 + step) % (2**31 - 1))
        starts = rng.randint(0, len(self.data) - dc.seq_len - 1,
                             size=(dc.global_batch,))
        lo = dc.host_id * dc.host_batch
        starts = starts[lo:lo + dc.host_batch]
        tok = np.stack([self.data[s:s + dc.seq_len + 1] for s in starts]).astype(np.int32)
        return {"inputs": tok[:, :-1] % self.vocab,
                "labels": tok[:, 1:] % self.vocab}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg: ModelConfig, dc: DataConfig, corpus_path: Optional[str] = None):
    if corpus_path:
        return ByteCorpus(dc, corpus_path, vocab_size=min(cfg.vocab_size, 256))
    return SyntheticLM(dc, cfg.vocab_size, family=cfg.family,
                       d_model=cfg.d_model, n_vision_tokens=cfg.n_vision_tokens)
