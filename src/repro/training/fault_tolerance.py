"""Fault-tolerance machinery: preemption, stragglers, restart policy.

On a 1000+ node fleet the scheduler sends SIGTERM with a grace window
before reclaiming a slice; ``PreemptionHandler`` converts that into a
cooperative "checkpoint now and exit 143" at the next step boundary.
``StragglerMonitor`` tracks per-step wall time and raises an alarm hook
when a step exceeds ``factor`` × the trailing median — on a real fleet the
hook feeds the job controller (which can evict the slow host / reshard);
here it logs and counts (and is unit-tested).

Restart policy is pure: the Trainer is a function of (checkpoint, step),
and the data pipeline is a function of (seed, step), so a restart — on the
same or a DIFFERENT pod count — reproduces the exact token stream.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, List, Optional


PREEMPTION_EXIT_CODE = 143


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._installed = False
        self._signals = signals

    def install(self):
        if self._installed:
            return
        for s in self._signals:
            try:
                signal.signal(s, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass
        self._installed = True

    def _on_signal(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self):  # for tests / manual drills
        self._requested = True


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 50,
                 alarm: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.alarm = alarm or (lambda step, dt, med: None)
        self.durations: List[float] = []
        self.alarms: List[int] = []
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        if len(self.durations) >= 5:
            med = statistics.median(self.durations[-self.window:])
            if dt > self.factor * med:
                self.alarms.append(step)
                self.alarm(step, dt, med)
        self.durations.append(dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Test-friendly: feed a duration directly; returns alarmed?"""
        alarmed = False
        if len(self.durations) >= 5:
            med = statistics.median(self.durations[-self.window:])
            if dt > self.factor * med:
                self.alarms.append(step)
                self.alarm(step, dt, med)
                alarmed = True
        self.durations.append(dt)
        return alarmed
