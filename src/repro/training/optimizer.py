"""Optimizers and LR schedules, pure JAX (optax is not available offline).

AdamW with decoupled weight decay and global-norm clipping; Lion as the
low-memory alternative.  Optimizer state is a pytree mirroring the params,
so the distribution layer shards it with the same PartitionSpecs as the
parameters (or ZeRO-style over the data axis — see distribution/sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: Any  # pytree like params
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]  # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        def z(p):
            return jnp.zeros_like(p, dtype=jnp.float32)

        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm else 1.0
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m2 / c1
            vhat = v2 / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


class LionState(NamedTuple):
    step: jnp.ndarray
    mu: Any


@dataclasses.dataclass(frozen=True)
class Lion:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.99
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> LionState:
        return LionState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(self, grads, state: LionState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm else 1.0
        lr = self.lr(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) * scale
            d = jnp.sign(self.b1 * m + (1 - self.b1) * g)
            if self.weight_decay and p.ndim >= 2:
                d = d + self.weight_decay * p.astype(jnp.float32)
            m2 = self.b2 * m + (1 - self.b2) * g
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m2

        out = jax.tree.map(upd, grads, state.mu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, LionState(step=step, mu=new_mu)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return f


def linear_schedule(peak_lr: float, warmup: int, total: int) -> Callable:
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        decay = jnp.clip(1.0 - (s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return peak_lr * jnp.where(s < warmup, warm, decay)
    return f


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.full((), lr, jnp.float32)


def make_optimizer(name: str, lr: float, warmup: int, total_steps: int,
                   weight_decay: float = 0.1, clip_norm: float = 1.0):
    sched = cosine_schedule(lr, warmup, total_steps)
    if name == "adamw":
        return AdamW(lr=sched, weight_decay=weight_decay, clip_norm=clip_norm)
    if name == "lion":
        return Lion(lr=sched, weight_decay=weight_decay, clip_norm=clip_norm)
    raise ValueError(name)
