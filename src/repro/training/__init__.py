from repro.training.optimizer import AdamW, Lion, make_optimizer, global_norm
from repro.training.data import DataConfig, SyntheticLM, ByteCorpus, make_dataset
from repro.training import checkpoint
from repro.training.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.training.train_loop import (
    Trainer,
    TrainerConfig,
    make_loss_fn,
    make_train_step,
)

__all__ = [
    "AdamW", "Lion", "make_optimizer", "global_norm",
    "DataConfig", "SyntheticLM", "ByteCorpus", "make_dataset",
    "checkpoint", "PreemptionHandler", "StragglerMonitor",
    "Trainer", "TrainerConfig", "make_loss_fn", "make_train_step",
]
