import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); this module is the only place the 512-device flag is
set — smoke tests and benchmarks see 1 device.

Per cell this driver:
  1. builds the step (train_step / prefill / serve_step) for the arch,
  2. jits with explicit in/out shardings from the distribution layer,
  3. ``.lower(**ShapeDtypeStructs).compile()`` — proving the sharding
     config is coherent (no mismatched collectives, fits memory),
  4. records ``memory_analysis()``, ``cost_analysis()`` and the collective
     operand bytes parsed from the post-SPMD HLO — the inputs to
     EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "u16": 2, "s16": 2, "pred": 1, "u8": 1, "s8": 1, "c64": 8}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|u32|s32|u16|s16|pred|u8|s8|c64)"
                       r"\[([0-9,]*)\]")


# re-exported for existing callers; the implementation lives in
# core.analysis so benchmarks can use it WITHOUT importing this module
# (whose import mutates XLA_FLAGS to fake 512 host devices)
from repro.core.analysis import cost_dict  # noqa: F401,E402


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text.

    Shapes in the SPMD-partitioned module are per-shard, so the totals here
    are per-chip bytes; ``collective term = per_chip_bytes / link_bw``
    (algebraically equal to total_bytes / (chips × link_bw)).
    """
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = re.match(r"%?[\w.\-]+\s*=\s*", s)
        if not m:
            continue
        rest = s[m.end():]
        op = None
        for c in _COLLECTIVES:
            # opcode appears right after the result shape, before '('
            if re.search(r"\)?\s" + c + r"(-start)?\(", " " + rest):
                op = c
                break
        if op is None:
            continue
        shapes = _SHAPE_RE.findall(rest)
        if not shapes:
            continue
        # first shape(s) before the opcode are the result; operands follow
        # the '(': count shapes appearing after the first '(' of the op call
        paren = rest.index("(")
        operand_shapes = _SHAPE_RE.findall(rest[paren:])
        nbytes = 0
        for dt, dims in operand_shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_op[op] += nbytes
        counts[op] += 1
    total = sum(per_op.values())
    return {"per_op_bytes": per_op, "counts": counts, "total_bytes": total}


def _lower_compile(cfg, shape, mesh, rules, *, grad_accum, remat, unroll,
                   shard_logits: bool = False, zero1: bool = False,
                   shard_stream: bool = False, shard_qkv: bool = False):
    """Shared lower+compile for one configuration. Returns (compiled, extras)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distribution import sharding as shd
    from repro.launch import steps as steps_lib
    from repro.training.train_loop import _opt_pspecs
    from repro.training.optimizer import make_optimizer

    pshape = steps_lib.param_specs(cfg)
    ppspec = shd.evenly(shd.param_pspecs(pshape, rules), pshape, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), ppspec)
    n_params = sum(int(np.prod(leaf.shape))
                   for leaf in jax.tree.leaves(pshape))

    logits_sh = None
    if shard_logits:
        logits_sh = NamedSharding(
            mesh, P(rules.dp, None, rules.axis("vocab")))
    stream_sh = None
    if shard_stream:
        stream_sh = NamedSharding(mesh, P(rules.dp, rules.tp, None))
    qkv_sh = None
    if shard_qkv:
        qkv_sh = NamedSharding(mesh, P(rules.dp, None, rules.axis("heads"), None))
    fn, _ = steps_lib.build_step(cfg, shape.kind, grad_accum=grad_accum,
                                 remat=remat, unroll=unroll,
                                 logits_sharding=logits_sh,
                                 stream_sharding=stream_sh,
                                 qkv_sharding=qkv_sh)
    ispecs = steps_lib.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = make_optimizer("adamw", 3e-4, 100, 10_000)
        oshape = jax.eval_shape(opt.init, pshape)
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shd.evenly(_opt_pspecs(oshape, ppspec, mesh,
                                                  zero1=zero1,
                                                  dp_axes=rules.dp),
                                      oshape, mesh))
        bsh = {k: NamedSharding(mesh, P(rules.dp, *([None] * (len(v.shape) - 1))))
               for k, v in ispecs.items()}
        jfn = jax.jit(fn, in_shardings=(psh, osh, bsh),
                      out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        args = (pshape, oshape, ispecs)
    elif shape.kind == "prefill":
        bsh = {k: NamedSharding(mesh, P(rules.dp, *([None] * (len(v.shape) - 1))))
               for k, v in ispecs.items()}
        jfn = jax.jit(fn, in_shardings=(psh, bsh))
        args = (pshape, ispecs)
    else:  # decode
        cache_shape = ispecs["cache"]
        cpspec = shd.evenly(shd.serving_cache_pspecs(cfg, rules, cache_shape),
                            cache_shape, mesh)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cpspec)
        tsh = NamedSharding(mesh, P(rules.dp))
        jfn = jax.jit(fn, in_shardings=(psh, tsh, csh),
                      out_shardings=(None, csh), donate_argnums=(2,))
        args = (pshape, ispecs["token"], cache_shape)

    compiled = jfn.lower(*args).compile()
    return compiled, {"n_params": n_params, "pshape": pshape}


def _analysis_layers(cfg):
    """Two small depths for cost extrapolation (must be > 0 and distinct).

    VLM scans over super-blocks of ``cross_attn_every`` layers, so depths
    are multiples of that; everything else extrapolates per layer."""
    unit = cfg.cross_attn_every if cfg.family == "vlm" else 1
    return unit * 1, unit * 2, unit


def _analysis_cfg(cfg, n_layers, shape):
    """Loop-free variant for cost analysis: all sequential tilings unrolled
    (single MoE dispatch group, unchunked attention, single SSD chunk) so
    XLA's cost model — which counts a loop body ONCE — sees every op."""
    kw = dict(n_layers=n_layers, query_chunk=0, moe_group=0)
    if cfg.ssm_state:
        kw["ssm_chunk"] = shape.seq_len if shape.kind != "decode" else cfg.ssm_chunk
    return cfg.with_(**kw)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             block_style: Optional[str] = None, rules_kw: Optional[dict] = None,
             grad_accum: int = 1, remat: bool = True, analysis: bool = True,
             cfg_overrides: Optional[dict] = None, shard_logits: bool = False,
             zero1: bool = False, shard_stream: bool = False,
             shard_qkv: bool = False,
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the roofline-input record."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, SHAPES, shape_applicable
    from repro.distribution import sharding as shd
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.training.train_loop import _opt_pspecs
    from repro.training.optimizer import make_optimizer

    t0 = time.time()
    cfg = get_config(arch)
    if block_style:
        cfg = cfg.with_(block_style=block_style)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    cfg.validate_style()
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = shd.make_rules(mesh, batch=shape.global_batch, **(rules_kw or {}))

    # (1) the real (scanned) program: proves sharding coherence + memory fit
    compiled, extras = _lower_compile(cfg, shape, mesh, rules,
                                      grad_accum=grad_accum, remat=remat,
                                      unroll=False, shard_logits=shard_logits,
                                      zero1=zero1, shard_stream=shard_stream,
                                      shard_qkv=shard_qkv)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    hlo = compiled.as_text()
    coll_raw = collective_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    record = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": {"multi_pod": multi_pod, "shape": dict(mesh.shape),
                 "chips": chips},
        "block_style": cfg.block_style,
        "n_params": extras["n_params"],
        "flops_per_device_raw": float(cost.get("flops", -1.0)),
        "bytes_accessed_per_device_raw": float(cost.get("bytes accessed", -1.0)),
        "collectives_raw": coll_raw,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "uneven_shardings": shd.check_divisibility(extras["pshape"], mesh, rules),
        "skipped": False,
    }

    # (2) cost extrapolation: XLA's cost model counts a while-loop body ONCE,
    # so the scanned program under-reports per-layer flops/bytes/collectives
    # by the trip count. Lower two loop-free (fully unrolled, untiled)
    # variants at small depths L1 < L2 and extrapolate linearly:
    #   cost(L) = cost(L1) + (L - L1) * (cost(L2) - cost(L1)) / (L2 - L1)
    # Everything outside the layer stack (embedding, loss, optimizer over
    # stacked arrays) is linear or constant in L, so the model is exact.
    if analysis:
        L1, L2, unit = _analysis_layers(cfg)
        pts = []
        for L in (L1, L2):
            acfg = _analysis_cfg(cfg, L, shape)
            # grad_accum=1 here: per-step flops/bytes are invariant to
            # microbatching, but the accumulation lax.scan body would be
            # counted once by XLA's cost model (same loop artifact as the
            # layer scan) — the real program above keeps the true value
            # for memory_analysis.
            c, _ = _lower_compile(acfg, shape, mesh, rules,
                                  grad_accum=1, remat=remat,
                                  unroll=True, shard_logits=shard_logits,
                                  zero1=zero1, shard_stream=shard_stream,
                                  shard_qkv=shard_qkv)
            cost_l = cost_dict(c)
            coll_l = collective_bytes(c.as_text())
            pts.append({"flops": float(cost_l.get("flops", 0.0)),
                        "bytes": float(cost_l.get("bytes accessed", 0.0)),
                        "coll": float(coll_l["total_bytes"])})
        Lfull = cfg.n_layers

        def extrap(key):
            slope = (pts[1][key] - pts[0][key]) / (L2 - L1)
            return pts[0][key] + slope * (Lfull - L1)

        record["flops_per_device"] = extrap("flops")
        record["bytes_accessed_per_device"] = extrap("bytes")
        record["collectives"] = {
            "total_bytes": extrap("coll"),
            "per_op_bytes": coll_raw["per_op_bytes"],  # raw breakdown (body once)
            "counts": coll_raw["counts"],
        }
        record["analysis_points"] = {"L": [L1, L2], "pts": pts}
    else:
        record["flops_per_device"] = record["flops_per_device_raw"]
        record["bytes_accessed_per_device"] = record["bytes_accessed_per_device_raw"]
        record["collectives"] = coll_raw

    # analytic MODEL_FLOPS (6·N·D train / 2·N·D prefill / 2·N_active decode)
    record["model_flops_per_device"] = _model_flops(cfg, shape) / chips
    if record["flops_per_device"] > 0:
        record["model_flops_ratio"] = (record["model_flops_per_device"]
                                       / record["flops_per_device"])
    record["timings_s"] = {"compile": round(t_compile, 2),
                           "total": round(time.time() - t0, 2)}
    return record


def _model_flops(cfg, shape) -> float:
    """Analytic useful-work FLOPs for the whole step (all chips).

    Dense train: 6·N·D; prefill: 2·N·D; decode: 2·N_active per token.
    MoE uses active params; attention-free/ssm uses total params. The
    paper-style N excludes the unembedding read... we use matmul params
    (embedding excluded, unembedding included as a matmul)."""
    # matmul params ~= total - input embedding (gather, not matmul)
    from repro.core.analysis import weight_table
    wt = weight_table(cfg)
    n_matmul = wt["total"] - cfg.d_model * cfg.vocab_size  # minus input embed
    if cfg.n_experts:
        frac_active = (cfg.experts_per_token / cfg.n_experts)
        per_layer = wt["ffn_per_layer"]
        n_matmul -= cfg.n_layers * per_layer * (1 - frac_active)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_matmul * tokens


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e targets; see EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)


def roofline_terms(record: Dict[str, Any]) -> Dict[str, float]:
    """Three-term roofline from a dry-run record (per-device quantities)."""
    if record.get("skipped"):
        return {}
    compute_s = record["flops_per_device"] / PEAK_FLOPS
    memory_s = record["bytes_accessed_per_device"] / HBM_BW
    collective_s = record["collectives"]["total_bytes"] / LINK_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--block-style", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the unrolled cost-extrapolation lowerings")
    ap.add_argument("--out", default=None, help="artifact dir (json per cell)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED_ARCHS, SHAPES

    cells = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}|{shape}|{'multi' if mp else 'single'}"
        out_path = None
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            style = args.block_style or "default"
            out_path = os.path.join(
                args.out, f"{arch}__{shape}__{'multi' if mp else 'single'}"
                          f"__{style}.json")
            if args.skip_existing and os.path.exists(out_path):
                print(f"[skip existing] {tag}", flush=True)
                continue
        try:
            rec = run_cell(arch, shape, multi_pod=mp,
                           block_style=args.block_style,
                           grad_accum=args.grad_accum,
                           remat=not args.no_remat,
                           analysis=not args.no_analysis)
            rec["roofline"] = roofline_terms(rec)
            status = ("SKIP: " + rec["reason"]) if rec.get("skipped") else (
                f"ok compile={rec['timings_s']['compile']}s "
                f"total={rec['timings_s']['total']}s "
                f"dominant={rec['roofline'].get('dominant')} "
                f"mfr={rec.get('model_flops_ratio', 0):.2f}")
            print(f"[{tag}] {status}", flush=True)
        except Exception as e:
            failures += 1
            rec = {"arch": arch, "shape": shape, "skipped": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            print(f"[{tag}] FAIL {type(e).__name__}: {e}", flush=True)
        if out_path:
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
