"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --block-style skipless_merged

``--smoke`` uses the reduced config (CPU-friendly); omit it on a real
cluster to train the full architecture.  ``--mesh dxm`` lays the host's
devices out as a data×model mesh (e.g. ``--mesh 2x2`` under
XLA_FLAGS=--xla_force_host_platform_device_count=4).
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--block-style", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--corpus", default=None, help="path to a text file")
    ap.add_argument("--mesh", default=None, help="DxM host mesh, e.g. 2x2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduce_config
    from repro.training import DataConfig, Trainer, TrainerConfig
    from repro.launch.mesh import make_mesh

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    if args.block_style:
        cfg = cfg.with_(block_style=args.block_style)
        cfg.validate_style()

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    tc = TrainerConfig(steps=args.steps, log_every=args.log_every,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                       lr=args.lr, warmup=args.warmup,
                       grad_accum=args.grad_accum, optimizer=args.optimizer,
                       seed=args.seed)
    dc = DataConfig(global_batch=args.batch, seq_len=args.seq_len,
                    seed=args.seed)
    trainer = Trainer(cfg, tc, dc, mesh=mesh, corpus_path=args.corpus)
    print(f"training {cfg.name} [{cfg.block_style}] from step "
          f"{trainer.start_step} to {tc.steps}", flush=True)
    metrics = trainer.run()
    print("final:", metrics, flush=True)


if __name__ == "__main__":
    main()
