"""Step builders + ShapeDtypeStruct input specs for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable
ShapeDtypeStruct stand-ins for every model input — no device allocation —
and ``build_step(cfg, kind)`` returns the function the cell lowers:

  train    -> full train_step (fwd + bwd + AdamW update, donated)
  prefill  -> forward_prefill (logits + filled DecodeCache); encoder archs
              lower the plain encode forward (no cache exists)
  decode   -> forward_step (one token against the cache) == serve_step
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import (DensePrefillDest, forward_prefill, forward_seq,
                          forward_step, init_cache, init_params)
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import make_train_step


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter tree (no allocation)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def opt_specs(cfg: ModelConfig, optimizer):
    p = param_specs(cfg)
    return jax.eval_shape(optimizer.init, p)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model-input stand-ins for one (arch × shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "audio":
            specs = {"inputs": sds((B, S, cfg.d_model), jnp.float32),
                     "labels": sds((B, S), jnp.int32)}
        else:
            specs = {"inputs": sds((B, S), jnp.int32),
                     "labels": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision"] = sds((B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
        return specs
    if shape.kind == "prefill":
        if cfg.family == "audio":
            specs = {"inputs": sds((B, S, cfg.d_model), jnp.float32)}
        else:
            specs = {"inputs": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision"] = sds((B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
        return specs
    if shape.kind == "decode":
        return {"token": sds((B,), jnp.int32),
                "cache": cache_specs(cfg, B, S)}
    raise ValueError(shape.kind)


def build_step(cfg: ModelConfig, kind: str, *, grad_accum: int = 1,
               remat: bool = True, impl: str = "xla", unroll: bool = False,
               logits_sharding=None, stream_sharding=None, qkv_sharding=None):
    """Returns (fn, arg_names) for lowering."""
    if kind == "train":
        opt = make_optimizer("adamw", 3e-4, 100, 10_000)
        step = make_train_step(cfg, opt, grad_accum=grad_accum, remat=remat,
                               impl=impl, unroll=unroll,
                               logits_sharding=logits_sharding,
                               stream_sharding=stream_sharding,
                               qkv_sharding=qkv_sharding)
        return step, ("params", "opt_state", "batch")
    if kind == "prefill":
        if cfg.is_encoder:
            def encode(params, batch):
                logits, _, _ = forward_seq(params, cfg, batch["inputs"],
                                           impl=impl, unroll=unroll,
                                           qkv_sharding=qkv_sharding)
                return logits
            return encode, ("params", "batch")

        def prefill(params, batch):
            # dispatches through the models.backends PREFILL registry:
            # merged qp configs lower the stream-as-query fast path
            dest = DensePrefillDest(cache_len=batch["inputs"].shape[1])
            return forward_prefill(params, cfg, batch["inputs"], dest,
                                   vision=batch.get("vision"), impl=impl,
                                   unroll=unroll, qkv_sharding=qkv_sharding)
        return prefill, ("params", "batch")
    if kind == "decode":
        def serve_step(params, token, cache):
            # qkv_sharding re-anchors TP head sharding for merged
            # (Q/P-removed) styles, which have no wq matmul to anchor it
            return forward_step(params, cfg, token, cache, impl=impl,
                                unroll=unroll, qkv_sharding=qkv_sharding)
        return serve_step, ("params", "token", "cache")
    raise ValueError(kind)
