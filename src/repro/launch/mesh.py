"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before the first jax call, and smoke tests must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use tiny ones, e.g. (2, 2) on 4 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Mesh over however many (host) devices exist — used by mini dry-runs."""
    n = jax.device_count()
    assert n_data * n_model <= n, (n_data, n_model, n)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
