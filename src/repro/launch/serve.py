"""Serving launcher: batched generation with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --block-style skipless_merged --requests 8 --max-new 16

With ``--merged-from-skipless`` the launcher builds a skipless model, runs
the paper's QP-removal merge, and serves the merged weights — reporting the
weight/bandwidth savings next to the generated tokens.

``--cache paged`` serves through the block-pool KV cache adapter
(``serving.PagedCacheAdapter``: admission by pages instead of a worst-case
slot cap, direct-to-page prefill) — ``--slots`` then sizes the page pool in
dense-slot equivalents while every request gets its own batch row.
``--cache paged_q8`` is the same pool with int8 pages + per-(page,
kv-head) scales (the SAME dense-slot-equivalent budget buys ~4x the
pages, and the report adds the quantized-pool byte telemetry).

Per-request serving stats (prompt_len, time-to-first-token, decode tok/s)
come straight from ``Engine.generate``'s RequestResults.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--block-style", default=None)
    ap.add_argument("--merged-from-skipless", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--cache", default="dense",
                    choices=("dense", "paged", "paged_q8"))
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_config, reduce_config
    from repro.core import merge_skipless
    from repro.models import count_params, init_params
    from repro.serving import (Engine, PagedCacheAdapter,
                               PagedQ8CacheAdapter, ServeConfig)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    if args.merged_from_skipless:
        cfg = cfg.with_(block_style="skipless")
    elif args.block_style:
        cfg = cfg.with_(block_style=args.block_style)
    cfg.validate_style()

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    n0 = count_params(params)
    if args.merged_from_skipless:
        params, cfg = merge_skipless(params, cfg, "qp")
        n1 = count_params(params)
        print(f"QP removal: {n0:,d} -> {n1:,d} params "
              f"({100 * (n0 - n1) / n0:.1f}% removed)", flush=True)

    if args.cache in ("paged", "paged_q8"):
        sc = ServeConfig(n_slots=args.requests, max_len=args.max_len,
                         temperature=args.temperature, seed=args.seed)
        cls = PagedCacheAdapter if args.cache == "paged" \
            else PagedQ8CacheAdapter
        cache = cls(
            block_size=args.block_size,
            n_blocks=args.slots * args.max_len // args.block_size)
    else:
        sc = ServeConfig(n_slots=args.slots, max_len=args.max_len,
                         temperature=args.temperature, seed=args.seed)
        cache = "dense"
    eng = Engine(cfg, params, sc, cache=cache)
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(0, cfg.vocab_size, size=(args.prompt_len,))
               for _ in range(args.requests)]
    t0 = time.perf_counter()  # monotonic: NTP steps can't skew a duration
    outs = eng.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    ttfts = [o.ttft_s for o in outs]
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s); "
          f"TTFT mean {np.mean(ttfts):.3f}s / max {np.max(ttfts):.3f}s",
          flush=True)
    if args.cache in ("paged", "paged_q8"):
        a = eng.pm.allocator
        print(f"  paged pool: {a.n_blocks} pages, peak used {a.peak_used}, "
              f"peak streams {eng.stats['peak_active']}, "
              f"shared {a.n_shared_hits}, cow {a.n_cow}, "
              f"deferred {eng.stats['n_deferred']}, "
              f"preempted {eng.stats['n_preempted']}", flush=True)
        if args.cache == "paged_q8":
            print(f"  q8 pool: {eng.pm.pool_bytes / 1e6:.2f} MB resident "
                  f"(int8 pages + scales)", flush=True)
    for i, o in enumerate(outs[:4]):
        # decode_tok_s is None for single-token requests (no decode phase)
        rate = "n/a" if o.decode_tok_s is None else f"{o.decode_tok_s:.1f}"
        print(f"  req{i}: {list(o[:12])}{'…' if len(o) > 12 else ''} "
              f"(ttft {o.ttft_s:.3f}s, {rate} tok/s decode)")


if __name__ == "__main__":
    main()
