"""Export + validation helpers: metrics JSON, Perfetto traces, and the
``BENCH_serving_obs.json`` payload the perf trajectory accumulates.

Everything here is read-side: it runs AFTER (or between) serving steps,
so it may evaluate lazy gauges, walk the trace ring, and touch the
allocator freely — none of it is on the per-token path.

``validate_perfetto`` is the structural gate tests and
``tools/obsdump.py --selftest`` share: it proves the export is a
well-formed ``trace_event`` JSON object document (the format
https://ui.perfetto.dev loads) without needing Perfetto itself.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional


def snapshot(engine) -> Dict[str, Any]:
    """Point-in-time metrics snapshot of an engine (obs on or off — the
    always-on ``Engine.metrics`` registry is the source)."""
    doc: Dict[str, Any] = {
        "metrics": engine.metrics.collect(),
        "engine": {"cache_kind": engine.kv.kind,
                   "impl": engine.impl,
                   "n_slots": engine.sc.n_slots,
                   "max_len": engine.sc.max_len,
                   "merged_fast_path": engine.merged_fast_path,
                   "obs_enabled": engine.obs.enabled},
    }
    if engine.paged:
        a = engine.pm.allocator
        doc["pool"] = {"n_blocks": a.n_blocks, "block_size": engine.pm.bs,
                      "peak_used": a.peak_used, "n_used": a.n_used,
                      "n_cow": a.n_cow, "n_shared_hits": a.n_shared_hits,
                      "n_recycled": a.n_recycled,
                      "ring_bound": engine.pm.ring_bound,
                      # running max (O(1) host state), same exported shape
                      # as the old per-release list's max(...)
                      "request_page_hwm": engine.pm.request_page_hwm.max,
                      "prefix_tree_nodes": engine.pm.tree.n_nodes,
                      "prefix_retained_pages": len(engine.pm.tree.retained),
                      "prefix_hit_tokens": engine.pm.tree.hit_tokens,
                      "prefix_evicted": engine.pm.tree.n_evicted}
    return doc


def serving_obs_doc(engine, extra: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """The ``BENCH_serving_obs.json`` payload: the headline latency
    quantiles + pool/scheduler counters of one instrumented serve, flat
    enough to diff across PRs, plus the full metrics snapshot."""
    assert engine.obs.enabled, "serving_obs_doc needs an instrumented run"
    m = engine.metrics
    ttft = m["serve_ttft_seconds"]
    step = m["serve_decode_step_seconds"]
    tok = m["serve_decode_tok_s"]
    doc: Dict[str, Any] = {
        "schema": "repro.obs/serving/v1",
        "headline": {
            "ttft_p50_ms": _ms(ttft.percentile(0.50)),
            "ttft_p99_ms": _ms(ttft.percentile(0.99)),
            "decode_step_p50_ms": _ms(step.percentile(0.50)),
            "decode_step_p99_ms": _ms(step.percentile(0.99)),
            "decode_tok_s_p50": tok.percentile(0.50),
            "requests_finished": m["serve_requests_finished"].value,
            "tokens": m["serve_tokens"].value,
            "deferred": m["serve_deferred"].value,
            "preempted": m["serve_preempted"].value,
            "peak_active": m["serve_peak_active"].collect()["high_water"],
        },
        "decode_step_histogram": step.collect(),
        "ttft_histogram": ttft.collect(),
    }
    snap = snapshot(engine)
    doc["metrics"] = snap["metrics"]
    doc["engine"] = snap["engine"]
    if "pool" in snap:
        doc["pool"] = snap["pool"]
        doc["headline"].update(
            pool_peak_used=snap["pool"]["peak_used"],
            pool_recycled=snap["pool"]["n_recycled"],
            pool_cow=snap["pool"]["n_cow"],
            pool_prefix_hits=snap["pool"]["n_shared_hits"])
    if extra:
        doc.update(extra)
    return doc


def write_json(path: str, doc: Dict[str, Any]) -> None:
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def write_perfetto(path: str, tracer) -> None:
    write_json(path, tracer.to_perfetto())


# ---------------------------------------------------------------------------
# structural validation (tests + obsdump --selftest)
# ---------------------------------------------------------------------------

_REQUIRED = {"X": ("name", "ph", "pid", "tid", "ts", "dur"),
             "B": ("name", "ph", "pid", "tid", "ts"),
             "E": ("name", "ph", "pid", "tid", "ts"),
             "i": ("name", "ph", "pid", "tid", "ts"),
             "C": ("name", "ph", "pid", "tid", "ts", "args"),
             "M": ("name", "ph", "pid", "args")}


def validate_perfetto(doc: Dict[str, Any]) -> Dict[str, int]:
    """Assert ``doc`` is a loadable trace_event JSON object document;
    returns event counts by phase.  Checks: JSON round-trip, the
    ``traceEvents`` list, per-phase required keys, non-negative ts/dur,
    thread metadata for every (pid, tid) that records events, and B/E
    balance per track (unfinished B's are allowed — open spans — but an
    E without a B is corruption)."""
    json.loads(json.dumps(doc))  # JSON-serializable end to end
    evs = doc.get("traceEvents")
    assert isinstance(evs, list) and evs, "traceEvents must be a list"
    counts: Dict[str, int] = {}
    named_threads = set()
    used_threads = set()
    open_depth: Dict[Any, int] = {}
    for ev in evs:
        ph = ev.get("ph")
        assert ph in _REQUIRED, f"unknown phase {ph!r}: {ev}"
        for key in _REQUIRED[ph]:
            assert key in ev, f"{ph!r} event missing {key!r}: {ev}"
        counts[ph] = counts.get(ph, 0) + 1
        if ph == "M":
            if ev["name"] == "thread_name":
                named_threads.add((ev["pid"], ev["tid"]))
            continue
        used_threads.add((ev["pid"], ev["tid"]))
        assert ev["ts"] >= 0, f"negative ts: {ev}"
        if ph == "X":
            assert ev["dur"] >= 0, f"negative dur: {ev}"
        if ph in ("B", "E"):
            k = (ev["pid"], ev["tid"])
            open_depth[k] = open_depth.get(k, 0) + (1 if ph == "B" else -1)
            assert open_depth[k] >= 0, f"E without B on track {k}"
    missing = used_threads - named_threads
    assert not missing, f"events on unnamed threads: {sorted(missing)}"
    return counts


def request_events(tracer, rid: int) -> List[Dict[str, Any]]:
    """A request-track's ring events, oldest first (internal schema) —
    the invariant tests' view of one request's life."""
    from repro.obs import trace as tr
    track = tr.request_track(rid)
    return [ev for ev in tracer.events() if ev["track"] == track]


def _ms(seconds: Optional[float]) -> Optional[float]:
    return None if seconds is None else round(seconds * 1e3, 4)
