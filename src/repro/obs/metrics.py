"""Process-local serving metrics: counters, gauges, log-bucket histograms.

No dependencies, no locks, no background threads — a metric is a plain
python object the serving loop mutates with one attribute update, and the
registry is a dict of them.  That cost profile is the point: the engine's
always-on counters (``Engine.stats`` reads through this registry) must be
no more expensive than the ad-hoc dict they replaced, and everything
heavier (timestamps, span recording) lives behind the ``Observer``
on/off switch, not here.

Three metric kinds:

  ``Counter``    monotonic float/int total (``inc``).
  ``Gauge``      last-set value plus an all-time ``high_water`` mark
                 (``set`` / ``set_max``); a gauge may instead be LAZY —
                 registered with a zero-arg callable evaluated at
                 ``collect()`` time, which is how allocator/pool telemetry
                 (``BlockAllocator.n_recycled``, pool occupancy, …) is
                 lifted into the registry with ZERO hot-path cost.
  ``Histogram``  fixed log-spaced buckets over (lo, hi): bucket ``i``
                 spans ``lo·g^i .. lo·g^(i+1)`` with ``g`` chosen for
                 ``per_decade`` buckets per factor of 10.  Records count /
                 sum / exact min / exact max, estimates quantiles by
                 log-linear interpolation inside the owning bucket, and
                 EXCLUDES None/NaN observations into ``n_excluded``
                 instead of polluting the distribution with zeros (the
                 ``decode_tok_s`` single-token case).

Exports: ``collect()`` (plain JSON-able dict, the ``BENCH_serving_obs``
payload and ``tools/obsdump.py``'s input) and ``to_prometheus()`` (the
text exposition format, cumulative ``le`` buckets and all).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Union


class Counter:
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n

    def collect(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Settable point-in-time value + its all-time high-water mark.

    A gauge constructed with ``fn`` is LAZY: ``value``/``high_water`` are
    read from the callable at collect time and the serving loop never
    touches it — the lift path for host-side allocator telemetry."""

    __slots__ = ("name", "help", "_value", "high_water", "fn")

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], Union[int, float]]] = None):
        self.name, self.help, self.fn = name, help, fn
        self._value: Union[int, float] = 0
        self.high_water: Union[int, float] = 0

    @property
    def value(self) -> Union[int, float]:
        return self.fn() if self.fn is not None else self._value

    def set(self, v: Union[int, float]) -> None:
        self._value = v
        if v > self.high_water:
            self.high_water = v

    def set_max(self, v: Union[int, float]) -> None:
        """Ratchet: keep the max of all ``set_max`` calls (peak_active)."""
        if v > self._value:
            self._value = v
        if v > self.high_water:
            self.high_water = v

    def collect(self) -> Dict[str, Any]:
        v = self.value
        hw = max(self.high_water, v) if self.fn is None else v
        return {"type": "gauge", "value": v, "high_water": hw}


class Histogram:
    """Fixed log-spaced buckets; see module docstring.

    ``observe(None)`` / ``observe(nan)`` increments ``n_excluded`` and
    leaves every aggregate untouched — the caller's "no sample" marker
    never skews a mean or a percentile."""

    __slots__ = ("name", "help", "lo", "edges", "buckets", "underflow",
                 "count", "total", "vmin", "vmax", "n_excluded")

    def __init__(self, name: str, help: str = "", lo: float = 1e-5,
                 hi: float = 1e3, per_decade: int = 5):
        assert 0 < lo < hi and per_decade > 0
        self.name, self.help, self.lo = name, help, lo
        n = int(math.ceil(per_decade * math.log10(hi / lo)))
        # edges[i] is the UPPER bound of bucket i (log-spaced, edges[-1]>=hi)
        self.edges: List[float] = [lo * 10.0 ** ((i + 1) / per_decade)
                                   for i in range(n)]
        self.buckets = [0] * n
        self.underflow = 0  # observations <= lo (bucketed at the floor)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.n_excluded = 0

    def observe(self, x: Optional[float]) -> None:
        if x is None or (isinstance(x, float) and math.isnan(x)):
            self.n_excluded += 1
            return
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x
        if x <= self.lo:
            self.underflow += 1
            return
        i = int(math.log10(x / self.lo) * len(self.edges)
                / math.log10(self.edges[-1] / self.lo))
        i = min(max(i, 0), len(self.edges) - 1)
        # float rounding can land one bucket off the true edge pair
        while i > 0 and x <= self._lower(i):
            i -= 1
        while i < len(self.edges) - 1 and x > self.edges[i]:
            i += 1
        self.buckets[i] += 1

    def _lower(self, i: int) -> float:
        return self.lo if i == 0 else self.edges[i - 1]

    def percentile(self, q: float) -> Optional[float]:
        """Quantile estimate (q in [0,1]): log-linear interpolation inside
        the owning bucket, clamped to the exact observed min/max."""
        if self.count == 0:
            return None
        rank = q * self.count
        seen = self.underflow
        if rank <= seen:
            return self.vmin
        for i, n in enumerate(self.buckets):
            if n and rank <= seen + n:
                frac = (rank - seen) / n
                lo, hi = self._lower(i), self.edges[i]
                est = lo * (hi / lo) ** frac
                return min(max(est, self.vmin), self.vmax)
            seen += n
        return self.vmax

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def collect(self) -> Dict[str, Any]:
        return {"type": "histogram", "count": self.count, "sum": self.total,
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "mean": self.mean,
                "p50": self.percentile(0.50),
                "p90": self.percentile(0.90),
                "p99": self.percentile(0.99),
                "n_excluded": self.n_excluded,
                "buckets": {f"{e:.6g}": n for e, n in
                            zip(self.edges, self.buckets) if n},
                "underflow_le": {f"{self.lo:.6g}": self.underflow}}


class MetricsRegistry:
    """Name -> metric, get-or-create.  ``counter``/``gauge``/``histogram``
    return the live object (the caller caches it and mutates attributes —
    no per-event dict lookups on the serving path); ``gauge_fn`` registers
    a lazy gauge read only at collect time."""

    def __init__(self):
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get(self, kind, name, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, **kw)
            self._metrics[name] = m
        assert isinstance(m, kind), (name, type(m), kind)
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help=help)

    def gauge_fn(self, name: str, fn: Callable[[], Union[int, float]],
                 help: str = "") -> Gauge:
        g = self._get(Gauge, name, help=help)
        g.fn = fn
        return g

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(Histogram, name, help=help, **kw)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def collect(self) -> Dict[str, Dict[str, Any]]:
        """Point-in-time snapshot of every metric as a JSON-able dict
        (lazy gauges are evaluated here and only here)."""
        return {name: self._metrics[name].collect()
                for name in sorted(self._metrics)}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format: counters as ``_total``,
        histograms with CUMULATIVE ``le`` buckets + ``_sum``/``_count``."""
        out: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                out.append(f"# TYPE {name} counter")
                out.append(f"{name}_total {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                out.append(f"# TYPE {name} gauge")
                c = m.collect()
                out.append(f"{name} {_fmt(c['value'])}")
                out.append(f"{name}_high_water {_fmt(c['high_water'])}")
            else:
                out.append(f"# TYPE {name} histogram")
                cum = m.underflow
                out.append(f'{name}_bucket{{le="{m.lo:.6g}"}} {cum}')
                for e, n in zip(m.edges, m.buckets):
                    cum += n
                    out.append(f'{name}_bucket{{le="{e:.6g}"}} {cum}')
                out.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                out.append(f"{name}_sum {_fmt(m.total)}")
                out.append(f"{name}_count {m.count}")
        return "\n".join(out) + "\n"


def _fmt(v: Union[int, float]) -> str:
    return repr(int(v)) if isinstance(v, int) or float(v).is_integer() \
        else repr(float(v))
