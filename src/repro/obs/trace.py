"""Request-lifecycle tracing: a bounded ring of span events with
Chrome/Perfetto ``trace_event`` JSON export.

Recording model (host-timestamp-only — hooks never touch the device):

  * A ``Track`` is one Perfetto "thread": the engine loop, each serving
    SLOT, and each REQUEST get their own, so a request's
    queued→prefill→decode→preempted→decode→finish life reads as one
    horizontal lane even as it migrates between slots.
  * Completed spans are stored as single COMPLETE events (begin + dur in
    one record), appended to a bounded ring buffer (``collections.deque``
    maxlen) that drops OLDEST-first under pressure.  Spans still open
    (``begin`` without ``end``) live in a per-track side table OUTSIDE
    the ring, so buffer churn can never corrupt an open span — they are
    emitted as unfinished ``B`` events at export.
  * ``instant`` marks (preempt, deferred) and ``counter`` samples (pool
    occupancy per engine step) are ring events too.

Export is ``to_perfetto()``: the ``{"traceEvents": [...]}`` JSON object
format, loadable directly in https://ui.perfetto.dev (or
``chrome://tracing``), with ``M`` metadata records naming every
process/thread.  Timestamps are microseconds from the tracer's epoch,
taken from ``time.perf_counter`` (monotonic; wall-clock NTP steps can
never fold a span backwards).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

#: (process id, process name) per track family — Perfetto groups tids
#: under pids, so the engine / slots / requests render as three groups.
_FAMILIES = {"engine": (1, "engine"), "slot": (2, "slots"),
             "request": (3, "requests")}

Track = Tuple[str, int]  # ("engine"|"slot"|"request", index)


def engine_track() -> Track:
    return ("engine", 0)


def slot_track(slot: int) -> Track:
    return ("slot", int(slot))


def request_track(rid: int) -> Track:
    return ("request", int(rid))


class TraceBuffer:
    """Bounded ring of trace events + side table of open spans."""

    def __init__(self, capacity: int = 65536):
        assert capacity > 0
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._open: Dict[Track, List[Tuple[str, float, Dict[str, Any]]]] = {}
        self._tracks: Dict[Track, None] = {}  # insertion-ordered set
        self.n_dropped = 0
        self.epoch = time.perf_counter()

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        """Monotonic seconds (the tracer's native time base)."""
        return time.perf_counter()

    # -- recording ------------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self._events) == self.capacity:
            self.n_dropped += 1  # deque(maxlen) evicts oldest-first
        self._events.append(ev)

    def _touch(self, track: Track) -> None:
        self._tracks[track] = None

    def begin(self, track: Track, name: str, t: Optional[float] = None,
              **args) -> None:
        """Open span ``name`` on ``track`` (closed by ``end``).  Open
        spans are held OUTSIDE the ring: events dropped under pressure
        never unbalance them."""
        self._touch(track)
        self._open.setdefault(track, []).append(
            (name, self.now() if t is None else t, args))

    def end(self, track: Track, name: str, t: Optional[float] = None,
            **args) -> None:
        """Close the innermost open span ``name`` on ``track`` and emit
        the complete event.  Unknown (already-dropped or never-begun)
        names are a no-op — the hooks stay crash-free mid-serve."""
        t1 = self.now() if t is None else t
        stack = self._open.get(track, [])
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] == name:
                _, t0, a0 = stack.pop(i)
                if args:
                    a0 = {**a0, **args}
                self.complete(track, name, t0, t1, **a0)
                return

    def complete(self, track: Track, name: str, t0: float, t1: float,
                 **args) -> None:
        """One whole span (begin time + end time known at record time)."""
        self._touch(track)
        self._push({"ph": "X", "name": name, "track": track, "t0": t0,
                    "dur": max(0.0, t1 - t0), "args": args})

    def instant(self, track: Track, name: str, t: Optional[float] = None,
                **args) -> None:
        self._touch(track)
        self._push({"ph": "i", "name": name, "track": track,
                    "t0": self.now() if t is None else t, "args": args})

    def counter(self, track: Track, name: str, value,
                t: Optional[float] = None) -> None:
        """One sample of a numeric counter track (pool occupancy…)."""
        self._touch(track)
        self._push({"ph": "C", "name": name, "track": track,
                    "t0": self.now() if t is None else t,
                    "args": {"value": value}})

    # -- introspection (tests / invariant checks) -----------------------
    def events(self) -> List[Dict[str, Any]]:
        """The ring's events, oldest first (internal schema)."""
        return list(self._events)

    def open_spans(self, track: Optional[Track] = None
                   ) -> List[Tuple[Track, str]]:
        out = [(tr, name) for tr, stack in self._open.items()
               for name, _, _ in stack]
        return [x for x in out if x[0] == track] if track is not None else out

    def __len__(self) -> int:
        return len(self._events)

    # -- export ---------------------------------------------------------
    def _ids(self, track: Track) -> Tuple[int, int]:
        pid, _ = _FAMILIES[track[0]]
        return pid, int(track[1])

    def to_perfetto(self) -> Dict[str, Any]:
        """Chrome/Perfetto ``trace_event`` JSON object format."""
        # export base: the construction epoch, unless a caller recorded
        # explicit earlier timestamps (tests do) — ts must be >= 0
        t_all = [ev["t0"] for ev in self._events] + \
            [t0 for stack in self._open.values() for _, t0, _ in stack]
        base = min([self.epoch] + t_all)
        us = lambda t: round((t - base) * 1e6, 3)  # noqa: E731
        out: List[Dict[str, Any]] = []
        seen_pids = set()
        for track in self._tracks:
            pid, tid = self._ids(track)
            if pid not in seen_pids:
                seen_pids.add(pid)
                out.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0,
                            "args": {"name": _FAMILIES[track[0]][1]}})
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid,
                        "args": {"name": f"{track[0]} {track[1]}"}})
        for ev in self._events:
            pid, tid = self._ids(ev["track"])
            rec = {"name": ev["name"], "ph": ev["ph"], "cat": "serving",
                   "pid": pid, "tid": tid, "ts": us(ev["t0"])}
            if ev["ph"] == "X":
                rec["dur"] = round(ev["dur"] * 1e6, 3)
            if ev["ph"] == "i":
                rec["s"] = "t"  # thread-scoped instant
            if ev["args"]:
                rec["args"] = dict(ev["args"])
            out.append(rec)
        # open spans: emitted as unfinished B events (Perfetto renders
        # them to the end of the trace) — they were never in the ring
        for track, stack in self._open.items():
            pid, tid = self._ids(track)
            for name, t0, args in stack:
                rec = {"name": name, "ph": "B", "cat": "serving",
                       "pid": pid, "tid": tid, "ts": us(t0)}
                if args:
                    rec["args"] = dict(args)
                out.append(rec)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"n_dropped": self.n_dropped,
                              "capacity": self.capacity}}
