"""The Observer: the ONE seam the serving engine emits telemetry through.

``Engine`` holds exactly one observer.  With ``ServeConfig.obs`` falsy
(the default) it is the module singleton ``NULL`` — a ``NullObserver``
whose every hook is a shared empty function and whose ``clock()`` returns
0.0 without a syscall, so the off-mode serving path pays one attribute
load + one no-op call per seam and nothing else (the zero-overhead-off
guarantee ``benchmarks/bench_obs_overhead.py`` measures and asserts).
With obs on, the observer binds a :class:`MetricsRegistry` and a
:class:`TraceBuffer` and turns the engine's existing host timestamps into
histograms and spans.

Discipline (enforced, not aspirational):

  * HOST timestamps only — hooks receive values the engine already had
    (``req.t_arrival``/``t_first``/…) or read ``time.perf_counter``;
    they never call ``block_until_ready`` or read a device array.
  * NO instrumentation inside traced code.  Anything that runs under
    ``jax.make_jaxpr``/``jit`` must not consult the observer in a way
    that stages a callback into the program — the ``repro.lint`` rule
    ``NoHostTransferInObsHooks`` re-traces every registered backend
    combo's serving program under an ACTIVE observer (``activated()``)
    and fails if instrumentation added any host-transfer primitive.

Request lifecycle on the trace (one lane per request, one per slot):

  queued   t_arrival -> slot granted       (admission wait + deferrals)
  prefill  prompt forward + first sample   (span carries bucket/true len)
  decode   first token -> finish/preempt   (the steady-state span)
  preempted  preempt -> resume             (evicted, waiting to re-admit)
  finish   TERMINAL instant — exactly one per request, ever
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, Optional

from repro.obs import trace as tr
from repro.obs.metrics import MetricsRegistry


def _noop(*args, **kwargs) -> None:
    return None


class NullObserver:
    """Every hook a no-op; ``clock()`` skips even the perf_counter call.
    This IS the off mode — not a stripped build, the shipped default."""

    enabled = False

    # one shared do-nothing function object for every hook keeps the
    # off-mode cost at attribute-load + empty-call, uniformly
    request_admitted = _noop
    request_preempted = _noop
    request_finished = _noop
    step_done = _noop
    queue_depth = _noop
    compile_event = _noop
    attach_engine = _noop
    generate_done = _noop
    sched_iteration = _noop
    chunk_done = _noop

    def clock(self) -> float:
        return 0.0


NULL = NullObserver()


class Observer:
    """Live metrics + tracing; see module docstring for the span model."""

    enabled = True

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 trace_capacity: int = 65536):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = tr.TraceBuffer(trace_capacity)
        m = self.metrics
        self._c_submitted = m.counter(
            "serve_requests_admitted", "requests admitted into a slot")
        self._c_resumed = m.counter(
            "serve_requests_resumed", "preempted requests re-admitted")
        self._c_finished = m.counter(
            "serve_requests_finished", "requests that reached terminal")
        # serve_deferred / serve_preempted / serve_peak_active are the
        # ENGINE's always-on counters (Engine.stats reads through them);
        # they live in this same registry but the engine owns their
        # increments — the observer only adds spans/histograms on top
        m.counter("serve_deferred", "admissions deferred (pool exhausted)")
        m.counter("serve_preempted", "requests evicted mid-decode")
        self._c_steps = m.counter("serve_steps", "batched decode steps")
        self._c_tokens = m.counter("serve_tokens", "tokens emitted")
        self._g_active = m.gauge("serve_active", "slots decoding now")
        self._g_queue = m.gauge(
            "serve_queue_depth", "requests waiting (queued + preempted)")
        self._h_ttft = m.histogram(
            "serve_ttft_seconds", "arrival -> first token", lo=1e-5, hi=1e3)
        self._h_queued = m.histogram(
            "serve_queued_seconds", "arrival -> slot granted",
            lo=1e-6, hi=1e3)
        self._h_prefill = m.histogram(
            "serve_prefill_seconds", "prompt forward + first sample",
            lo=1e-5, hi=1e3)
        self._h_step = m.histogram(
            "serve_decode_step_seconds", "one batched decode step",
            lo=1e-5, hi=1e2)
        self._h_tok_s = m.histogram(
            "serve_decode_tok_s", "per-request steady decode rate "
            "(single-token requests excluded, not zero)", lo=1e-2, hi=1e6)
        self._h_compile = m.histogram(
            "compile_seconds", "jit lower+compile wall time", lo=1e-3,
            hi=1e4)
        self._c_compiles = m.counter("compile_events", "lower+compile calls")
        self._c_compile_bytes = m.counter(
            "compile_hlo_bytes", "compiled HLO text bytes, cumulative")
        # continuous-batching scheduler (repro.serving.sched)
        self._c_sched_iters = m.counter(
            "sched_iterations", "scheduler iterations planned")
        self._c_sched_chunks = m.counter(
            "sched_chunks", "prefill chunks executed")
        self._c_sched_chunk_tokens = m.counter(
            "sched_chunk_tokens", "prompt tokens prefilled via chunks")
        self._g_sched_budget = m.gauge(
            "sched_budget_used", "tokens charged in the last iteration")
        self._h_chunk = m.histogram(
            "sched_chunk_seconds", "one chunk-prefill dispatch",
            lo=1e-5, hi=1e2)
        self._engine = None

    # -- plumbing -------------------------------------------------------
    def clock(self) -> float:
        return time.perf_counter()

    def attach_engine(self, engine) -> None:
        """Register the LAZY gauges lifted from the engine and its cache
        adapter (pool occupancy, recycle/CoW/prefix-hit counters…) —
        evaluated only at ``collect()``, never on the serving path."""
        self._engine = engine
        m = self.metrics
        m.gauge_fn("serve_slots_free", lambda: len(engine.free_slots),
                   "slots idle")
        m.gauge_fn("serve_preempted_waiting",
                   lambda: len(engine.preempted),
                   "evicted requests awaiting resume")
        for name, (fn, help) in engine.kv.obs_gauges().items():
            m.gauge_fn(name, fn, help)

    # -- request lifecycle ----------------------------------------------
    def request_admitted(self, req, slot: int, *, n_shared: int,
                         resume: bool, bucket_len: int,
                         t_prefill0: float) -> None:
        """After a successful ``Engine.submit``: close the wait span
        (queued or preempted), record the prefill span, and for fresh
        requests the TTFT + the opening of the decode span."""
        now = self.clock()
        rtrack = tr.request_track(req.rid)
        if resume:
            self._c_resumed.inc()
            self.trace.end(rtrack, "preempted", t=t_prefill0, slot=slot)
        else:
            self._c_submitted.inc()
            self._h_queued.observe(t_prefill0 - req.t_arrival)
            self.trace.complete(rtrack, "queued", req.t_arrival, t_prefill0,
                                prompt_len=len(req.prompt))
        self.trace.complete(rtrack, "prefill", t_prefill0, now, slot=slot,
                            bucket_len=bucket_len, n_shared=n_shared)
        self.trace.complete(tr.slot_track(slot), "prefill", t_prefill0, now,
                            rid=req.rid, bucket_len=bucket_len)
        if not resume:
            ttft = req.t_first - req.t_arrival
            self._h_ttft.observe(ttft)
            self.trace.instant(rtrack, "first_token", t=req.t_first,
                               ttft_s=round(ttft, 6))
        self.trace.begin(rtrack, "decode", t=now, slot=slot)
        self._g_active.set(len(self._engine.active)
                           if self._engine is not None else 0)

    def request_preempted(self, req, slot: int) -> None:
        now = self.clock()
        rtrack = tr.request_track(req.rid)
        self.trace.end(rtrack, "decode", t=now, preempted=True)
        self.trace.instant(rtrack, "preempt", t=now, slot=slot)
        self.trace.begin(rtrack, "preempted", t=now)

    def request_finished(self, req, *, decode_tok_s: Optional[float],
                         ttft_s: float) -> None:
        """TERMINAL hook — exactly once per request.  ``decode_tok_s`` is
        None for single-token requests: excluded (``n_excluded``), never
        aggregated as a zero."""
        self._c_finished.inc()
        self._h_tok_s.observe(decode_tok_s)
        rtrack = tr.request_track(req.rid)
        t1 = req.t_last if req.t_last is not None else self.clock()
        self.trace.end(rtrack, "decode", t=t1)
        self.trace.instant(rtrack, "finish", t=t1,
                           new_tokens=len(req.out_tokens),
                           ttft_s=round(ttft_s, 6),
                           decode_tok_s=None if decode_tok_s is None
                           else round(decode_tok_s, 3))

    # -- engine loop ----------------------------------------------------
    def step_done(self, t0: float, t1: float, *, n_active: int,
                  n_tokens: int) -> None:
        self._c_steps.inc()
        self._c_tokens.inc(n_tokens)
        self._h_step.observe(t1 - t0)
        self._g_active.set(n_active)
        et = tr.engine_track()
        self.trace.complete(et, "step", t0, t1, n_active=n_active)
        eng = self._engine
        if eng is not None and eng.paged:
            self.trace.counter(et, "pool_blocks_used",
                               eng.pm.allocator.n_used, t=t1)

    def queue_depth(self, n: int) -> None:
        self._g_queue.set(n)

    # -- continuous-batching scheduler ----------------------------------
    def sched_iteration(self, t0: float, t1: float, *, n_decode: int,
                        n_chunks: int, n_chunk_tokens: int,
                        budget_used: int) -> None:
        """One ScheduledEngine iteration: the planned decode/prefill mix
        and its budget charge, as an engine-track span + counters."""
        self._c_sched_iters.inc()
        self._c_sched_chunks.inc(n_chunks)
        self._c_sched_chunk_tokens.inc(n_chunk_tokens)
        self._g_sched_budget.set(budget_used)
        et = tr.engine_track()
        self.trace.complete(et, "sched_iteration", t0, t1,
                            n_decode=n_decode, n_chunks=n_chunks,
                            budget_used=budget_used)
        self.trace.counter(et, "sched_budget_used", budget_used, t=t1)

    def chunk_done(self, req, slot: int, start: int, n_tokens: int,
                   t0: float, t1: float, *, final: bool) -> None:
        """One chunk-prefill dispatch for ``req``: a span on both the
        request's and the slot's track (the final chunk closes into the
        regular prefill/decode lifecycle via ``request_admitted``)."""
        self._h_chunk.observe(t1 - t0)
        self.trace.complete(tr.request_track(req.rid), "chunk", t0, t1,
                            slot=slot, start=start, n_tokens=n_tokens,
                            final=final)
        self.trace.complete(tr.slot_track(slot), "chunk", t0, t1,
                            rid=req.rid, start=start)

    def generate_done(self, t0: float, t1: float, *, n_requests: int,
                      n_tokens: int) -> None:
        self.trace.complete(tr.engine_track(), "generate", t0, t1,
                            n_requests=n_requests, n_tokens=n_tokens)

    # -- compile events -------------------------------------------------
    def compile_event(self, phase: str, bucket_len: Optional[int],
                      hlo_bytes: int, seconds: float) -> None:
        t1 = self.clock()
        self._c_compiles.inc()
        self._c_compile_bytes.inc(hlo_bytes)
        self._h_compile.observe(seconds)
        self.trace.complete(tr.engine_track(), f"compile:{phase}",
                            t1 - seconds, t1, bucket_len=bucket_len,
                            hlo_bytes=hlo_bytes)


# ---------------------------------------------------------------------------
# active observer: the global the SWEEP arms while re-tracing serving
# programs.  Traced code may consult it, but must never stage host
# callbacks off it — repro.lint's NoHostTransferInObsHooks diffs the
# programs traced with it active vs inactive.
# ---------------------------------------------------------------------------

_active: Any = NULL


def get_active():
    """The observer in effect for code being traced right now (``NULL``
    unless inside ``activated(...)``)."""
    return _active


@contextlib.contextmanager
def activated(observer):
    """Arm ``observer`` as the active observer for the duration."""
    global _active
    prev = _active
    _active = observer
    try:
        yield observer
    finally:
        _active = prev
