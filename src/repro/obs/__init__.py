"""``repro.obs`` — serving observability: metrics, tracing, export.

Three pillars (see ISSUE 7 / README "Observability"):

  1. :mod:`repro.obs.metrics` — process-local counters / gauges /
     log-bucket histograms, JSON + Prometheus text export, no deps.
  2. :mod:`repro.obs.trace` — request-lifecycle spans in a bounded ring
     buffer with Chrome/Perfetto ``trace_event`` JSON export.
  3. :mod:`repro.obs.observer` — the single seam the engine emits
     through.  Off by default (``ServeConfig.obs`` falsy → ``NULL``, a
     shared no-op stub), host-timestamp-only, and lint-enforced to add
     zero host-transfer primitives to traced programs
     (``NoHostTransferInObsHooks``).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (TraceBuffer, Track,  # noqa: F401
                             engine_track, request_track, slot_track)
from repro.obs.observer import (NULL, NullObserver, Observer,  # noqa: F401
                                activated, get_active)
from repro.obs.export import (request_events, serving_obs_doc,  # noqa: F401
                              snapshot, validate_perfetto, write_json,
                              write_perfetto)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TraceBuffer", "Track", "engine_track", "request_track", "slot_track",
    "NULL", "NullObserver", "Observer", "activated", "get_active",
    "request_events", "serving_obs_doc", "snapshot", "validate_perfetto",
    "write_json", "write_perfetto",
]
