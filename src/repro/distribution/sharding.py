"""Logical-axis sharding rules -> PartitionSpecs for every pytree we place.

Parallelism mapping (1000+ chip design):
  * DP  — batch over ("pod", "data") (multi-pod) or ("data",): gradients
    all-reduce hierarchically (XLA schedules intra-pod reduce-scatter +
    inter-pod all-reduce over the "pod" axis).
  * TP  — heads / kv-heads / d_ff / vocab / ssm-rows over "model";
    GSPMD inserts the block-level collectives (all-reduce or
    reduce-scatter+all-gather depending on downstream shardings).
  * EP  — MoE expert axis over "model" (expert parallelism); the dispatch
    einsums induce the all-to-all-style resharding.
  * batch-less shapes (long_500k, batch 1) drop the DP axis (replicate)
    rather than padding 1 -> |dp|.

Rules are path-based over the parameter tree: ``_PARAM_RULES`` matches the
TRAILING dims of each leaf by (module, param-name); leading stack axes
(layers / groups) are never sharded (they are scanned over).

Merged (Q/P-removed) parameter trees are covered by the same table: wq/wp
simply don't exist, K* / V* keep the column (head) sharding of the K/V
they were rewritten from, and the P fold leaves FFN/MoE input-matrix specs
unchanged (same shapes).  The merged-only leaves — ``input_proj`` (audio
front-end T_0), ``embed_bias`` / ``b_out`` (affine-merge biases) — get
explicit rows below.  NOTE: with wq gone the activation side loses its TP
head-sharding anchor; forward passes re-anchor via explicit
with_sharding_constraint (see models.transformer) using the same
``heads`` rule.

Uneven shardings (e.g. 40 heads over 16 chips) are permitted — GSPMD pads —
and flagged by ``check_divisibility`` so the roofline/perf pass can see the
padding waste explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axes (None = replicate)."""
    dp: Optional[Tuple[str, ...]] = ("data",)  # batch
    tp: Optional[str] = "model"  # heads/ffn/vocab/experts
    # overrides let the perf pass re-map specific logical dims:
    vocab: Optional[str] = "use_tp"
    heads: Optional[str] = "use_tp"
    ffn: Optional[str] = "use_tp"
    experts: Optional[str] = "use_tp"

    def axis(self, name: str):
        v = getattr(self, name)
        return self.tp if v == "use_tp" else v


def make_rules(mesh: Mesh, *, batch: int = 0, **kw) -> ShardingRules:
    """Default rules for a mesh; drops DP when the batch can't use it."""
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if batch and batch < dp_size:
        dp_axes = None  # replicate tiny batches (e.g. long_500k batch=1)
    return ShardingRules(dp=dp_axes, tp="model" if "model" in names else None, **kw)


# ---------------------------------------------------------------------------
# parameter specs (path-based)
# ---------------------------------------------------------------------------

def _param_spec(path: Tuple[str, ...], ndim: int, rules: ShardingRules) -> P:
    """Spec for one param; trailing-dim rules, leading stack dims -> None."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    tp = rules.axis
    table = {
        # (parent, name): trailing spec
        ("embed", "table"): (tp("vocab"), None),
        ("unembed", "table"): (tp("vocab"), None),
        ("attn", "wq"): (None, tp("heads")),
        ("attn", "wk"): (None, tp("heads")),
        ("attn", "wv"): (None, tp("heads")),
        ("attn", "wp"): (tp("heads"), None),
        ("attn", "bq"): (tp("heads"),),
        ("attn", "bk"): (tp("heads"),),
        ("attn", "bv"): (tp("heads"),),
        ("ffn", "w_gate"): (None, tp("ffn")),
        ("ffn", "w_up"): (None, tp("ffn")),
        ("ffn", "w_down"): (tp("ffn"), None),
        ("ffn", "w_in"): (None, tp("ffn")),
        ("ffn", "w_out"): (tp("ffn"), None),
        ("moe", "router"): (None, None),
        ("moe", "w_gate"): (tp("experts"), None, None),
        ("moe", "w_up"): (tp("experts"), None, None),
        ("moe", "w_down"): (tp("experts"), None, None),
        # merged-only leaves (Q/P-removed trees, core/merge.py)
        ("", "input_proj"): (None, tp("heads")),  # audio T_0: columns = q heads
        ("", "embed_bias"): (None,),  # stream-basis biases stay replicated
        ("", "b_out"): (None,),
        ("layers", "b_out"): (None,),
        ("ssm", "in_proj"): (tp("ffn"), None),  # row (d_model) sharded
        ("ssm", "out_proj"): (tp("ffn"), None),  # row (d_inner) sharded
        ("ssm", "conv_kernel"): (None, None),
        ("ssm", "conv_bias"): (None,),
        ("ssm", "A_log"): (None,),
        ("ssm", "D"): (None,),
        ("ssm", "dt_bias"): (None,),
    }
    trailing = table.get((parent, name))
    if trailing is None:
        # norms, biases, conv_pos, input_proj, b_out, … -> replicate
        trailing = tuple(None for _ in range(ndim))
    pad = ndim - len(trailing)
    return P(*((None,) * pad + tuple(trailing)))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def param_pspecs(params_shape, rules: ShardingRules):
    """PartitionSpec pytree matching a params (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_names(path), len(leaf.shape), rules),
        params_shape)


def param_shardings(params_shape, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_shape, rules))


# ---------------------------------------------------------------------------
# activation / input / cache specs
# ---------------------------------------------------------------------------

def batch_pspec(rules: ShardingRules, extra_dims: int = 1) -> P:
    return P(rules.dp, *([None] * extra_dims))


def input_pspecs(cfg: ModelConfig, kind: str, rules: ShardingRules) -> Dict[str, P]:
    """Specs for the input dict of train/prefill/decode steps."""
    dp = rules.dp
    if kind == "train":
        specs = {"inputs": P(dp, None), "labels": P(dp, None)}
        if cfg.family == "audio":
            specs["inputs"] = P(dp, None, None)
        if cfg.family == "vlm":
            specs["vision"] = P(dp, None, None)
        return specs
    if kind == "prefill":
        specs = {"inputs": P(dp, None)}
        if cfg.family == "audio":
            specs["inputs"] = P(dp, None, None)
        if cfg.family == "vlm":
            specs["vision"] = P(dp, None, None)
        return specs
    if kind == "decode":
        return {"token": P(dp)}
    raise ValueError(kind)


def cache_pspecs(cfg: ModelConfig, rules: ShardingRules):
    """PartitionSpecs for a DecodeCache (structure mirrors the NamedTuple).

    KV caches are SEQUENCE-sharded over the model axis (a flash-decoding
    style split-K: every chip scores its cache slice, XLA combines the
    softmax stats) because GQA kv-head counts (8, 5, 2, …) rarely divide a
    16-way TP axis while cache lengths always do.  SSM state shards over
    heads when divisible (``evenly`` downgrades it otherwise — e.g. hymba's
    50 heads stay replicated, they are tiny)."""
    from repro.models.transformer import DecodeCache
    from repro.models import mamba2 as m2

    dp, tp = rules.dp, rules.axis("heads")
    kv = P(None, dp, tp, None, None)
    return DecodeCache(
        k=kv, v=kv,
        kv_pos=P(dp, tp),
        length=P(dp),
        ssm=m2.SSMState(ssm=P(None, dp, tp, None, None),
                        conv=P(None, dp, None, None)),
        cross_k=P(None, dp, None, tp, None),  # vision tokens: head-sharded
        cross_v=P(None, dp, None, tp, None),
    )


def paged_cache_pspecs(cfg: ModelConfig, rules: ShardingRules):
    """PartitionSpecs for a PagedDecodeCache (serving block pool).

    The pool's PHYSICAL-BLOCK axis shards over the model axis — the paged
    analogue of the dense cache's sequence split (flash-decoding split-K:
    each chip scores the pages it owns and XLA combines softmax stats) —
    because kv-head counts (8, 5, 2, …) rarely divide a 16-way TP axis
    while pool sizes are free to.  Block tables and lengths are tiny
    per-slot int32 vectors: batch-sharded like the dense bookkeeping.
    """
    from repro.models.transformer import PagedDecodeCache

    dp, tp = rules.dp, rules.axis("heads")
    pool = P(None, tp, None, None, None)
    return PagedDecodeCache(
        k=pool, v=pool,
        block_tables=P(dp, None),
        length=P(dp),
    )


def paged_q8_cache_pspecs(cfg: ModelConfig, rules: ShardingRules):
    """PartitionSpecs for a PagedQ8DecodeCache: the int8 pools shard like
    the fp pools (physical-block axis over the model axis), and the
    (L, NB, Hkv) scale arrays shard their block axis IDENTICALLY so every
    page's scale row lives on the chip that owns the page."""
    from repro.models.transformer import PagedQ8DecodeCache

    dp, tp = rules.dp, rules.axis("heads")
    pool = P(None, tp, None, None, None)
    scale = P(None, tp, None)
    return PagedQ8DecodeCache(
        k=pool, v=pool, k_scale=scale, v_scale=scale,
        block_tables=P(dp, None),
        length=P(dp),
    )


def serving_cache_pspecs(cfg: ModelConfig, rules: ShardingRules, cache_like):
    """PartitionSpecs for whichever serving cache is in use, TRIMMED to the
    fields that actually exist.

    ``cache_like`` is the cache pytree (arrays or ShapeDtypeStructs) the
    engine will pass to the jitted step: a ``PagedDecodeCache`` maps to the
    block-pool specs; a ``DecodeCache`` maps to the dense specs with the
    spec entries for absent (None) fields dropped — pjit rejects specs for
    missing subtrees, and which fields exist depends on the family (ssm
    state, vlm cross-kv, …).  This is the single home for that trim logic
    (the engine used to re-derive it per call site).
    """
    from repro.models.transformer import (DecodeCache, PagedDecodeCache,
                                          PagedQ8DecodeCache)

    if isinstance(cache_like, PagedQ8DecodeCache):
        return paged_q8_cache_pspecs(cfg, rules)
    if isinstance(cache_like, PagedDecodeCache):
        return paged_cache_pspecs(cfg, rules)
    spec = cache_pspecs(cfg, rules)
    return DecodeCache(*[
        None if getattr(cache_like, f) is None else getattr(spec, f)
        for f in DecodeCache._fields])


def logits_pspec(rules: ShardingRules, seq_dim: bool = True) -> P:
    if seq_dim:
        return P(rules.dp, None, rules.axis("vocab"))
    return P(rules.dp, rules.axis("vocab"))


# ---------------------------------------------------------------------------
# mesh-aware downgrade: pjit argument shardings must divide evenly
# ---------------------------------------------------------------------------

def evenly(pspec_tree, shape_tree, mesh: Mesh):
    """Replace any spec axis whose dim doesn't divide the mesh axes with
    None (replicate).  pjit rejects uneven ARGUMENT shardings, so every
    explicitly-sharded input passes through this.  Downgrades are visible
    via ``check_divisibility`` (same predicate), never silent corruption."""
    def fix(spec, leaf):
        if spec is None or leaf is None:
            return spec
        dims = tuple(leaf.shape)
        parts = []
        for d, ax in enumerate(tuple(spec) + (None,) * (len(dims) - len(spec))):
            if ax is None:
                parts.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            parts.append(ax if dims[d] % size == 0 else None)
        return P(*parts)

    return jax.tree.map(fix, pspec_tree, shape_tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))


# ---------------------------------------------------------------------------
# divisibility audit (padding waste is visible, not silent)
# ---------------------------------------------------------------------------

def check_divisibility(params_shape, mesh: Mesh, rules: ShardingRules):
    """Returns a list of (path, dim, size, axis_size) uneven shardings."""
    uneven = []

    def visit(path, leaf):
        spec = _param_spec(_path_names(path), len(leaf.shape), rules)
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[d] % size:
                uneven.append(("/".join(_path_names(path)), d, leaf.shape[d], size))

    jax.tree_util.tree_map_with_path(visit, params_shape)
    return uneven
