from repro.distribution import sharding

__all__ = ["sharding"]
