"""Mistral-7B surgery walkthrough (paper §3 + §4 in one script).

Builds a skipless Mistral-7B-shaped model (reduced dims for CPU; pass
--full-width to use the real 4096-wide layers), audits the invertibility of
every Q (paper §4), merges per Fig 1(b), and prints the paper's table
arithmetic for the real model.

  PYTHONPATH=src python examples/mistral_surgery.py
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import condition_numbers, merge_skipless, weight_table
from repro.models import count_params, forward_seq, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-width", action="store_true",
                    help="real d_model=4096 layers (slow on CPU)")
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    base = get_config("mistral-7b")
    if args.full_width:
        cfg = base.with_(n_layers=args.layers, block_style="skipless",
                         dtype="float32", param_dtype="float32")
    else:
        cfg = reduce_config(base).with_(
            n_layers=args.layers, block_style="skipless",
            dtype="float32", param_dtype="float32")

    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0

    # §4 audit: every Q must be invertible
    conds = condition_numbers(params, cfg, "qp")
    print(f"invertibility audit over {len(conds)} layers: "
          f"cond(Q) median={np.median(conds):.0f} max={conds.max():.0f} "
          f"(all finite: {np.all(np.isfinite(conds))})")

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, _, _ = forward_seq(params, cfg, toks)
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    mlogits, _, _ = forward_seq(mparams, mcfg, toks)
    rel = float(np.max(np.abs(np.asarray(logits) - np.asarray(mlogits)))
                / np.max(np.abs(np.asarray(logits))))
    print(f"merge equivalence: rel max err = {rel:.2e}")
    print(f"params {count_params(params):,} -> {count_params(mparams):,}")
    assert rel < 3e-4

    # the real-model arithmetic (paper §3 table)
    t = weight_table(base)
    print(f"\nMistral-7B table (paper §3):")
    print(f"  Q+P / layer : {t['qp_per_layer']:>13,d}")
    print(f"  K+V / layer : {t['kv_per_layer']:>13,d}")
    print(f"  FFN / layer : {t['ffn_per_layer']:>13,d}")
    print(f"  embeddings  : {t['embed']:>13,d}")
    print(f"  total       : {t['total'] / 1e9:.1f}B -> "
          f"{t['total_without_qp'] / 1e9:.1f}B without Q+P "
          f"({100 * t['savings_frac']:.0f}% saved, {t['speedup']:.2f}x)")
    print("OK")


if __name__ == "__main__":
    main()
