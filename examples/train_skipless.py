"""End-to-end training driver: the paper's QP-free architectures.

Two acts, mirroring the paper:

1. Fig 4 (paper §5): train a Q/P-free block WITH norms and skips — the
   paper's proposed practical architecture. It trains like a standard
   transformer while carrying 2·d² fewer weights per layer.
2. Fig 1: train a fully skipless model briefly (the paper notes skipless
   nets are hard/slow to train — §5 — which reproduces here), then perform
   the exact QP-removal surgery on the TRAINED weights and verify the
   merged model serves byte-identical greedy continuations.

  PYTHONPATH=src python examples/train_skipless.py              # ~10M, fast
  PYTHONPATH=src python examples/train_skipless.py --full       # ~100M model
"""
import argparse
import shutil

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import merge_skipless
from repro.models import count_params
from repro.serving import Engine, ServeConfig
from repro.training import DataConfig, Trainer, TrainerConfig


def make_cfg(full: bool, style: str) -> ModelConfig:
    ffn = "gelu_mlp"  # skipless literature trains MLPs (GLU is scale-unstable)
    if full:  # ~100M params
        return ModelConfig(
            name=f"{style}-100m", family="dense", n_layers=10, d_model=640,
            n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=32000,
            ffn_type=ffn, block_style=style,
            ffn_out_gain=1.6 if style == "skipless" else 1.0,
            dtype="float32", param_dtype="float32")
    return ModelConfig(  # ~10M params, CPU-friendly
        name=f"{style}-10m", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=512,
        ffn_type=ffn, block_style=style,
        ffn_out_gain=1.6 if style == "skipless" else 1.0,
        dtype="float32", param_dtype="float32")


def train(cfg, steps, lr, batch, seq_len, ckpt_dir, weight_decay=0.1):
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    tc = TrainerConfig(steps=steps, log_every=max(steps // 5, 1),
                       ckpt_every=max(steps // 2, 1), ckpt_dir=ckpt_dir,
                       lr=lr, warmup=max(steps // 10, 5),
                       weight_decay=weight_decay)
    dc = DataConfig(global_batch=batch, seq_len=seq_len, seed=0)
    tr = Trainer(cfg, tc, dc)
    tr.run()
    return tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    # ---- Act 1: paper Fig 4 — QP-free with norms + skips ------------------
    cfg4 = make_cfg(args.full, "residual_qpfree")
    tr4 = train(cfg4, args.steps, 1e-3, args.batch, args.seq_len,
                "/tmp/repro_fig4")
    l4 = [m["loss"] for m in tr4.metrics_log]
    print(f"\nFig-4 QP-free ({count_params(tr4.params):,} params): "
          f"loss {l4[0]:.3f} -> {l4[-1]:.3f}")
    assert l4[-1] < l4[0] - 0.3, "Fig-4 variant must train"

    # ---- Act 2: Fig 1 skipless + exact surgery ----------------------------
    cfg1 = make_cfg(args.full, "skipless")
    tr1 = train(cfg1, max(args.steps // 5, 30), 3e-4, args.batch,
                args.seq_len, "/tmp/repro_fig1", weight_decay=0.0)
    l1 = [m["loss"] for m in tr1.metrics_log]
    print(f"skipless ({count_params(tr1.params):,} params): "
          f"loss {l1[0]:.3f} -> {l1[-1]:.3f} "
          f"(slow/fragile training — exactly the paper's §5 caveat)")

    params = jax.device_get(tr1.params)
    mparams, mcfg = merge_skipless(params, cfg1, "qp")
    n0, n1 = count_params(params), count_params(mparams)
    print(f"QP surgery on the trained weights: {n0:,} -> {n1:,} "
          f"(-{100 * (n0 - n1) / n0:.1f}%)")

    prompts = [np.arange(8) % cfg1.vocab_size,
               (np.arange(8) * 3) % cfg1.vocab_size]
    out_a = Engine(cfg1, params, ServeConfig(n_slots=2, max_len=64)).generate(
        prompts, max_new_tokens=12)
    out_b = Engine(mcfg, mparams, ServeConfig(n_slots=2, max_len=64)).generate(
        prompts, max_new_tokens=12)
    assert out_a == out_b, "merged model must generate identical tokens"
    print(f"greedy continuations identical after surgery: {out_a[0][:8]}…")
    print("OK")


if __name__ == "__main__":
    main()
