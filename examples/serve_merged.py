"""Serving demo: continuous batching with a QP-removed model, dense or
paged KV cache.

Removing Q/P (paper Fig 1b) cuts the per-token WEIGHT stream; what turns
that into throughput is batching enough concurrent requests over the
remaining K*/V* reads.  The dense cache caps concurrency at
``HBM / (L · max_len · Hkv · Dh)`` worst-case slots; ``--cache paged``
spends the same bytes on a block pool (vLLM-style: free-list allocator,
per-request block tables, prefix sharing with copy-on-write), so a
mixed-length request mix runs many more streams per HBM byte — watch
``peak streams`` between the two runs.  (On the CPU container the
absolute tok/s is illustrative; the bandwidth accounting is the
TPU-relevant part.)

  PYTHONPATH=src python examples/serve_merged.py [--arch llama3.2-1b]
                                                 [--cache dense|paged]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import decode_ms_per_token, merge_skipless, weight_table
from repro.models import count_params, init_params
from repro.serving import Engine, PagedCacheAdapter, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--cache", default="dense", choices=("dense", "paged"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch)).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    n0, n1 = count_params(params), count_params(mparams)
    print(f"serving {cfg.name} with QP removed: {n0:,} -> {n1:,} params "
          f"({args.cache} cache)")

    if args.cache == "paged":
        # slots are just batch rows; the POOL (sized like `--slots` dense
        # slots) is what admission control spends — prefill writes prompt
        # KV direct-to-page (no worst-case intermediate buffer)
        sc = ServeConfig(n_slots=args.requests, max_len=128)
        cache = PagedCacheAdapter(block_size=16,
                                  n_blocks=args.slots * 128 // 16)
    else:
        sc = ServeConfig(n_slots=args.slots, max_len=128)
        cache = "dense"
    eng = Engine(mcfg, mparams, sc, cache=cache)
    print(f"  merged fast path: decode={eng.merged_fast_path} "
          f"prefill={eng.merged_prefill_fast_path} (Q/P weights never "
          f"read in either serving phase)")
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(rng.randint(6, 24),))
               for _ in range(args.requests)]
    t0 = time.perf_counter()  # monotonic: NTP steps can't skew a duration
    outs = eng.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    ttfts = [o.ttft_s for o in outs]
    print(f"{args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s on CPU), "
          f"peak streams {eng.stats['peak_active']}, "
          f"TTFT mean {np.mean(ttfts):.3f}s")
    if args.cache == "paged":
        a = eng.pm.allocator
        print(f"  pool: {a.n_blocks} pages, peak used {a.peak_used}, "
              f"prefix-shared {a.n_shared_hits}, copy-on-write {a.n_cow}, "
              f"deferred {eng.stats['n_deferred']}, "
              f"preempted {eng.stats['n_preempted']}")

    # the TPU-relevant accounting (paper §3 model, full-size arch):
    full = get_config(args.arch)
    t = weight_table(full)
    ms_w = decode_ms_per_token(t["total"])
    ms_wo = decode_ms_per_token(t["total_without_qp"])
    print(f"\n{full.name} @ v5e batch-1 decode (weights streaming, bf16):")
    print(f"  with Q+P   : {ms_w:.2f} ms/token")
    print(f"  without Q+P: {ms_wo:.2f} ms/token   -> {ms_w / ms_wo:.2f}x")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o}")
    print("OK")


if __name__ == "__main__":
    main()
