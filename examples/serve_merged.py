"""Serving demo: continuous batching with a QP-removed model.

Eight batched requests served through the slot engine, with the paper's
weight savings reported next to the measured tokens/s.  (On the CPU
container the absolute tok/s is illustrative; the bandwidth accounting is
the TPU-relevant part.)

  PYTHONPATH=src python examples/serve_merged.py [--arch llama3.2-1b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import decode_ms_per_token, merge_skipless, weight_table
from repro.models import count_params, init_params
from repro.serving import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch)).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    n0, n1 = count_params(params), count_params(mparams)
    print(f"serving {cfg.name} with QP removed: {n0:,} -> {n1:,} params")

    eng = Engine(mcfg, mparams, ServeConfig(n_slots=args.slots, max_len=128))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(12,))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"{args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s on CPU)")

    # the TPU-relevant accounting (paper §3 model, full-size arch):
    full = get_config(args.arch)
    t = weight_table(full)
    ms_w = decode_ms_per_token(t["total"])
    ms_wo = decode_ms_per_token(t["total_without_qp"])
    print(f"\n{full.name} @ v5e batch-1 decode (weights streaming, bf16):")
    print(f"  with Q+P   : {ms_w:.2f} ms/token")
    print(f"  without Q+P: {ms_wo:.2f} ms/token   -> {ms_w / ms_wo:.2f}x")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o}")
    print("OK")


if __name__ == "__main__":
    main()
