"""Quickstart: the paper's trick in 60 seconds.

Build a small skipless GQA transformer (Fig 1a), remove its Q and P weights
exactly (Fig 1b / Table 1), and verify the two models are the same function.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import merge_skipless, weight_table
from repro.models import count_params, forward_seq, init_params

# a Mistral-style GQA decoder, skipless (no residuals / no norms)
cfg = ModelConfig(
    name="quickstart", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, d_ff=688,
    vocab_size=1024, ffn_type="swiglu",
    block_style="skipless", dtype="float32", param_dtype="float32")

params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
logits, _, _ = forward_seq(params, cfg, tokens)

# --- the paper's merge: Q and P vanish, K*/V*/M*/O* absorb them -----------
merged_params, merged_cfg = merge_skipless(params, cfg, variant="qp")
merged_logits, _, _ = forward_seq(merged_params, merged_cfg, tokens)

n0, n1 = count_params(params), count_params(merged_params)
err = float(np.max(np.abs(np.asarray(logits) - np.asarray(merged_logits))))
rel = err / float(np.max(np.abs(np.asarray(logits))))

print(f"params:        {n0:,} -> {n1:,}  "
      f"(-{n0 - n1:,} = -{100 * (n0 - n1) / n0:.1f}%)")
print(f"removed/layer: 2·d² = {2 * cfg.d_model ** 2:,} (Q and P)")
print(f"max |Δlogit|:  {err:.2e}  (relative {rel:.2e})")
print("note: the merge itself is exact (float64); the residual above is the")
print("      fp32 RUNTIME cost of evaluating (u·Q)·(Q⁻¹K) vs u·K — it scales")
print("      with cond(Q)·eps per layer (see EXPERIMENTS.md §Numerics)")

# --- what this means for Mistral-7B (paper §3) ----------------------------
from repro.configs import get_config
t = weight_table(get_config("mistral-7b"))
print(f"\nMistral-7B:    {t['total'] / 1e9:.1f}B -> "
      f"{t['total_without_qp'] / 1e9:.1f}B weights "
      f"({100 * t['savings_frac']:.0f}% saved) -> "
      f"{t['speedup']:.2f}x batch-1 decode speedup (memory-bound)")
assert rel < 5e-2  # fp32 runtime; drops to ~1e-13 under float64 evaluation
print("\nOK")
