"""End-to-end system behaviour: the paper's full workflow.

Train a skipless model -> merge (QP removal) -> verify the merged model is
the same function -> serve it with continuous batching -> outputs identical
to serving the unmerged model. This is the paper's value proposition
exercised through every layer of the framework.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import decode_speedup, merge_skipless, weight_table
from repro.models import count_params, init_params
from repro.serving import Engine, ServeConfig
from repro.training import DataConfig, Trainer, TrainerConfig


def test_train_merge_serve_roundtrip(tmp_path):
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    dc = DataConfig(global_batch=8, seq_len=24, seed=1)
    tc = TrainerConfig(steps=25, log_every=25, ckpt_every=25,
                       ckpt_dir=str(tmp_path / "ck"), lr=1e-3, warmup=3)
    trainer = Trainer(cfg, tc, dc)
    trainer.run()
    params = jax.device_get(trainer.params)

    # --- merge the TRAINED weights (the paper's deployment story) ---------
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    n0, n1 = count_params(params), count_params(mparams)
    assert n1 < n0
    # per-layer savings = 2·d²  (Q and P)
    assert n0 - n1 == cfg.n_layers * 2 * cfg.d_model * cfg.d_model

    # --- serve both; greedy outputs must be identical ---------------------
    prompts = [np.arange(6) % cfg.vocab_size, (np.arange(6) + 3) % cfg.vocab_size]
    out_a = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48)).generate(
        prompts, max_new_tokens=8)
    out_b = Engine(mcfg, mparams, ServeConfig(n_slots=2, max_len=48)).generate(
        prompts, max_new_tokens=8)
    assert out_a == out_b, "QP-removed serving diverged from the original"


def test_weight_tables_all_archs():
    """weight_table runs for every assigned arch and is self-consistent."""
    from repro.configs import ASSIGNED_ARCHS
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        t = weight_table(cfg)
        assert t["total"] > 0
        assert 0 <= t["removed"] < t["total"]
        assert abs((t["total"] - t["removed"]) - t["total_without_qp"]) == 0
        if cfg.qp_removal_applicable and cfg.family != "hybrid":
            assert t["speedup"] > 1.0, arch
        if not cfg.has_attention:
            assert t["removed"] == 0 and t["speedup"] == 1.0


def test_moe_active_weight_speedup_extension():
    """Beyond-paper: MoE decode reads active experts only — speedup of the
    attention-side removal is larger relative to active bytes."""
    from repro.core import active_weights_per_token
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    total_model = weight_table(cfg)["speedup"]
    active_model = decode_speedup(cfg, active_only=True)
    assert active_model > total_model > 1.0
    assert active_weights_per_token(cfg) < weight_table(cfg)["total"]
