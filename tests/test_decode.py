"""Incremental decode == full-sequence forward, for every causal arch,
including ring-buffer sliding-window and SSM state handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import (DensePrefillDest, forward_decode, forward_prefill,
                          forward_seq, init_params)

CAUSAL = [a for a in ASSIGNED_ARCHS if get_config(a).causal]


@pytest.mark.parametrize("arch", CAUSAL)
def test_decode_matches_full_forward(arch):
    cfg = reduce_config(get_config(arch))
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))  # dropless both paths
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S_pre, S_gen = 2, 8, 6
    S = S_pre + S_gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    vision = None
    if cfg.family == "vlm":
        vision = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.n_vision_tokens, cfg.d_model))
    full, _, _ = forward_seq(params, cfg, toks, vision=vision)
    last, cache = forward_prefill(params, cfg, toks[:, :S_pre],
                                  DensePrefillDest(S + 2), vision=vision)
    step = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    errs = [np.max(np.abs(np.asarray(last) - np.asarray(full[:, S_pre - 1])))]
    for t in range(S_pre, S):
        lg, cache = step(params, toks[:, t], cache)
        errs.append(np.max(np.abs(np.asarray(lg) - np.asarray(full[:, t]))))
    assert max(errs) < 2e-3, (arch, errs)


def test_sliding_window_ring_buffer_wraps():
    """Decode far past the window: ring buffer must stay exact."""
    cfg = reduce_config(get_config("hymba-1.5b")).with_(sliding_window=6)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20  # window 6 -> wraps 3x
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _, _ = forward_seq(params, cfg, toks)
    _, cache = forward_prefill(params, cfg, toks[:, :4], DensePrefillDest(32))
    assert cache.k.shape[2] == 6  # ring buffer is window-sized
    step = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    for t in range(4, S):
        lg, cache = step(params, toks[:, t], cache)
        err = np.max(np.abs(np.asarray(lg) - np.asarray(full[:, t])))
        assert err < 2e-3, (t, err)


@pytest.mark.parametrize("n_kv", [4, 2])  # MHA and GQA (4 q heads)
def test_merged_fastpath_greedy_token_equivalence(n_kv):
    """A merge_skipless model decoding through the merged fast path emits
    token-for-token the same greedy stream (logits within tolerance) as
    the unmerged skipless model through the generic path — for both the
    XLA route and the merged Pallas kernel (interpret mode)."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=n_kv)
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    B, S_pre, n_new = 2, 6, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre), 0,
                              cfg.vocab_size)
    lg0, c0 = forward_prefill(params, cfg, toks, DensePrefillDest(32))
    lg1, c1 = forward_prefill(mparams, mcfg, toks, DensePrefillDest(32))
    ck = c1  # separate cache for the pallas-kernel route
    step0 = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    step1 = jax.jit(lambda p, t, c: forward_decode(p, mcfg, t, c))
    stepk = jax.jit(lambda p, t, c: forward_decode(p, mcfg, t, c,
                                                   impl="pallas_interpret"))

    def greedy(lg):
        return np.asarray(jnp.argmax(lg[:, :cfg.vocab_size], axis=-1))

    t0, t1 = greedy(lg0), greedy(lg1)
    np.testing.assert_array_equal(t0, t1)
    for _ in range(n_new):
        a, c0 = step0(params, jnp.asarray(t0), c0)
        b, c1 = step1(mparams, jnp.asarray(t1), c1)
        bk, ck = stepk(mparams, jnp.asarray(t1), ck)
        denom = np.max(np.abs(np.asarray(a))) + 1e-9
        assert np.max(np.abs(np.asarray(a) - np.asarray(b))) / denom < 3e-4
        assert np.max(np.abs(np.asarray(b) - np.asarray(bk))) / denom < 1e-5
        t0, t1, tk = greedy(a), greedy(b), greedy(bk)
        np.testing.assert_array_equal(t0, t1)  # token-for-token identical
        np.testing.assert_array_equal(t1, tk)


def test_decode_merged_equals_decode_vanilla():
    """QP-removed serving path == vanilla skipless serving path."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    B, S_pre = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre + 4), 0,
                              cfg.vocab_size)
    _, c0 = forward_prefill(params, cfg, toks[:, :S_pre], DensePrefillDest(16))
    _, c1 = forward_prefill(mparams, mcfg, toks[:, :S_pre], DensePrefillDest(16))
    step0 = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    step1 = jax.jit(lambda p, t, c: forward_decode(p, mcfg, t, c))
    for t in range(S_pre, S_pre + 4):
        a, c0 = step0(params, toks[:, t], c0)
        b, c1 = step1(mparams, toks[:, t], c1)
        denom = np.max(np.abs(np.asarray(a))) + 1e-9
        assert np.max(np.abs(np.asarray(a) - np.asarray(b))) / denom < 3e-4
