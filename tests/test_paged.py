"""Paged KV-cache subsystem: kernels vs oracles, allocator/prefix-sharing
semantics, and the paged engine's greedy token-equality with the dense
engine and the full-sequence oracle (mixed-length MHA+GQA workloads,
xla and pallas routes, prefix sharing with copy-on-write, admission
control and preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.kernels import ops, ref
from repro.models import forward_seq, init_params
from repro.serving import Engine, ServeConfig
from repro.serving.paged_kv_cache import BlockAllocator, PagedCacheManager


# ---------------------------------------------------------------------------
# kernels vs oracles
# ---------------------------------------------------------------------------

def _rand_tables(rng, B, MB, NB, bs, lens):
    """Distinct physical pages per slot covering each slot's current
    position (qpos = lens[b]), rest unmapped."""
    bt = np.full((B, MB), -1, np.int32)
    perm = rng.permutation(NB)
    ptr = 0
    for b, L in enumerate(lens):
        for j in range((L + bs) // bs):  # covers position L inclusive
            bt[b, j] = perm[ptr]
            ptr += 1
    return jnp.asarray(bt)


@pytest.mark.parametrize("dtype,B,Hq,Hkv,D,NB,bs,MB,win", [
    (jnp.float32, 3, 4, 2, 64, 16, 16, 4, 0),
    (jnp.bfloat16, 3, 4, 2, 64, 16, 16, 4, 0),
    (jnp.float32, 2, 8, 1, 32, 12, 8, 4, 0),   # MQA
    (jnp.float32, 2, 4, 4, 16, 14, 8, 6, 11),  # MHA + sliding window
])
def test_paged_decode_kernel_matches_ref(dtype, B, Hq, Hkv, D, NB, bs, MB, win):
    rng = np.random.RandomState(0)
    G = Hq // Hkv
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (NB, bs, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (NB, bs, Hkv, D), dtype)
    lens = [int(x) for x in rng.randint(1, MB * bs - 1, size=B)]
    bt = _rand_tables(rng, B, MB, NB, bs, lens)
    qpos = jnp.asarray(lens, jnp.int32)
    out = ops.decode_attention_paged(q, kp, vp, block_tables=bt,
                                     q_position=qpos, sliding_window=win,
                                     interpret=True)
    want = ref.ref_decode_attention_paged(
        q.reshape(B, Hkv, G, D), kp, vp, bt, qpos,
        sliding_window=win).reshape(B, Hq, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype,B,Hq,Hkv,D,NB,bs,MB,win", [
    (jnp.float32, 3, 4, 2, 64, 16, 16, 4, 0),
    (jnp.bfloat16, 2, 4, 1, 32, 12, 8, 4, 0),  # MQA
    (jnp.float32, 2, 4, 4, 16, 14, 8, 6, 11),  # MHA + sliding window
])
def test_paged_merged_kernel_matches_ref(dtype, B, Hq, Hkv, D, NB, bs, MB, win):
    rng = np.random.RandomState(1)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    u = jax.random.normal(ks[0], (B, Hq * D), dtype)
    kp = jax.random.normal(ks[1], (NB, bs, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (NB, bs, Hkv, D), dtype)
    lens = [int(x) for x in rng.randint(1, MB * bs - 1, size=B)]
    bt = _rand_tables(rng, B, MB, NB, bs, lens)
    qpos = jnp.asarray(lens, jnp.int32)
    out = ops.decode_attention_paged_merged(
        u, kp, vp, block_tables=bt, q_position=qpos, n_kv_heads=Hkv,
        sliding_window=win, interpret=True)
    want = ref.ref_decode_attention_paged_merged(
        u, kp, vp, bt, qpos, n_kv_heads=Hkv, sliding_window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_paged_ref_matches_dense_ref():
    """The paged oracle itself is just a gather in front of the dense
    oracle: densify manually and cross-check."""
    rng = np.random.RandomState(2)
    B, Hq, Hkv, D, NB, bs, MB = 2, 4, 2, 16, 10, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hkv, Hq // Hkv, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, Hkv, D), jnp.float32)
    lens = [7, 13]
    bt = _rand_tables(rng, B, MB, NB, bs, lens)
    qpos = jnp.asarray(lens, jnp.int32)
    got = ref.ref_decode_attention_paged(q, kp, vp, bt, qpos)
    k = ref.ref_paged_gather(kp, bt).transpose(0, 2, 1, 3)
    v = ref.ref_paged_gather(vp, bt).transpose(0, 2, 1, 3)
    kv_pos = ref.ref_paged_positions(bt, bs)
    want = ref.ref_decode_attention(q, k, v, kv_pos, qpos[:, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# allocator / manager semantics
# ---------------------------------------------------------------------------

def test_block_allocator_refcounts_and_exhaustion():
    a = BlockAllocator(4)
    ids = a.alloc(3)
    assert ids is not None and a.n_free == 1
    assert a.alloc(2) is None, "over-allocation must fail, not wrap"
    a.fork(ids[:2])  # share two pages
    assert a.release(ids) == [ids[2]]  # shared pages stay resident
    assert a.n_free == 2
    assert sorted(a.release(ids[:2])) == sorted(ids[:2])
    assert a.n_free == 4


def test_manager_prefix_sharing_and_release():
    cfg = reduce_config(get_config("llama3.2-1b"))
    m = PagedCacheManager(cfg, n_slots=3, max_len=32, block_size=8,
                          n_blocks=8)
    toks = np.arange(20) % cfg.vocab_size  # 2 full pages + 1 partial
    assert m.admit(0, toks) == 0  # nothing to share yet
    assert m.allocator.n_used == 3
    assert m.admit(1, toks) == 3  # full chain + exact-prompt partial
    assert m.allocator.n_used == 3, "identical prompt must map 0 new pages"
    # both slots append -> each copy-on-writes the shared partial page
    assert m.ensure_appendable(0) and m.ensure_appendable(1)
    assert m.allocator.n_cow >= 1
    assert m.tables[0, 2] != m.tables[1, 2], "partial page must diverge"
    assert (m.tables[0, :2] == m.tables[1, :2]).all(), "full pages stay shared"
    m.release(0)
    m.release(1)
    # prefix retention: the registered chain (2 full pages + the prompt
    # tail) survives its last sharer, held by the radix tree alone
    assert m.allocator.n_used == 3 == len(m.tree.retained)
    assert m.admit(2, toks) == 3, "a later admit hits the retained chain"
    m.release(2)
    assert m.drop_prefix_cache() == 3
    assert m.allocator.n_used == 0, "all pages must return to the free list"
    assert m.tree.n_pages == 0 and m.tree.n_nodes == 0


def test_manager_admission_control():
    cfg = reduce_config(get_config("llama3.2-1b"))
    m = PagedCacheManager(cfg, n_slots=4, max_len=32, block_size=8,
                          n_blocks=4)
    assert m.admit(0, np.arange(17)) == 0  # 3 pages
    assert m.admit(1, np.arange(50, 60)) is None  # needs 2, 1 free: defer
    assert m.admit(2, np.arange(70, 75)) == 0  # 1 page fits
    with pytest.raises(ValueError):
        m.admit(3, np.arange(40))  # longer than max_len


# ---------------------------------------------------------------------------
# engine end-to-end: paged == dense == oracle
# ---------------------------------------------------------------------------

def _greedy_oracle(params, cfg, prompt, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward_seq(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        out.append(t)
        toks.append(t)
    return out


def _mixed_prompts(vocab, n=5):
    rng = np.random.RandomState(3)
    return [rng.randint(0, vocab, size=(int(s),)).astype(np.int32)
            for s in rng.randint(3, 20, size=n)]


@pytest.mark.parametrize("n_kv,impl", [
    (4, "xla"), (2, "xla"),  # MHA and GQA
    (2, "pallas_interpret"),
])
def test_paged_engine_matches_dense_and_oracle(n_kv, impl):
    """Mixed-length workload through more requests than the paged pool can
    hold at once: every greedy stream must match both the dense engine and
    the full-sequence oracle."""
    cfg = reduce_config(get_config("mistral-7b")).with_(n_kv_heads=n_kv)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _mixed_prompts(cfg.vocab_size)
    dense = Engine(cfg, params, ServeConfig(n_slots=3, max_len=64), impl=impl)
    paged = Engine(cfg, params,
                   ServeConfig(n_slots=4, max_len=64, cache_kind="paged",
                               block_size=8, n_blocks=16), impl=impl)
    out_d = dense.generate(prompts, max_new_tokens=6)
    out_p = paged.generate(prompts, max_new_tokens=6)
    assert out_p == out_d
    for p, o in zip(prompts, out_p):
        assert o == _greedy_oracle(params, cfg, p, 6), p[:3]
    assert paged.pm.allocator.n_used == len(paged.pm.tree.retained), \
        "drained engine holds only tree-retained prefix pages"
    paged.pm.drop_prefix_cache()
    assert paged.pm.allocator.n_used == 0, "drained engine must free pool"


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_paged_engine_merged_fast_path(impl):
    """QP-merged model through the paged engine: merged fast path + block
    tables must stay token-exact vs the merged full-sequence oracle."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    eng = Engine(mcfg, mparams,
                 ServeConfig(n_slots=3, max_len=64, cache_kind="paged",
                             block_size=8), impl=impl)
    assert eng.merged_fast_path
    prompts = _mixed_prompts(cfg.vocab_size, n=3)
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(mparams, mcfg, p, 6), p[:3]


def test_paged_prefix_sharing_cow_token_exact():
    """Two concurrent requests with the same prompt share its pages
    (including the partial tail page, diverging via copy-on-write when
    they decode) and still emit the oracle's exact stream."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    shared = np.arange(21) % cfg.vocab_size  # 2 full pages + 1 partial
    other = np.arange(7) + 3
    eng = Engine(cfg, params,
                 ServeConfig(n_slots=4, max_len=64, cache_kind="paged",
                             block_size=8))
    outs = eng.generate([shared, shared, other], max_new_tokens=6)
    w = _greedy_oracle(params, cfg, shared, 6)
    assert outs[0] == w and outs[1] == w
    assert outs[2] == _greedy_oracle(params, cfg, other, 6)
    assert eng.pm.allocator.n_shared_hits >= 3, "prompt pages must be shared"
    assert eng.pm.allocator.n_cow >= 1, "append into shared tail must CoW"


def test_paged_admission_and_preemption_token_exact():
    """Pool far smaller than the workload: requests defer (admission
    control) and get preempted mid-decode, then resume — streams must
    stay token-identical to the oracle throughout."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(8) + i for i in range(3)]
    eng = Engine(cfg, params,
                 ServeConfig(n_slots=3, max_len=64, cache_kind="paged",
                             block_size=8, n_blocks=7))
    outs = eng.generate(prompts, max_new_tokens=20)
    assert eng.stats["n_preempted"] > 0, "workload sized to force preemption"
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 20)


def test_paged_rejects_stateful_families():
    cfg = reduce_config(get_config("mamba2-2.7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(AssertionError):
        Engine(cfg, params, ServeConfig(n_slots=2, max_len=32,
                                        cache_kind="paged", block_size=8))


def test_submit_rejects_requests_that_cannot_finish():
    """prompt + max_new_tokens > max_len must fail fast at submit, not
    crash mid-decode when the request walks off its block table (which
    would discard every co-scheduled stream)."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    for kind in ("dense", "paged"):
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=32,
                                              cache_kind=kind, block_size=8))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.generate([np.arange(30) % cfg.vocab_size], max_new_tokens=8)


def test_preemption_preserves_sampling_stream():
    """A preempted+resumed request must continue its PRNG stream where it
    stopped — replaying draws from the start would make sampled output
    depend on co-scheduled traffic (which preemption is a function of)."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(8), np.arange(8) + 50]  # target submits last
    roomy = Engine(cfg, params,
                   ServeConfig(n_slots=2, max_len=64, temperature=1.0,
                               seed=5, cache_kind="paged", block_size=8,
                               n_blocks=16))
    out_roomy = roomy.generate(prompts, max_new_tokens=20)
    assert roomy.stats["n_preempted"] == 0
    tight = Engine(cfg, params,
                   ServeConfig(n_slots=2, max_len=64, temperature=1.0,
                               seed=5, cache_kind="paged", block_size=8,
                               n_blocks=5))
    out_tight = tight.generate(prompts, max_new_tokens=20)
    assert tight.stats["n_preempted"] > 0, "pool sized to force preemption"
    assert out_tight == out_roomy
