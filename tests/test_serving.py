"""Serving engine: continuous batching vs oracle, slot reuse, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import forward_seq, init_params
from repro.serving import Engine, Request, ServeConfig


def _greedy_oracle(params, cfg, prompt, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward_seq(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        out.append(t)
        toks.append(t)
    return out


@pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b", "mamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_continuous_batching_matches_oracle(arch):
    cfg = reduce_config(get_config(arch))
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=3, max_len=64))
    prompts = [np.arange(5) % cfg.vocab_size + i for i in range(5)]
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 6), (arch, p[:3])


def test_slot_reuse_more_requests_than_slots():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48))
    prompts = [np.arange(4) + i for i in range(7)]  # 7 requests, 2 slots
    outs = eng.generate(prompts, max_new_tokens=5)
    assert len(outs) == 7 and all(len(o) == 5 for o in outs)
    assert len(eng.free_slots) == 2 and not eng.active


def test_engine_merged_fast_path_matches_oracle():
    """Continuous batching over a QP-merged model: serve_step takes the
    merged decode fast path and must stay token-exact vs the
    full-sequence oracle on the merged weights."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    eng = Engine(mcfg, mparams, ServeConfig(n_slots=2, max_len=48))
    assert eng.merged_fast_path
    prompts = [np.arange(5) % cfg.vocab_size + i for i in range(3)]
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(mparams, mcfg, p, 6), p[:3]


def test_eos_terminates_early():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=48))
    # first find what greedy emits, then set that as EOS
    first = _greedy_oracle(params, cfg, np.arange(4), 2)
    eng.sc.eos_token = first[1]
    outs = eng.generate([np.arange(4)], max_new_tokens=10)
    assert outs[0][-1] == first[1] and len(outs[0]) <= 10


def test_temperature_sampling_masks_padded_vocab():
    cfg = reduce_config(get_config("hymba-1.5b"))  # vocab 128 -> padded 128
    cfg = cfg.with_(vocab_size=100)  # force padding (100 -> 128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48,
                                          temperature=1.0, seed=3))
    outs = eng.generate([np.arange(4) % 100, np.arange(4) % 100],
                        max_new_tokens=20)
    for o in outs:
        assert all(t < 100 for t in o), "sampled a padded vocab id"


@pytest.mark.parametrize("variant", ["kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_kp_vp_merged_variants_serve_generic_path(variant, cache_kind):
    """kp/vp merged variants (MHA-only, paper Fig 1c/d) have no fast-path
    route — the engine must report merged_fast_path=False and decode them
    through the generic path token-identically to the UNMERGED oracle, in
    both cache kinds (so the paged engine can't silently misroute them)."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4)  # MHA: kv_dim == d_model, required for kp/vp
    assert cfg.kp_vp_removal_applicable
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, variant)
    sc = ServeConfig(n_slots=2, max_len=48, cache_kind=cache_kind,
                     block_size=8)
    eng = Engine(mcfg, mparams, sc)
    assert not eng.merged_fast_path, "kp/vp must take the generic path"
    prompts = [np.arange(5) % cfg.vocab_size + i for i in range(3)]
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 6), (variant, p[:3])


def test_per_slot_prng_streams_traffic_independent():
    """A request's sampled continuation is a function of (params, prompt,
    seed, submission index) — NOT of co-scheduled traffic.  The engine
    docstring promised per-slot PRNG streams; a shared key would make the
    busy run diverge from the solo run."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sc = dict(n_slots=3, max_len=64, temperature=1.0, seed=11)
    p0 = np.arange(5)
    solo = Engine(cfg, params, ServeConfig(**sc)).generate(
        [p0], max_new_tokens=8)[0]
    busy = Engine(cfg, params, ServeConfig(**sc)).generate(
        [p0, np.arange(6) + 2, np.arange(4) + 9, np.arange(7) + 1],
        max_new_tokens=8)[0]
    assert solo == busy, "sampling must not depend on co-scheduled traffic"


def test_prompt_bucketing_exact_and_few_compiles():
    """Distinct prompt lengths share power-of-two prefill buckets: outputs
    stay oracle-exact while the prefill jit compiles O(log max_len)
    programs instead of one per length."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64))
    assert eng._bucketing
    prompts = [np.arange(n) % cfg.vocab_size for n in (3, 5, 6, 7, 9, 11, 13)]
    outs = eng.generate(prompts, max_new_tokens=4)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 4), len(p)
    # lengths 3..13 -> buckets {8, 16}: two compiled prefill programs
    assert eng._prefill._cache_size() <= 2, eng._prefill._cache_size()


def test_dense_serving_prompt_longer_than_window():
    """Ring-phase regression: a prompt longer than the sliding window must
    prefill the ring so decode overwrites EXPIRED positions (slot = pos %
    window), not live ones."""
    cfg = reduce_config(get_config("mistral-7b"))  # sliding_window 16
    assert 0 < cfg.sliding_window < 25
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(25) % cfg.vocab_size
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64))
    out = eng.generate([prompt], max_new_tokens=8)[0]
    assert out == _greedy_oracle(params, cfg, prompt, 8)
