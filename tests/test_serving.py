"""Serving engine: continuous batching vs oracle, slot reuse, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import forward_seq, init_params
from repro.serving import Engine, Request, ServeConfig


def _greedy_oracle(params, cfg, prompt, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward_seq(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        out.append(t)
        toks.append(t)
    return out


@pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b", "mamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_continuous_batching_matches_oracle(arch):
    cfg = reduce_config(get_config(arch))
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=3, max_len=64))
    prompts = [np.arange(5) % cfg.vocab_size + i for i in range(5)]
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 6), (arch, p[:3])


def test_slot_reuse_more_requests_than_slots():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48))
    prompts = [np.arange(4) + i for i in range(7)]  # 7 requests, 2 slots
    outs = eng.generate(prompts, max_new_tokens=5)
    assert len(outs) == 7 and all(len(o) == 5 for o in outs)
    assert len(eng.free_slots) == 2 and not eng.active


def test_engine_merged_fast_path_matches_oracle():
    """Continuous batching over a QP-merged model: serve_step takes the
    merged decode fast path and must stay token-exact vs the
    full-sequence oracle on the merged weights."""
    from repro.core import merge_skipless
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    eng = Engine(mcfg, mparams, ServeConfig(n_slots=2, max_len=48))
    assert eng.merged_fast_path
    prompts = [np.arange(5) % cfg.vocab_size + i for i in range(3)]
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(mparams, mcfg, p, 6), p[:3]


def test_eos_terminates_early():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=48))
    # first find what greedy emits, then set that as EOS
    first = _greedy_oracle(params, cfg, np.arange(4), 2)
    eng.sc.eos_token = first[1]
    outs = eng.generate([np.arange(4)], max_new_tokens=10)
    assert outs[0][-1] == first[1] and len(outs[0]) <= 10


def test_temperature_sampling_masks_padded_vocab():
    cfg = reduce_config(get_config("hymba-1.5b"))  # vocab 128 -> padded 128
    cfg = cfg.with_(vocab_size=100)  # force padding (100 -> 128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48,
                                          temperature=1.0, seed=3))
    outs = eng.generate([np.arange(4) % 100, np.arange(4) % 100],
                        max_new_tokens=20)
    for o in outs:
        assert all(t < 100 for t in o), "sampled a padded vocab id"
