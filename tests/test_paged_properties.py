"""Model-based property suite for the paged serving manager.

Hypothesis drives random request-lifecycle traces — admit (with prompt
duplication so prefix sharing and page forking fire), decode steps
(ensure_appendable + advance, the path that maps, copy-on-writes and
ring-recycles pages), preempt/release — against ``PagedCacheManager``
with a deliberately tiny pool, and checks after EVERY op:

  * no double-free: the free list holds no duplicates and is disjoint
    from every page any live slot maps;
  * refcounts match live sharers: ``allocator.ref[p]`` equals the number
    of slots currently mapping page ``p``, for every page;
  * conservation: ``n_free + n_used == n_blocks`` always;
  * windowed ring bound: a slot never holds more than
    ``ceil(window/block_size) + 1`` pages — checked both against the
    manager's own table and against an INDEPENDENT pure-python model of
    the ring-slot set a request's (prompt length, decoded tokens) implies;
  * conservation with RETENTION: slot-mapped pages + tree-retained pages
    + free pages == pool, and ``ref[p]`` == live sharers + (1 if the
    radix tree retains p); after draining every slot the pool holds only
    tree-retained pages, and ``drop_prefix_cache`` returns it to empty.

Marked ``property``: the CI ``property`` job runs this file with a raised
example budget (``PROPERTY_EXAMPLES``); tier-1 keeps the fast default and
skips cleanly when hypothesis is absent (tests/_hypothesis_stub.py).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.kernels.paging import paged_ring_blocks
from repro.serving.paged_kv_cache import (PagedCacheManager,
                                          PagedQ8CacheManager)

pytestmark = pytest.mark.property

MAX_EXAMPLES = int(os.environ.get("PROPERTY_EXAMPLES", "25"))

MAX_LEN = 64
BLOCK = 8
N_BLOCKS = 10  # tiny on purpose: admission failures and preemption fire
N_SLOTS = 4


class RefSlot:
    """Independent model of ONE request's page footprint: the set of
    table slots its (prompt length, decode steps) implies.  Knows nothing
    about the allocator — only the ring arithmetic the bound rests on."""

    def __init__(self, n_tokens: int, window: int):
        self.len = n_tokens
        self.ring = paged_ring_blocks(window, BLOCK)
        if self.ring >= -(-MAX_LEN // BLOCK):
            self.ring = 0  # window covers the table: absolute addressing
        nb = -(-n_tokens // BLOCK)
        first = max(0, n_tokens - window + 1) // BLOCK if self.ring else 0
        self.mapped = {b % self.ring if self.ring else b
                       for b in range(first, nb)}

    def step(self) -> None:
        li = self.len // BLOCK
        self.mapped.add(li % self.ring if self.ring else li)
        self.len += 1

    @property
    def n_pages(self) -> int:
        return len(self.mapped)


def _check_invariants(pm: PagedCacheManager, model: dict) -> None:
    alloc = pm.allocator
    free = list(alloc._free)
    assert len(set(free)) == len(free), "double-free: duplicate free pages"
    assert alloc.n_free + alloc.n_used == alloc.n_blocks

    holders = np.zeros((alloc.n_blocks,), np.int64)
    mapped: set = set()
    for slot, info in pm._slots.items():
        live = [p for p in info.blocks if p >= 0]
        assert len(set(live)) == len(live), "slot maps a page twice"
        assert not set(live) & set(free), "live page is on the free list"
        holders[live] += 1
        mapped |= set(live)
        # the ring bound, against the manager's own table …
        assert len(live) <= pm.ring_bound, (slot, live)
        assert info.hwm <= pm.ring_bound
        # … and against the independent ring-slot model
        assert len(live) == model[slot].n_pages, (slot, live)
        assert int(pm.lengths[slot]) == model[slot].len
    retained = set(pm.tree.retained)
    assert retained <= pm.tree.pages(), "retained page left the tree"
    assert not retained & set(free), "retained page on the free list"
    assert pm.tree.pages() <= mapped | retained, \
        "tree references a page with no slot and no retention"
    for p in retained:
        holders[p] += 1
    np.testing.assert_array_equal(
        alloc.ref, holders,
        err_msg="refcounts must equal live sharers + tree retention")
    # pool conservation: slot-mapped + tree-retained + free == pool
    assert mapped | retained | set(free) == set(range(alloc.n_blocks))


def _trace_strategy():
    # (op selector, slot/prompt selector, length selector); "step" is
    # over-weighted so traces actually decode across block boundaries
    return st.lists(
        st.tuples(
            st.sampled_from(["admit", "step", "step", "step", "step",
                             "release"]),
            st.integers(min_value=0, max_value=7),
            st.integers(min_value=1, max_value=40),
        ),
        min_size=1, max_size=60)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(window=st.sampled_from([0, 5, 16]), trace=_trace_strategy())
def test_manager_trace_invariants(window, trace):
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        sliding_window=window)
    pm = PagedCacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                           block_size=BLOCK, n_blocks=N_BLOCKS)
    model: dict = {}

    for op, sel, n in trace:
        active = sorted(model)
        if op == "admit" and len(model) < N_SLOTS:
            slot = min(set(range(N_SLOTS)) - set(active))
            # three prompt families sharing prefixes (sel picks one), so
            # identical admits fork pages instead of allocating
            toks = (np.arange(n, dtype=np.int32) + (sel % 3) * 100) \
                % cfg.vocab_size
            if pm.admit(slot, toks) is not None:
                model[slot] = RefSlot(n, window)
                # the engine prefills right after admit; the manager-level
                # trace only needs the table/length bookkeeping
                pm.prefill_block_ids(slot, len(toks))
        elif op == "step" and active:
            slot = active[sel % len(active)]
            if int(pm.lengths[slot]) + 1 >= MAX_LEN:
                continue
            if pm.ensure_appendable(slot):
                pm.advance(slot)
                model[slot].step()
            else:  # pool exhausted: the engine would preempt this slot
                pm.release(slot)
                del model[slot]
        elif op == "release" and active:
            slot = active[sel % len(active)]
            pm.release(slot)
            del model[slot]
        _check_invariants(pm, model)

    for slot in sorted(model):
        pm.release(slot)
    assert pm.allocator.n_used == len(pm.tree.retained), \
        "drained pool may hold only tree-retained prefix pages"
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0, "drained pool must free every page"
    assert pm.tree.n_pages == 0 and pm.tree.n_nodes == 0
    assert pm.request_page_hwm.max <= pm.ring_bound


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(window=st.sampled_from([5, 16]),
       n_prompt=st.integers(min_value=1, max_value=40),
       n_decode=st.integers(min_value=0, max_value=23))
def test_windowed_request_never_exceeds_ring_bound(window, n_prompt,
                                                   n_decode):
    """The acceptance bound in isolation: ONE windowed request, any
    (prompt, decode) split, never maps more than ceil(window/block)+1
    pages — while an unwindowed request of the same total length may."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        sliding_window=window)
    pm = PagedCacheManager(cfg, n_slots=1, max_len=MAX_LEN,
                           block_size=BLOCK, n_blocks=N_BLOCKS)
    assert pm.admit(0, np.arange(n_prompt, dtype=np.int32)) is not None
    bound = -(-window // BLOCK) + 1
    assert pm.ring_bound == bound
    for _ in range(n_decode):
        assert pm.ensure_appendable(0)
        pm.advance(0)
        mapped = int((pm.tables[0] >= 0).sum())
        assert mapped <= bound, (n_prompt, n_decode, mapped)
    pm.release(0)
    assert pm.request_page_hwm.last <= bound
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0


# ---------------------------------------------------------------------------
# paged_q8: scale rows travel with their page
# ---------------------------------------------------------------------------

def _stamp(pm, page, marker, expected):
    """Write a unique marker into page ``page``'s scale rows (all layers,
    all kv heads) — the stand-in for the real quantize-on-write, visible
    to the host so the model can track it through the lifecycle."""
    ks, vs = np.asarray(pm.k_scale).copy(), np.asarray(pm.v_scale).copy()
    ks[:, page, :] = marker
    vs[:, page, :] = marker + 0.5
    pm.k_scale, pm.v_scale = jnp.asarray(ks), jnp.asarray(vs)
    expected[page] = marker


def _live_pages(pm, slot):
    return [p for p in pm._slots[slot].blocks if p >= 0]


def _check_scales(pm, expected):
    """Every page any live slot maps must carry exactly the scale marker
    the model assigned it — through prefix sharing, CoW detach (the copy
    must carry the SOURCE page's rows), and ring recycling."""
    ks, vs = np.asarray(pm.k_scale), np.asarray(pm.v_scale)
    for slot in pm._slots:
        for p in _live_pages(pm, slot):
            assert p in expected, (slot, p, "mapped page never stamped")
            np.testing.assert_array_equal(
                ks[:, p, :], np.full_like(ks[:, p, :], expected[p]),
                err_msg=f"k_scale of page {p} lost its marker")
            np.testing.assert_array_equal(
                vs[:, p, :], np.full_like(vs[:, p, :], expected[p] + 0.5),
                err_msg=f"v_scale of page {p} lost its marker")


def _absorb_page_delta(pm, expected, before, after, d_cow, fresh_marker,
                       d_recycled=0):
    """Update the scale model after one op.  A copying CoW detach moves
    the source page's marker to the destination (copy_block_q8 copied
    the rows); any other newly mapped page is a fresh write and gets
    stamped.  In-place ring recycling changes no page id, so markers
    persist by construction — but a recycle-DETACH (``d_recycled`` with
    ``d_cow``: the window rolled over a page the tree or a peer still
    holds) copies nothing, so its fresh page is stamped like any other
    (every offset is rewritten before any query attends it)."""
    new_pages, gone = after - before, before - after
    if d_cow and not d_recycled and len(new_pages) == 1 and len(gone) == 1:
        src, dst = gone.pop(), new_pages.pop()
        # the copy must already be on the device BEFORE we update the
        # model — _check_scales then proves dst carries src's rows
        expected[dst] = expected[src]
        return fresh_marker
    for p in sorted(new_pages):
        _stamp(pm, p, fresh_marker, expected)
        fresh_marker += 1.0
    return fresh_marker


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(window=st.sampled_from([0, 5, 16]), trace=_trace_strategy())
def test_q8_scale_rows_travel_with_their_page(window, trace):
    """The q8 lifecycle invariant: scale rows are conserved in lockstep
    with their page through admit (prefix-shared pages keep the sharer's
    marker), CoW detach (the fork carries the source's rows), fresh maps,
    ring recycling (same page id — marker persists) and release — on top
    of all the fp manager's page-conservation invariants, which the q8
    manager inherits."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        sliding_window=window)
    pm = PagedQ8CacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                             block_size=BLOCK, n_blocks=N_BLOCKS)
    assert pm.k.dtype == jnp.int8 and pm.k_scale.dtype == jnp.float32
    model: dict = {}
    expected: dict = {}
    marker = 1.0

    def all_mapped():
        return {p for s in pm._slots for p in _live_pages(pm, s)}

    for op, sel, n in trace:
        active = sorted(model)
        before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
        if op == "admit" and len(model) < N_SLOTS:
            slot = min(set(range(N_SLOTS)) - set(active))
            toks = (np.arange(n, dtype=np.int32) + (sel % 3) * 100) \
                % cfg.vocab_size
            if pm.admit(slot, toks) is not None:
                model[slot] = RefSlot(n, window)
                pm.prefill_block_ids(slot, len(toks))
        elif op == "step" and active:
            slot = active[sel % len(active)]
            if int(pm.lengths[slot]) + 1 >= MAX_LEN:
                continue
            if pm.ensure_appendable(slot):
                pm.advance(slot)
                model[slot].step()
            else:
                pm.release(slot)
                del model[slot]
        elif op == "release" and active:
            slot = active[sel % len(active)]
            pm.release(slot)
            del model[slot]
        marker = _absorb_page_delta(pm, expected, before, all_mapped(),
                                    pm.allocator.n_cow - cow0, marker,
                                    pm.allocator.n_recycled - rec0)
        _check_invariants(pm, model)
        _check_scales(pm, expected)

    for slot in sorted(model):
        pm.release(slot)
    assert pm.allocator.n_used == 0


def test_q8_scales_survive_cow_and_recycle_without_hypothesis():
    """Tier-1 sanity for the q8 scale model: two identical windowed
    prompts share pages, decode forks them (CoW must carry the scale
    rows) and then rolls the ring over recycled pages — all without
    hypothesis, so a stubbed environment still covers the path."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(sliding_window=16)
    pm = PagedQ8CacheManager(cfg, n_slots=2, max_len=MAX_LEN,
                             block_size=BLOCK, n_blocks=N_BLOCKS)
    model, expected, marker = {}, {}, 1.0

    def all_mapped():
        return {p for s in pm._slots for p in _live_pages(pm, s)}

    for slot, n in ((0, 20), (1, 20)):  # identical prompts: shared pages
        before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
        assert pm.admit(slot, np.arange(n, dtype=np.int32)) is not None
        model[slot] = RefSlot(n, 16)
        pm.prefill_block_ids(slot, n)
        marker = _absorb_page_delta(pm, expected, before, all_mapped(),
                                    pm.allocator.n_cow - cow0, marker,
                                    pm.allocator.n_recycled - rec0)
        _check_scales(pm, expected)
    assert pm.allocator.n_shared_hits > 0, "prompts must actually share"
    for _ in range(24):
        for slot in (0, 1):
            before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
            if pm.ensure_appendable(slot):
                pm.advance(slot)
                model[slot].step()
            marker = _absorb_page_delta(pm, expected, before, all_mapped(),
                                        pm.allocator.n_cow - cow0, marker,
                                        pm.allocator.n_recycled - rec0)
            _check_invariants(pm, model)
            _check_scales(pm, expected)
    assert pm.allocator.n_cow > 0 or pm.allocator.n_recycled > 0
    for slot in (0, 1):
        pm.release(slot)
    assert pm.allocator.n_used == 0


# ---------------------------------------------------------------------------
# multi-tenant Zipf traffic vs an independent radix model
# ---------------------------------------------------------------------------

# ~Zipf(1) popularity over 4 tenant heads: rank r drawn with weight 1/2^r
ZIPF_RANKS = [0] * 8 + [1] * 4 + [2] * 2 + [3]


def _zipf_prompt(vocab, rank, depth, sfx_len, sfx_seed):
    """Tenant head (Zipf-popular system prompt, 2 blocks) + a nested
    few-shot stack (each shot one block, prefix-of-each-other across
    depths) + a unique user suffix — the multi-tenant serving shape
    where cross-request retention pays."""
    head = (np.arange(2 * BLOCK, dtype=np.int32) * 7 + 1
            + rank * 1000) % vocab
    shots = [(np.arange(BLOCK, dtype=np.int32) * 3 + 2 + d * 500) % vocab
             for d in range(depth)]
    sfx = (np.arange(sfx_len, dtype=np.int32) * 11 + sfx_seed + 17) % vocab
    return np.concatenate([head] + shots + [sfx]).astype(np.int32)


def _radix_cover(reg_full, reg_whole, toks) -> int:
    """Independent model of the tree's match: tokens covered are the
    longest registered full-block prefix chain, or the whole prompt on
    an exact whole-prompt registration (the tail rule)."""
    t = tuple(int(x) for x in toks)
    if t in reg_whole:
        return len(t)
    cov = 0
    for k in range(1, len(t) // BLOCK + 1):
        if t[:k * BLOCK] not in reg_full:
            break
        cov = k * BLOCK
    return cov


def _run_zipf_trace(trace):
    """Serve the trace one request at a time (every request RELEASED
    before the next admits, so live sharing never contributes — every
    hit crosses request lifetimes via retention) on two managers: a pool
    big enough that nothing is ever evicted, checked EXACTLY against the
    independent radix model, and the tiny default pool, where eviction
    makes the model an upper bound.  Invariants checked after every op."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    big = PagedCacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                            block_size=BLOCK, n_blocks=64)
    small = PagedCacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                              block_size=BLOCK, n_blocks=N_BLOCKS)
    reg_full, reg_whole = set(), set()
    expected_hits = 0
    for rank, depth, sfx_len, sfx_seed, n_dec in trace:
        toks = _zipf_prompt(cfg.vocab_size, rank, depth, sfx_len, sfx_seed)
        want = _radix_cover(reg_full, reg_whole, toks)
        _, got = big.tree.match(toks)
        assert got == want, "tree coverage diverged from the radix model"
        _, got_small = small.tree.match(toks)
        assert got_small <= want, "eviction can only lose coverage"
        for pm in (big, small):
            if pm.admit(0, toks) is None:
                assert pm is small, "the big pool must never defer"
                continue
            model = {0: RefSlot(len(toks), 0)}
            _check_invariants(pm, model)
            for _ in range(n_dec):
                if int(pm.lengths[0]) + 1 >= MAX_LEN:
                    break
                if pm.ensure_appendable(0):
                    pm.advance(0)
                    model[0].step()
                _check_invariants(pm, model)
            pm.release(0)
            _check_invariants(pm, {})
        expected_hits += want
        t = tuple(int(x) for x in toks)
        for k in range(1, len(t) // BLOCK + 1):
            reg_full.add(t[:k * BLOCK])
        if len(t) % BLOCK:
            reg_whole.add(t)
    assert big.tree.hit_tokens == expected_hits, (
        "hit-token accounting diverged from the radix model")
    assert small.tree.hit_tokens <= expected_hits
    for pm in (big, small):
        assert pm.allocator.n_used == len(pm.tree.retained)
        pm.drop_prefix_cache()
        assert pm.allocator.n_used == 0
        assert pm.tree.n_pages == 0 and pm.tree.n_nodes == 0
    return big, small


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(trace=st.lists(
    st.tuples(st.sampled_from(ZIPF_RANKS),   # tenant head, Zipf-weighted
              st.integers(min_value=0, max_value=3),    # few-shot depth
              st.integers(min_value=0, max_value=5),    # suffix length
              st.integers(min_value=0, max_value=50),   # suffix content
              st.integers(min_value=0, max_value=10)),  # decode steps
    min_size=1, max_size=25))
def test_zipf_multi_tenant_matches_radix_model(trace):
    _run_zipf_trace(trace)


def test_zipf_trace_runs_without_hypothesis():
    """Tier-1 sanity: a fixed Zipf trace exercises the radix-model
    comparison (and really fires eviction on the tiny pool) even when
    hypothesis is stubbed out."""
    rng = np.random.RandomState(0)
    trace = [(ZIPF_RANKS[rng.randint(len(ZIPF_RANKS))],
              int(rng.randint(0, 4)), int(rng.randint(0, 6)),
              int(rng.randint(0, 51)), int(rng.randint(0, 11)))
             for _ in range(20)]
    # every tenant at full depth with a unique suffix: guarantees the
    # retained footprint overflows the tiny pool, so eviction fires
    trace += [(r, 3, 5, 90 + r, 2) for r in range(4)]
    _, small = _run_zipf_trace(trace)
    assert small.tree.n_evicted > 0, (
        "the tiny pool must actually exercise eviction")


def test_hypothesis_is_exercised():
    """Tier-1 sanity: the trace interpreter runs even without hypothesis
    (one fixed trace), so a stubbed environment still covers the path."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(sliding_window=16)
    pm = PagedCacheManager(cfg, n_slots=2, max_len=MAX_LEN,
                           block_size=BLOCK, n_blocks=N_BLOCKS)
    model = {}
    for slot, n in ((0, 20), (1, 20)):  # identical prompts: forked pages
        assert pm.admit(slot, np.arange(n, dtype=np.int32)) is not None
        model[slot] = RefSlot(n, 16)
        _check_invariants(pm, model)
    for _ in range(24):  # roll both windows across recycled blocks
        for slot in (0, 1):
            if pm.ensure_appendable(slot):
                pm.advance(slot)
                model[slot].step()
            _check_invariants(pm, model)
    assert pm.allocator.n_recycled > 0 or pm.allocator.n_cow > 0
    for slot in (0, 1):
        pm.release(slot)
    assert pm.allocator.n_used == 0
