"""Radix-tree prefix cache: cross-request retention regressions.

Covers the PR's bugfix surface end to end:
  * the per-request page high-water tracker is O(1) in requests served
    (it replaced an unbounded ``List[int]`` — a host leak in a
    long-running server) while keeping every exported stat;
  * eviction / ring recycle fully clears registry state — a re-admitted
    prompt can never match a page whose bytes were reclaimed;
  * a WARM prefix hit (pages held only by the tree across request
    lifetimes) is byte-identical to recomputing: greedy streams match a
    cold-cache engine exactly, across fp/q8 pools and weight styles;
  * retention off restores the old flat-registry lifecycle (entries die
    with their page's last sharer).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.models import init_params
from repro.serving import (Engine, PagedCacheAdapter, PagedQ8CacheAdapter,
                           ServeConfig)
from repro.serving.paged_kv_cache import (PagedCacheManager, RequestPageHwm)

BLOCK = 8


def _mk_pm(cfg=None, *, n_blocks=10, window=0, retention=True):
    cfg = cfg or reduce_config(get_config("llama3.2-1b"))
    if window:
        cfg = cfg.with_(sliding_window=window)
    return PagedCacheManager(cfg, n_slots=4, max_len=64, block_size=BLOCK,
                             n_blocks=n_blocks, prefix_retention=retention)


def _conserved(pm):
    """Pool conservation: slot-mapped + tree-retained + free == pool,
    refcounts == live sharers + retention."""
    alloc = pm.allocator
    free = set(alloc._free)
    holders = np.zeros((alloc.n_blocks,), np.int64)
    mapped = set()
    for info in pm._slots.values():
        live = [p for p in info.blocks if p >= 0]
        holders[live] += 1
        mapped |= set(live)
    retained = set(pm.tree.retained)
    for p in retained:
        holders[p] += 1
    np.testing.assert_array_equal(alloc.ref, holders)
    assert not retained & free and not mapped & free
    assert mapped | retained | free == set(range(alloc.n_blocks))


# ---------------------------------------------------------------------------
# satellite: the high-water tracker is O(1) in requests served
# ---------------------------------------------------------------------------

def test_request_page_hwm_state_is_o1():
    """Serve/release far more requests than any bound and assert the
    tracker's state stays three ints — the old list grew per release."""
    # no containers anywhere: __slots__ pins the state, no __dict__ to
    # hide a list in, and every slot holds a plain int
    assert RequestPageHwm.__slots__ == ("max", "count", "last")
    assert not hasattr(RequestPageHwm(), "__dict__")

    pm = _mk_pm(n_blocks=24)
    n_requests = 500
    for i in range(n_requests):
        toks = (np.arange(4 + (i % 3) * 8, dtype=np.int32) * 7 + i) % 97
        assert pm.admit(0, toks) is not None
        pm.release(0)
        pm.drop_prefix_cache()  # keep the tiny pool drained as we spin
    hwm = pm.request_page_hwm
    assert hwm.count == n_requests
    assert hwm.max == 3  # 20-token prompts: ceil(20/8) pages
    assert 1 <= hwm.last <= hwm.max
    assert all(isinstance(getattr(hwm, s), int)
               for s in RequestPageHwm.__slots__)
    # emptiness + repr contracts consumers rely on
    assert bool(hwm) and not bool(RequestPageHwm())
    assert "count=500" in repr(hwm)


# ---------------------------------------------------------------------------
# satellite: eviction / recycle fully clears registry state
# ---------------------------------------------------------------------------

def test_evicted_prefix_never_matches_on_readmit():
    """Evict a retained chain under pressure, then re-admit the SAME
    prompt: zero stale matches (its old pages now hold other bytes) and
    conservation holds throughout."""
    pm = _mk_pm(n_blocks=10)
    prompt = (np.arange(27, dtype=np.int32) * 3 + 1) % 97  # 4 pages
    assert pm.admit(0, prompt) == 0
    pm.release(0)
    assert len(pm.tree.retained) == 4
    _conserved(pm)

    # pressure: a distinct 8-page prompt needs 2 more than the free
    # list holds — eviction reclaims exactly those, leaf-end first, so
    # the chain is consumed back to front (tail, then last full block)
    big = (np.arange(8 * BLOCK, dtype=np.int32) * 7 + 2) % 97
    assert pm.admit(1, big) == 0
    assert pm.tree.n_evicted == 2, "evict the minimum, back to front"
    _conserved(pm)
    pages, covered = pm.tree.match(prompt)
    assert len(pages) == 2 and covered == 2 * BLOCK, (
        "the surviving front of the chain must still match — only the "
        "evicted tail may disappear")
    pm.release(1)
    pm.drop_prefix_cache()
    _conserved(pm)

    pages, covered = pm.tree.match(prompt)
    assert pages == [] and covered == 0, "stale match after eviction"
    assert pm.admit(2, prompt) == 0, "re-admit must share nothing"
    _conserved(pm)
    pm.release(2)
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0
    assert pm.tree.n_pages == 0 and pm.tree.n_nodes == 0


def test_ring_recycle_clears_registry_for_retained_chain():
    """Windowed: a later request's ring rolls IN PLACE over its own
    solely-owned registered pages — the tree entry (and any retained
    descendants) must die with the bytes, so the prompt never matches
    stale content afterwards."""
    pm = _mk_pm(n_blocks=10, window=16)  # ring = 3
    prompt = (np.arange(12, dtype=np.int32) * 5 + 1) % 97  # fits window
    assert pm.admit(0, prompt) == 0
    # decode across the window: the ring recycles the registered pages
    while int(pm.lengths[0]) < 40:
        assert pm.ensure_appendable(0)
        pm.advance(0)
        _conserved(pm)
    assert pm.allocator.n_recycled > 0
    pages, covered = pm.tree.match(prompt)
    assert pages == [] and covered == 0, (
        "rolled-over page still matches its registered prompt")
    pm.release(0)
    assert pm.allocator.n_used == len(pm.tree.retained)
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0
    _conserved(pm)


def test_evict_order_lru_leaf_end_through_branches():
    """Pin the eviction semantics the single-traversal heap must keep:
    LRU leaf-end first (tails before their node's last block), chains
    consumed back to front, and a node drained of its subtree becomes
    the next candidate (cascade), until the tree is fully dry."""
    from repro.serving.radix_tree import RadixPrefixTree
    tree = RadixPrefixTree(block_size=4)
    head = list(range(8))                      # shared 2-block head
    a = np.asarray(head + [20, 21, 22, 23, 30], np.int32)  # + block + tail
    b = np.asarray(head + [40, 41, 42, 43], np.int32)      # + block
    tree.insert(a, [0, 1, 2, 3])
    tree.insert(b, [0, 1, 4])                  # shares pages 0,1
    tree.match(b)                              # bump branch b's LRU stamp
    tree.retained = set(tree.pages())
    order = tree.evict(100, lambda p: True)
    # branch a drains first (older LRU stamp): tail 3 before page 2;
    # then branch b's page 4; the shared head cascades last, back to
    # front, once both branches are gone
    assert order == [3, 2, 4, 1, 0]
    assert tree.n_pages == 0 and tree.n_nodes == 0 and not tree.retained
    assert tree.n_evicted == 5
    assert tree.evict(1, lambda p: True) == [], "dry tree yields nothing"


def test_ring_drop_with_live_sharer_of_retained_descendants():
    """Regression (review): C registers [b0..b3]; D shares, extends, and
    CoW-detaches from b0 as its window rolls (ref[b0] falls back to C
    alone), then finishes — the tree adopts b1..b3 (ref = C + tree).
    When C's window later rolls past b0, ``_drop_page(b0)`` drops the
    subtree and its retained orphans b1..b3 are STILL MAPPED by C:
    releasing the tree's reference must leave them to die with C's
    slot, not assert they were freed (the old assert crashed the
    serving loop on this reachable state)."""
    pm = _mk_pm(n_blocks=12, window=32)  # ring = 5, bs = 8
    prompt = (np.arange(32, dtype=np.int32) * 3 + 1) % 97  # blocks b0..b3
    assert pm.admit(0, prompt) == 0          # C registers the chain
    assert pm.admit(1, prompt.copy()) == 4   # D shares all four pages
    b0 = pm._slots[0].blocks[0]
    # D decodes until block 5 reuses ring slot 0: shared → CoW-detach
    while int(pm.lengths[1]) < 41:
        assert pm.ensure_appendable(1)
        pm.advance(1)
    assert int(pm.allocator.ref[b0]) == 1, "D must have detached from b0"
    pm.release(1)  # tree adopts D's still-registered pages b1..b3
    assert len(pm.tree.retained) == 3
    _conserved(pm)
    # C's window now rolls past b0: the drop's orphans are retained AND
    # live-mapped — must neither assert nor free them out from under C
    while int(pm.lengths[0]) < 41:
        assert pm.ensure_appendable(0)
        pm.advance(0)
        _conserved(pm)
    assert not pm.tree.retained, "orphans must lose the tree's reference"
    assert pm.tree.n_pages == 0
    pm.release(0)
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0
    _conserved(pm)


def test_retention_off_restores_old_registry_lifecycle():
    """``prefix_retention=False``: entries die with their page's last
    sharer — release returns the pool to empty, nothing survives for a
    later admit to hit."""
    pm = _mk_pm(n_blocks=10, retention=False)
    prompt = (np.arange(20, dtype=np.int32) * 3 + 2) % 97
    assert pm.admit(0, prompt) == 0
    assert pm.admit(1, prompt.copy()) == 3, "live sharing still works"
    pm.release(0)
    pm.release(1)
    assert pm.allocator.n_used == 0, "no retention: release frees all"
    assert pm.tree.n_pages == 0 and not pm.tree.retained
    assert pm.admit(2, prompt.copy()) == 0, "nothing survives to hit"
    pm.release(2)
    assert pm.allocator.n_used == 0


# ---------------------------------------------------------------------------
# warm hit == recompute, across pools and weight styles
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        block_style="skipless")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("cache_cls,merged", [
    (PagedCacheAdapter, False),
    (PagedCacheAdapter, True),
    (PagedQ8CacheAdapter, False),
    (PagedQ8CacheAdapter, True),
])
def test_warm_prefix_hit_token_identical_to_cold(small_model, cache_cls,
                                                 merged):
    """Two serve waves on one engine: wave 2 shares wave 1's prompt head
    AFTER every wave-1 request released, so its pages come from the
    tree's retention.  The warm streams must equal a cold-cache engine's
    (every page recomputed) token for token."""
    cfg, params = small_model
    if merged:
        params, cfg = merge_skipless(params, cfg, "qp")
    head = (np.arange(16, dtype=np.int32) * 5 + 1) % cfg.vocab_size
    wave1 = [head.copy(),
             np.concatenate([head, np.full((4,), 7, np.int32)])]
    wave2 = [np.concatenate([head, np.full((3,), 11, np.int32)]),
             head.copy()]

    def engine(retention):
        return Engine(cfg, params, ServeConfig(n_slots=2, max_len=64),
                      cache=cache_cls(block_size=BLOCK, n_blocks=24,
                                      prefix_retention=retention))

    warm = engine(True)
    warm.generate(wave1, max_new_tokens=4)
    assert not warm.pm._slots, "wave 1 must have fully released"
    assert warm.pm.tree.retained, "released prefix must be retained"
    hits0 = warm.pm.tree.hit_tokens
    warm_outs = warm.generate(wave2, max_new_tokens=4)
    assert warm.pm.tree.hit_tokens > hits0, (
        "wave 2 must hit the retained head across request lifetimes")

    cold = engine(False)
    cold_outs = cold.generate(wave2, max_new_tokens=4)
    assert warm_outs == cold_outs, (
        "a retained-page hit must be byte-identical to recomputing")
    # drained conservation on the warm engine
    warm.pm.drop_prefix_cache()
    assert warm.pm.allocator.n_used == 0
    _conserved(warm.pm)
