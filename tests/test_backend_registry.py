"""The serving API seam: every (cache_kind × style × impl) combo serves
through the single registry entry point (``models.forward_step`` looking
up ``models.backends``) and emits greedy tokens identical to the unmerged
dense XLA full-sequence oracle; unknown combos fail loudly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.kernels import ops as kops
from repro.models import backends, forward_seq, init_params, serving_style_key
from repro.serving import Engine, PagedCacheAdapter, ServeConfig

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    """One base model + its three merged rewrites + the oracle streams.

    MHA (n_kv_heads = n_heads) so the kp/vp variants are applicable
    (paper Fig 1c/d need e == d); float32 + scaled embeddings so the
    merged/unmerged logit comparison is well-conditioned.
    """
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4)
    assert cfg.kp_vp_removal_applicable
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0

    models = {"generic": (cfg, params)}
    for variant in ("qp", "kp", "vp"):
        mp, mc = merge_skipless(params, cfg, variant)
        models[variant] = (mc, mp)

    prompts = [np.arange(5) % cfg.vocab_size + 3 * i for i in range(2)]

    def greedy_oracle(prompt, n):
        toks = list(prompt)
        out = []
        for _ in range(n):
            lg, _, _ = forward_seq(params, cfg,
                                   jnp.asarray(toks, jnp.int32)[None])
            t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
            out.append(t)
            toks.append(t)
        return out

    oracle = [greedy_oracle(p, MAX_NEW) for p in prompts]
    return models, prompts, oracle


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_cross_product_matches_unmerged_dense_xla_oracle(
        setup, cache_kind, style, impl):
    """The acceptance grid: all (cache ∈ {dense,paged}) × (style ∈
    {generic,qp,kp,vp}) × (impl ∈ {xla,pallas}) combos serve through the
    one registry entry point, greedy token-identical to the unmerged
    dense XLA oracle.  kp/vp must route to the generic backend
    (merged_fast_path False); qp must take the fast path."""
    models, prompts, oracle = setup
    cfg, params = models[style]
    sc = ServeConfig(n_slots=2, max_len=48)
    cache = PagedCacheAdapter(block_size=8) if cache_kind == "paged" \
        else "dense"
    eng = Engine(cfg, params, sc, impl=impl, cache=cache)
    assert eng.backend.key == (cache_kind, serving_style_key(cfg), impl)
    assert eng.merged_fast_path == (style == "qp"), (
        "only the qp variant has a fast-path route; kp/vp and unmerged "
        "models serve through the generic backend")
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    for p, o, want in zip(prompts, outs, oracle):
        assert o == want, (cache_kind, style, impl, list(p[:3]))


def test_registry_rejects_unknown_combos():
    with pytest.raises(KeyError, match="no AttentionBackend registered"):
        backends.get_backend("ring", "generic", "xla")
    with pytest.raises(KeyError, match="registered combos"):
        backends.get_backend("dense", "quantized", "xla")
    with pytest.raises(KeyError, match="cuda"):
        backends.get_backend("dense", "generic", "cuda")
    with pytest.raises(KeyError, match="no Pallas decode kernel"):
        kops.decode_kernel("dense", "quantized")


def test_registry_covers_the_serving_grid():
    keys = set(backends.registered_backends())
    for ck in backends.CACHE_KINDS:
        for st in backends.STYLES:
            for impl in backends.IMPLS:
                assert (ck, st, impl) in keys, (ck, st, impl)
    for ck in backends.CACHE_KINDS:
        assert backends.get_backend(ck, "merged", "xla").fast_path
        assert not backends.get_backend(ck, "generic", "xla").fast_path


def test_engine_rejects_unknown_cache_kind():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="unknown cache kind"):
        Engine(cfg, params, ServeConfig(n_slots=1, max_len=32), cache="ring")


def test_serving_style_key():
    base = reduce_config(get_config("mistral-7b"))
    assert serving_style_key(base) == "generic"
    merged = base.with_(block_style="skipless_merged", merged_variant="qp")
    assert serving_style_key(merged) == "merged"
    kp = base.with_(block_style="skipless_merged", merged_variant="kp",
                    n_kv_heads=4)
    assert serving_style_key(kp) == "generic"
    ssm = reduce_config(get_config("mamba2-2.7b"))
    assert serving_style_key(ssm) == "generic"
    # hybrid merged keeps P (fused attn+ssm stream feeds the FFN): generic
    hybrid = reduce_config(get_config("hymba-1.5b")).with_(
        block_style="skipless_merged")
    assert serving_style_key(hybrid) == "generic"
