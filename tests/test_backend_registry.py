"""The serving API seam: every (cache_kind × style × impl) combo serves
through the single registry entry points — ``models.forward_step`` for
decode AND ``models.forward_prefill`` for prefill, both looking up
``models.backends`` — and emits greedy tokens identical to the unmerged
dense XLA full-sequence oracle; unknown combos fail loudly; invalid
prefill requests raise ValueError at the dispatch boundary (not asserts —
they must survive ``python -O``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.kernels import ops as kops
from repro.models import (DensePrefillDest, PagedPrefillDest, backends,
                          forward_prefill, forward_seq, forward_step,
                          init_paged_cache, init_params, prefill_style_key,
                          serving_style_key)
from repro.lint import walker as lint_walker
from repro.serving import (Engine, PagedCacheAdapter, PagedQ8CacheAdapter,
                           ServeConfig)
from repro.serving.paged_kv_cache import PagedCacheManager

MAX_NEW = 4
WIN = 3           # sliding window of the windowed grid axis
WIN_BLOCK = 2     # paged block size there -> ring bound ceil(3/2)+1 = 3
WIN_MAX_NEW = 5   # rolls the ring over a RECYCLED page by the 4th decoded
#                   token: the 7-token prompt maps blocks 2..3 (0..1 are
#                   dead at admit), decode maps block 4 fresh, then block 5
#                   lands on block 2's ring slot -> in-place recycle


@pytest.fixture(scope="module")
def setup():
    """One base model + its three merged rewrites + the oracle streams.

    MHA (n_kv_heads = n_heads) so the kp/vp variants are applicable
    (paper Fig 1c/d need e == d); float32 + scaled embeddings so the
    merged/unmerged logit comparison is well-conditioned.
    """
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4)
    assert cfg.kp_vp_removal_applicable
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0

    models = {"generic": (cfg, params)}
    for variant in ("qp", "kp", "vp"):
        mp, mc = merge_skipless(params, cfg, variant)
        models[variant] = (mc, mp)

    prompts = [np.arange(5) % cfg.vocab_size + 3 * i for i in range(2)]

    def greedy_oracle(prompt, n):
        toks = list(prompt)
        out = []
        for _ in range(n):
            lg, _, _ = forward_seq(params, cfg,
                                   jnp.asarray(toks, jnp.int32)[None])
            t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
            out.append(t)
            toks.append(t)
        return out

    oracle = [greedy_oracle(p, MAX_NEW) for p in prompts]
    return models, prompts, oracle


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_cross_product_matches_unmerged_dense_xla_oracle(
        setup, cache_kind, style, impl):
    """The acceptance grid: all (cache ∈ {dense,paged}) × (style ∈
    {generic,qp,kp,vp}) × (impl ∈ {xla,pallas}) combos serve through the
    one registry entry point, greedy token-identical to the unmerged
    dense XLA oracle.  kp/vp must route to the generic backend
    (merged_fast_path False); qp must take the fast path."""
    models, prompts, oracle = setup
    cfg, params = models[style]
    sc = ServeConfig(n_slots=2, max_len=48)
    cache = PagedCacheAdapter(block_size=8) if cache_kind == "paged" \
        else "dense"
    eng = Engine(cfg, params, sc, impl=impl, cache=cache)
    assert eng.backend.key == (cache_kind, serving_style_key(cfg), impl)
    assert eng.merged_fast_path == (style == "qp"), (
        "only the qp variant has a fast-path route; kp/vp and unmerged "
        "models serve through the generic backend")
    assert eng.prefill_backend.key == (cache_kind, prefill_style_key(cfg),
                                       impl)
    assert eng.merged_prefill_fast_path == (style == "qp"), (
        "prefill mirrors decode: only qp takes the stream-as-query fast "
        "path")
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    for p, o, want in zip(prompts, outs, oracle):
        assert o == want, (cache_kind, style, impl, list(p[:3]))


@pytest.fixture(scope="module")
def setup_windowed():
    """The sliding-window axis of both serving grids: same base model and
    merged rewrites as ``setup`` but with a window SMALLER than the first
    prompt, so every cell must window-mask at prefill and decode, and the
    paged cells must ring-recycle out-of-window pages
    (ceil(WIN/WIN_BLOCK)+1 = 3 table slots)."""
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4, sliding_window=WIN)
    assert cfg.kp_vp_removal_applicable
    params = init_params(jax.random.PRNGKey(1), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0

    models = {"generic": (cfg, params)}
    for variant in ("qp", "kp", "vp"):
        mp, mc = merge_skipless(params, cfg, variant)
        models[variant] = (mc, mp)

    # one prompt LONGER than the window (its head is out of window before
    # decode even starts) and one shorter
    prompts = [np.arange(7) % cfg.vocab_size,
               (np.arange(2) * 7 + 2) % cfg.vocab_size]

    def greedy_oracle(prompt, n):
        toks = list(prompt)
        out = []
        for _ in range(n):
            lg, _, _ = forward_seq(params, cfg,
                                   jnp.asarray(toks, jnp.int32)[None])
            t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
            out.append(t)
            toks.append(t)
        return out

    oracle = [greedy_oracle(p, WIN_MAX_NEW) for p in prompts]
    return models, prompts, oracle


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_windowed_cross_product_matches_unmerged_dense_xla_oracle(
        setup_windowed, cache_kind, style, impl):
    """The acceptance grid with ``sliding_window > 0``: every cache ×
    style × impl cell — dense window-sized ring buffers AND paged bounded
    ring tables with in-place page recycling — stays greedy-token-
    identical to the unmerged dense XLA oracle, including the prompt
    longer than the window."""
    models, prompts, oracle = setup_windowed
    cfg, params = models[style]
    sc = ServeConfig(n_slots=2, max_len=32)
    cache = PagedCacheAdapter(block_size=WIN_BLOCK) if cache_kind == "paged" \
        else "dense"
    eng = Engine(cfg, params, sc, impl=impl, cache=cache)
    outs = eng.generate(prompts, max_new_tokens=WIN_MAX_NEW)
    for p, o, want in zip(prompts, outs, oracle):
        assert o == want, (cache_kind, style, impl, list(p[:3]))
    if cache_kind == "paged":
        pm = eng.pm
        assert pm.ring == -(-WIN // WIN_BLOCK) + 1 == pm.ring_bound
        assert pm.allocator.n_recycled > 0, (
            "the 7-token prompt + decode must roll the ring over a "
            "recycled page — otherwise this grid isn't testing recycling")
        assert pm.request_page_hwm.max <= pm.ring_bound, (
            "a windowed request held more pages than ceil(window/block)+1")


def _greedy_windowed_paged(cfg, params, prompt, n, impl):
    """Greedy-decode through the dispatchers against a RING paged cache,
    with ``PagedCacheManager`` doing the table bookkeeping the engine
    normally drives (admit → direct-to-page prefill → ensure_appendable/
    advance around each step)."""
    pm = PagedCacheManager(cfg, n_slots=1, max_len=32,
                           block_size=WIN_BLOCK, n_blocks=16)
    toks = np.asarray(prompt, np.int32)
    n_shared = pm.admit(0, toks)
    assert n_shared is not None
    ids = pm.prefill_block_ids(0, len(toks))
    lg, (k, v) = forward_prefill(
        params, cfg, jnp.asarray(toks, jnp.int32)[None],
        PagedPrefillDest(pm.k, pm.v, jnp.asarray(ids, jnp.int32)), impl=impl)
    pm.k, pm.v = k, v
    out = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
    for _ in range(n - 1):
        assert pm.ensure_appendable(0)
        lg, cache = forward_step(params, cfg,
                                 jnp.asarray(out[-1:], jnp.int32),
                                 pm.device_cache(), impl=impl)
        pm.update_pools(cache)
        pm.advance(0)
        out.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
    assert max(int((pm.tables[0] >= 0).sum()), pm._slots[0].hwm) \
        <= pm.ring_bound
    return out


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_windowed_prefill_grid_matches_unmerged_dense_xla_oracle(
        setup_windowed, cache_kind, style, impl):
    """The PREFILL acceptance grid with ``sliding_window > 0``: prefill
    through the dispatcher into a window-ring dense cache / a bounded ring
    block table (live-window blocks only), then decode continuation —
    every cell must emit the unmerged dense XLA oracle's exact stream,
    including the prompt longer than the window (whose dead head blocks
    are never even mapped on the paged side)."""
    models, prompts, oracle = setup_windowed
    cfg, params = models[style]
    for p, want in zip(prompts, oracle):
        if cache_kind == "dense":
            got = _greedy_via_prefill_and_step(cfg, params, p, WIN_MAX_NEW,
                                               "dense", impl)
        else:
            got = _greedy_windowed_paged(cfg, params, p, WIN_MAX_NEW, impl)
        assert got == want, (cache_kind, style, impl, list(p[:3]))


def _greedy_via_prefill_and_step(cfg, params, prompt, n, cache_kind, impl):
    """Greedy-decode ``n`` tokens straight through the dispatchers: one
    ``forward_prefill`` into the cache kind's destination, then
    ``forward_step`` against the resulting cache."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    S = toks.shape[1]
    if cache_kind == "dense":
        lg, cache = forward_prefill(params, cfg, toks, DensePrefillDest(48),
                                    impl=impl)
    else:
        bs = 8
        pc = init_paged_cache(cfg, n_blocks=8, block_size=bs, n_slots=1,
                              max_len=S + n)
        nbk = -(-S // bs)
        lg, (k, v) = forward_prefill(
            params, cfg, toks,
            PagedPrefillDest(pc.k, pc.v, jnp.arange(nbk, dtype=jnp.int32)),
            impl=impl)
        mb = pc.block_tables.shape[1]
        cache = pc._replace(k=k, v=v,
                            block_tables=jnp.arange(
                                mb, dtype=jnp.int32)[None, :],
                            length=jnp.full((1,), S, jnp.int32))
    out = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
    for _ in range(n - 1):
        lg, cache = forward_step(params, cfg,
                                 jnp.asarray(out[-1:], jnp.int32), cache,
                                 impl=impl)
        out.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
    return out


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_prefill_grid_matches_unmerged_dense_xla_oracle(
        setup, cache_kind, style, impl):
    """The PREFILL acceptance grid, mirroring the decode grid: every
    (cache ∈ {dense,paged}) × (style ∈ {generic,qp,kp,vp}) × (impl ∈
    {xla,pallas}) combo prefills through the one registry dispatcher and
    (with decode continuation) emits the unmerged dense XLA oracle's
    exact greedy stream.  qp must resolve to the merged (fast-path)
    prefill backend; kp/vp must stay pinned to the generic one."""
    models, prompts, oracle = setup
    cfg, params = models[style]
    backend = backends.get_prefill_backend(cache_kind, prefill_style_key(cfg),
                                           impl)
    assert backend.fast_path == (style == "qp"), (
        "only the qp variant has a stream-as-query prefill route")
    for p, want in zip(prompts, oracle):
        got = _greedy_via_prefill_and_step(cfg, params, p, MAX_NEW,
                                           cache_kind, impl)
        assert got == want, (cache_kind, style, impl, list(p[:3]))


def test_registry_rejects_unknown_combos():
    with pytest.raises(KeyError, match="no AttentionBackend registered"):
        backends.get_backend("ring", "generic", "xla")
    with pytest.raises(KeyError, match="registered combos"):
        backends.get_backend("dense", "quantized", "xla")
    with pytest.raises(KeyError, match="cuda"):
        backends.get_backend("dense", "generic", "cuda")
    with pytest.raises(KeyError, match="no Pallas decode kernel"):
        kops.decode_kernel("dense", "quantized")


def test_prefill_registry_rejects_unknown_combos():
    with pytest.raises(KeyError, match="no PrefillBackend registered"):
        backends.get_prefill_backend("ring", "generic", "xla")
    with pytest.raises(KeyError, match="registered prefill combos"):
        backends.get_prefill_backend("dense", "quantized", "xla")
    with pytest.raises(KeyError, match="cuda"):
        backends.get_prefill_backend("dense", "generic", "cuda")
    with pytest.raises(KeyError, match="no Pallas attention kernel"):
        kops.attention_kernel("train", "dense", "generic")
    with pytest.raises(KeyError, match="no Pallas attention kernel"):
        kops.attention_kernel("prefill", "dense", "quantized")


def test_prefill_dispatcher_rejects_invalid_requests():
    """The paged-prefill preconditions are ValueErrors at the dispatch
    boundary — they must survive ``python -O`` (the asserts they replaced
    vanish under it)."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    kp = jnp.zeros((cfg.n_layers, 4, 8, cfg.n_kv_heads, cfg.d_head))
    ids1 = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="one request at a time"):
        forward_prefill(params, cfg, jnp.zeros((2, 8), jnp.int32),
                        PagedPrefillDest(kp, kp, ids1))
    with pytest.raises(ValueError, match="too few"):
        forward_prefill(params, cfg, jnp.zeros((1, 16), jnp.int32),
                        PagedPrefillDest(kp, kp, ids1))
    with pytest.raises(ValueError, match="cache_len > 0"):
        forward_prefill(params, cfg, jnp.zeros((1, 8), jnp.int32),
                        DensePrefillDest(0))
    with pytest.raises(ValueError, match="unknown prefill destination"):
        forward_prefill(params, cfg, jnp.zeros((1, 8), jnp.int32), "dense")
    with pytest.raises(ValueError, match="both dest= and legacy"):
        # a half-migrated call mixing conventions must fail, not silently
        # drop the legacy arguments and prefill the wrong cache kind
        forward_prefill(params, cfg, jnp.zeros((1, 8), jnp.int32),
                        DensePrefillDest(16), pages=(kp, kp, ids1))
    scfg = reduce_config(get_config("mamba2-2.7b"))
    sparams = init_params(jax.random.PRNGKey(0), scfg)
    with pytest.raises(ValueError, match="attention-only"):
        forward_prefill(sparams, scfg, jnp.zeros((1, 8), jnp.int32),
                        PagedPrefillDest(kp, kp, ids1))


def test_prefill_shim_and_dispatcher_are_token_identical(setup):
    """The deprecated ``cache_len=``/``pages=`` mega-signature is a pure
    shim: it must warn, and its logits, cache, and greedy continuation
    must be bit-identical to the ``dest=`` dispatcher's."""
    models, prompts, _ = setup
    cfg, params = models["qp"]
    toks = jnp.asarray(prompts[0], jnp.int32)[None]
    with pytest.warns(DeprecationWarning, match="mega-signature"):
        lg_old, c_old = forward_prefill(params, cfg, toks, cache_len=32)
    lg_new, c_new = forward_prefill(params, cfg, toks, DensePrefillDest(32))
    assert jnp.array_equal(lg_old, lg_new)
    for a, b in zip(jax.tree.leaves(c_old), jax.tree.leaves(c_new)):
        assert jnp.array_equal(a, b)

    def greedy(lg, cache):
        out = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
        for _ in range(3):
            lg, cache = forward_step(params, cfg,
                                     jnp.asarray(out[-1:], jnp.int32), cache)
            out.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
        return out

    assert greedy(lg_old, c_old) == greedy(lg_new, c_new)


def _count_dot_generals(jaxpr) -> int:
    """dot_general eqns anywhere in the program — the shared repro.lint
    walker (one recursion for the whole repo, not a per-test copy)."""
    return lint_walker.count_primitive(jaxpr, "dot_general")


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_merged_prefill_lowers_no_q_projection_matmul(setup, cache_kind,
                                                      impl):
    """The acceptance check, analogous to test_paged_prefill's
    no-max_len-buffer assertion: the lowered merged prefill program must
    contain NO Q-projection (or P-projection) matmul.  The qp-merged
    rewrite of the same model differs from its unmerged source by exactly
    the wq and wp matmuls per scanned layer body — so the merged jaxpr
    must count exactly two fewer dot_generals, and the merged param tree
    must hold no wq/wp to read in the first place."""
    models, prompts, _ = setup
    cfg, params = models["generic"]
    mcfg, mparams = models["qp"]
    assert "wq" not in mparams["layers"]["attn"], "no Q weights exist"
    assert "wp" not in mparams["layers"]["attn"], "no P weights exist"
    toks = jnp.asarray(prompts[0], jnp.int32)[None]

    if cache_kind == "dense":
        def prog(c):
            return lambda p, t: forward_prefill(p, c, t, DensePrefillDest(32),
                                                impl=impl)
        jx_g = jax.make_jaxpr(prog(cfg))(params, toks)
        jx_m = jax.make_jaxpr(prog(mcfg))(mparams, toks)
    else:
        S = toks.shape[1]
        pc = init_paged_cache(cfg, n_blocks=4, block_size=8, n_slots=1,
                              max_len=16)
        ids = jnp.arange(-(-S // 8), dtype=jnp.int32)

        def prog(c):
            return lambda p, t, kp, vp: forward_prefill(
                p, c, t, PagedPrefillDest(kp, vp, ids), impl=impl)
        jx_g = jax.make_jaxpr(prog(cfg))(params, toks, pc.k, pc.v)
        jx_m = jax.make_jaxpr(prog(mcfg))(mparams, toks, pc.k, pc.v)

    n_g, n_m = _count_dot_generals(jx_g), _count_dot_generals(jx_m)
    assert n_m == n_g - 2, (
        f"merged prefill must drop exactly the wq and wp matmuls: generic "
        f"has {n_g} dot_generals, merged has {n_m}")
    # same invariant as a lint rule — what repro.lint.sweep() enforces for
    # every registered combo without this test
    from repro.lint import LintTarget, NoForbiddenMatmul
    target = LintTarget(phase="prefill", cache_kind=cache_kind,
                        style="merged", impl=impl, jaxpr=jx_m,
                        source_jaxpr=jx_g)
    assert NoForbiddenMatmul().check(target) == []


def test_registry_covers_the_serving_grid():
    keys = set(backends.registered_backends())
    pkeys = set(backends.registered_prefill_backends())
    for ck in backends.CACHE_KINDS:
        for st in backends.STYLES:
            for impl in backends.IMPLS:
                assert (ck, st, impl) in keys, (ck, st, impl)
                assert (ck, st, impl) in pkeys, ("prefill", ck, st, impl)
    for ck in backends.CACHE_KINDS:
        assert backends.get_backend(ck, "merged", "xla").fast_path
        assert not backends.get_backend(ck, "generic", "xla").fast_path
        assert backends.get_prefill_backend(ck, "merged", "xla").fast_path
        assert not backends.get_prefill_backend(ck, "generic", "xla").fast_path


def test_engine_rejects_unknown_cache_kind():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="unknown cache kind"):
        Engine(cfg, params, ServeConfig(n_slots=1, max_len=32), cache="ring")


def test_serving_style_key():
    base = reduce_config(get_config("mistral-7b"))
    assert serving_style_key(base) == "generic"
    merged = base.with_(block_style="skipless_merged", merged_variant="qp")
    assert serving_style_key(merged) == "merged"
    kp = base.with_(block_style="skipless_merged", merged_variant="kp",
                    n_kv_heads=4)
    assert serving_style_key(kp) == "generic"
    ssm = reduce_config(get_config("mamba2-2.7b"))
    assert serving_style_key(ssm) == "generic"
    # hybrid merged keeps P (fused attn+ssm stream feeds the FFN): generic
    hybrid = reduce_config(get_config("hymba-1.5b")).with_(
        block_style="skipless_merged")
    assert serving_style_key(hybrid) == "generic"


# ------------------------------------------------------------- paged_q8


@pytest.fixture(scope="module")
def setup_q8():
    """mult=1 twin of ``setup`` for the q8-vs-fp greedy gate.

    int8 KV error is ~0.4% of each page's absmax, and the x50 embedding
    amplification that conditions the merged/unmerged float comparisons
    amplifies THAT error super-linearly through the skipless stack (no
    residual lane to damp it) — measured 75% of the logit range, far past
    any greedy margin.  The q8-vs-fp gate therefore runs on the unscaled
    model, where argmax margins dominate quantization noise.  The x50
    models from ``setup`` still back the q8-vs-q8 identity grid: those
    cells differ only by float-reordering-sized amounts (the pool bits
    are impl-independent by construction), which x50 conditions exactly
    as it does the fp grid."""
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    models = {"generic": (cfg, params)}
    for variant in ("qp", "kp", "vp"):
        mp, mc = merge_skipless(params, cfg, variant)
        models[variant] = (mc, mp)
    prompts = [np.arange(5) % cfg.vocab_size + 3 * i for i in range(2)]
    return models, prompts


def _engine_streams(cfg, params, cache, impl, prompts, n, max_len=48):
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=max_len),
                 impl=impl, cache=cache)
    outs = eng.generate(prompts, max_new_tokens=n)
    return eng, [list(map(int, o)) for o in outs]


@pytest.fixture(scope="module")
def q8_oracle(setup):
    """The q8 grid's own oracle: the (generic, xla) paged_q8 cell on the
    x50 models.  Every other q8 cell must be token-identical to it —
    quantize-on-write runs in plain XLA in every impl's program, so the
    pool bits (and hence the greedy stream) are impl- and
    style-independent."""
    models, prompts, _ = setup
    cfg, params = models["generic"]
    _, streams = _engine_streams(cfg, params, PagedQ8CacheAdapter(
        block_size=8), "xla", prompts, MAX_NEW)
    return streams


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
def test_q8_grid_token_identical_to_q8_xla_oracle(setup, q8_oracle, style,
                                                  impl):
    """The paged_q8 acceptance grid: every style × impl cell serves
    through the registry ("paged_q8" row, merged fast path for qp) and
    emits a greedy stream bit-identical to the (generic, xla) q8 cell.
    Identity — not closeness — because prefill's in-attention fake-quant,
    the direct-to-page writes, and decode's append all route through the
    same masked quantize, so every cell reads the same int8 pool."""
    models, prompts, _ = setup
    cfg, params = models[style]
    eng, streams = _engine_streams(cfg, params, PagedQ8CacheAdapter(
        block_size=8), impl, prompts, MAX_NEW)
    assert eng.backend.key == ("paged_q8", serving_style_key(cfg), impl)
    assert eng.merged_fast_path == (style == "qp")
    assert eng.prefill_backend.key == ("paged_q8", prefill_style_key(cfg),
                                       impl)
    for p, o, want in zip(prompts, streams, q8_oracle):
        assert o == want, (style, impl, list(p[:3]), o, want)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
def test_q8_greedy_matches_fp_paged_on_conditioned_model(setup_q8, style,
                                                         impl):
    """The cross-precision numerics gate at reduced shapes: on the
    well-conditioned (unscaled) model the int8 pool's greedy stream must
    MATCH the fp paged pool's, token for token, in every style × impl
    cell — quantization noise stays under the argmax margins."""
    models, prompts = setup_q8
    cfg, params = models[style]
    _, fp = _engine_streams(cfg, params, PagedCacheAdapter(block_size=8),
                            impl, prompts, MAX_NEW)
    _, q8 = _engine_streams(cfg, params, PagedQ8CacheAdapter(block_size=8),
                            impl, prompts, MAX_NEW)
    assert q8 == fp, (style, impl, fp, q8)


@pytest.fixture(scope="module")
def q8_windowed_oracle(setup_windowed):
    models, prompts, _ = setup_windowed
    cfg, params = models["generic"]
    _, streams = _engine_streams(cfg, params, PagedQ8CacheAdapter(
        block_size=WIN_BLOCK), "xla", prompts, WIN_MAX_NEW, max_len=32)
    return streams


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
def test_q8_windowed_grid_rings_and_stays_self_consistent(
        setup_windowed, q8_windowed_oracle, style, impl):
    """The sliding-window row of the q8 grid: bounded ring tables with
    in-place page recycling, int8 pages and their scale rows recycled in
    lockstep (``q8_append_token`` resets a page's scale on entry, so a
    recycled page never inherits the evicted request's scale).  Every
    cell token-identical to the (generic, xla) q8 windowed cell."""
    models, prompts, _ = setup_windowed
    cfg, params = models[style]
    eng, streams = _engine_streams(cfg, params, PagedQ8CacheAdapter(
        block_size=WIN_BLOCK), impl, prompts, WIN_MAX_NEW, max_len=32)
    for p, o, want in zip(prompts, streams, q8_windowed_oracle):
        assert o == want, (style, impl, list(p[:3]), o, want)
    pm = eng.pm
    assert pm.ring == -(-WIN // WIN_BLOCK) + 1 == pm.ring_bound
    assert pm.allocator.n_recycled > 0, (
        "the 7-token prompt + decode must roll the ring over a recycled "
        "page — otherwise this grid isn't testing q8 scale recycling")
    assert pm.request_page_hwm.max <= pm.ring_bound


def test_q8_prefill_logit_error_bounded_at_full_shape(setup_q8):
    """The second half of the numerics gate: at the full serving shape
    (a whole 48-token page-aligned prompt — six pages, every attention
    read crossing page-scale boundaries) the q8 prefill logits stay
    within a bounded relative error of the fp paged prefill logits."""
    from repro.models import (PagedQ8PrefillDest, init_paged_q8_cache)
    models, _ = setup_q8
    cfg, params = models["generic"]
    S, bs = 48, 8
    toks = jnp.asarray(np.arange(S) * 5 % cfg.vocab_size, jnp.int32)[None]
    nbk = S // bs
    pc = init_paged_cache(cfg, n_blocks=nbk, block_size=bs, n_slots=1,
                          max_len=S)
    lg_fp, _ = forward_prefill(
        params, cfg, toks,
        PagedPrefillDest(pc.k, pc.v, jnp.arange(nbk, dtype=jnp.int32)))
    qc = init_paged_q8_cache(cfg, n_blocks=nbk, block_size=bs, n_slots=1,
                             max_len=S)
    lg_q8, _ = forward_prefill(
        params, cfg, toks,
        PagedQ8PrefillDest(qc.k, qc.v, qc.k_scale, qc.v_scale,
                           jnp.arange(nbk, dtype=jnp.int32)))
    err = float(jnp.max(jnp.abs(lg_q8 - lg_fp)))
    scale = float(jnp.max(jnp.abs(lg_fp)))
    assert err <= 0.10 * scale, (
        f"q8 prefill logit error {err:.4g} exceeds 10% of the fp logit "
        f"range {scale:.4g}")
    # and the greedy choice itself must survive the perturbation here
    assert int(jnp.argmax(lg_q8[0, :cfg.vocab_size])) \
        == int(jnp.argmax(lg_fp[0, :cfg.vocab_size]))


def test_q8_prefill_dispatcher_rejects_unaligned_prompt():
    """paged_q8 prefill quantizes whole pages on write — a prompt that
    is not page-aligned must be rejected at the dispatch boundary (the
    engine's bucket padding guarantees alignment; raw callers get a
    ValueError, not silent garbage in the last page's scale)."""
    from repro.models import PagedQ8PrefillDest
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    kp = jnp.zeros((cfg.n_layers, 4, 8, cfg.n_kv_heads, cfg.d_head),
                   jnp.int8)
    ks = jnp.zeros((cfg.n_layers, 4, cfg.n_kv_heads), jnp.float32)
    ids1 = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="multiple of the page size"):
        forward_prefill(params, cfg, jnp.zeros((1, 5), jnp.int32),
                        PagedQ8PrefillDest(kp, kp, ks, ks, ids1))


def test_prefill_style_key():
    base = reduce_config(get_config("mistral-7b"))
    assert prefill_style_key(base) == "generic"
    merged = base.with_(block_style="skipless_merged", merged_variant="qp")
    assert prefill_style_key(merged) == "merged"
    kp = base.with_(block_style="skipless_merged", merged_variant="kp",
                    n_kv_heads=4)
    assert prefill_style_key(kp) == "generic"
    ssm = reduce_config(get_config("mamba2-2.7b"))
    assert prefill_style_key(ssm) == "generic"
    # vlm qp DECODES merged (self-attn steps only) but PREFILLS generic:
    # the interleaved cross-attention layers read vision tokens, which the
    # stream-as-query whole-prompt core does not cover
    vlm = reduce_config(get_config("llama3.2-vision-11b")).with_(
        block_style="skipless_merged", merged_variant="qp")
    assert serving_style_key(vlm) == "merged"
    assert prefill_style_key(vlm) == "generic"
