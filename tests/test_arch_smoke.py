"""Per-assigned-architecture smoke tests (reduced configs, CPU).

Each arch: instantiate the reduced family-preserving config, run one
forward and one full train step, assert output shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import count_params, forward_seq, init_params
from repro.training import make_train_step, make_optimizer


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":
        batch = {"inputs": jax.random.normal(ks[0], (B, S, cfg.d_model)),
                 "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    else:
        toks = jax.random.randint(ks[0], (B, S + 1), 0, cfg.vocab_size)
        batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(ks[2], (B, cfg.n_vision_tokens,
                                                    cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert count_params(params) > 0
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = forward_seq(params, cfg, batch["inputs"],
                                 vision=batch.get("vision"))
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert np.isfinite(float(aux))

    opt = make_optimizer("adamw", 1e-3, 2, 100)
    step = jax.jit(make_train_step(cfg, opt, remat=True))
    new_params, new_opt, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).qp_removal_applicable])
def test_merged_style_trains(arch):
    """The paper's merged form is a first-class trainable architecture."""
    cfg = reduce_config(get_config(arch)).with_(block_style="skipless_merged")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    opt = make_optimizer("adamw", 1e-3, 2, 100)
    step = jax.jit(make_train_step(cfg, opt))
    _, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact assigned hyperparameters (source-of-truth guard)."""
    expect = {
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "llama3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == expect
    if arch == "mamba2-2.7b":
        assert c.ssm_state == 128
    if arch == "hymba-1.5b":
        assert c.ssm_state == 16
    if arch == "phi3.5-moe-42b-a6.6b":
        assert (c.n_experts, c.experts_per_token) == (16, 2)
    if arch == "moonshot-v1-16b-a3b":
        assert (c.n_experts, c.experts_per_token) == (64, 6)
