"""repro.lint: every rule proven LIVE (negative-fire on a bad program),
the registry sweep proven COMPLETE (target count == registry size), and
the host-aliasing detector proven both clean on the real engines and
firing on sabotaged ones."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.lint import (DonationEffective, Finding, LintRule, LintTarget,
                        NoDequantizedPoolBuffer, NoDtypePromotionDrift,
                        NoForbiddenMatmul, NoHostTransferInObsHooks,
                        NoHostTransferInStepLoop, NoOversizedBuffer, aliasing,
                        get_rule, register_rule, registered_rules, run_rules,
                        sweep, walker)
from repro.lint.builtin import HOST_TRANSFER_PRIMITIVES
from repro.models import backends, init_params
from repro.serving import Engine, ServeConfig
from repro.serving.hostbufs import ALIGN, aligned_empty, aligned_zeros
from repro.serving.paged_kv_cache import PagedDecodeCache

MAX_LEN = 160  # collides with no reduced model dim (cf. test_paged_prefill)


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------

def test_walker_recurses_into_scan_and_cond():
    def inner(c, x):
        y = jnp.where(c.sum() > 0,
                      jnp.dot(c, x),          # dot inside the branch
                      jnp.dot(x, c))
        return y, y

    def f(c, xs):
        return jax.lax.scan(inner, c, xs)

    c = jnp.zeros((3, 3))
    xs = jnp.zeros((5, 3, 3))
    jx = jax.make_jaxpr(f)(c, xs)
    # both dots live inside the scan body: a non-recursive count sees 0
    assert walker.count_primitive(jx, "dot_general") == 2
    assert sum(1 for e in walker.as_jaxpr(jx).eqns
               if e.primitive.name == "dot_general") == 0
    # aval stream includes scan-internal shapes
    assert any(tuple(getattr(a, "shape", ())) == (3, 3)
               for a in walker.iter_avals(jx))
    assert "scan" in walker.primitive_names(jx)


def test_donated_flat_indices_count_pytree_leaves():
    args = ({"a": jnp.zeros(2), "b": jnp.zeros(2)}, jnp.zeros(3),
            [jnp.zeros(1)] * 3)
    assert walker.donated_flat_indices(args, (1,)) == [2]
    assert walker.donated_flat_indices(args, (0, 2)) == [0, 1, 3, 4, 5]


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

def test_rule_registry_latest_wins_and_loud_unknown():
    class Probe(LintRule):
        name = "test_probe_rule"
        description = "x"

        def applies(self, t):
            return False

        def check(self, t):
            return []

    try:
        first, second = Probe(), Probe()
        register_rule(first)
        register_rule(second)
        assert get_rule("test_probe_rule") is second
        assert "test_probe_rule" in registered_rules()
        with pytest.raises(KeyError, match="registered rules"):
            get_rule("no_such_rule")
    finally:
        from repro.lint.rules import _RULES
        _RULES.pop("test_probe_rule", None)


# ---------------------------------------------------------------------------
# negative fire: every built-in rule must trigger on a bad program
# ---------------------------------------------------------------------------

def _target(**kw):
    base = dict(phase="decode", cache_kind="dense", style="generic",
                impl="xla", jaxpr=None)
    base.update(kw)
    return LintTarget(**base)


def test_no_forbidden_matmul_fires_when_q_is_left_in():
    # a "merged" program that is secretly the UNMERGED one: same count as
    # its source, so the required (-2) delta is violated
    f = jax.make_jaxpr(lambda a, b: a @ b @ b)(jnp.zeros((3, 3)),
                                               jnp.zeros((3, 3)))
    t = _target(style="merged", jaxpr=f, source_jaxpr=f)
    findings = NoForbiddenMatmul().check(t)
    assert findings and findings[0].rule == "NoForbiddenMatmul"
    assert findings[0].detail == {"merged": 2, "source": 2}
    # ...and stays quiet on an honest -2 delta
    g = jax.make_jaxpr(lambda a, b: a @ b @ b @ a @ b)(jnp.zeros((3, 3)),
                                                       jnp.zeros((3, 3)))
    assert NoForbiddenMatmul().check(_target(style="merged", jaxpr=f,
                                             source_jaxpr=g)) == []


def test_no_oversized_buffer_fires_on_max_len_intermediate():
    bad = jax.make_jaxpr(
        lambda x: (jnp.zeros((1, MAX_LEN, 4)) + x).sum())(jnp.zeros((4,)))
    t = _target(phase="prefill", cache_kind="paged", jaxpr=bad,
                max_len=MAX_LEN)
    findings = NoOversizedBuffer().check(t)
    assert findings and str(MAX_LEN) in findings[0].message
    ok = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros((4,)))
    assert NoOversizedBuffer().check(_target(
        phase="prefill", cache_kind="paged", jaxpr=ok, max_len=MAX_LEN)) == []


def test_donation_effective_fires_on_dropped_donation():
    # b is donated but NO output matches its aval -> jax silently drops
    # the donation; the rule must not
    a = jax.ShapeDtypeStruct((4,), jnp.float32)
    b = jax.ShapeDtypeStruct((6,), jnp.float32)

    def f(x, y):
        return x + y.sum()

    lowered = jax.jit(f, donate_argnums=(1,)).lower(a, b)
    t = _target(jaxpr=None, lowered=lowered,
                donated_flat=tuple(walker.donated_flat_indices((a, b), (1,))))
    findings = DonationEffective().check(t)
    assert findings and "donat" in findings[0].message
    # effective donation (same-aval output) passes
    lowered_ok = jax.jit(lambda x, y: (x.sum(), y + 1),
                         donate_argnums=(1,)).lower(a, b)
    t_ok = _target(jaxpr=None, lowered=lowered_ok,
                   donated_flat=tuple(walker.donated_flat_indices((a, b),
                                                                  (1,))))
    assert DonationEffective().check(t_ok) == []


def test_dtype_promotion_drift_fires_on_fp32_shadow():
    shape = (4, 8)
    k = jnp.zeros(shape, jnp.bfloat16)

    def drift(x):  # a full cache-shaped fp32 shadow of a bf16 buffer
        return (x.astype(jnp.float32) + 1.0).astype(jnp.bfloat16)

    t = _target(jaxpr=jax.make_jaxpr(drift)(k), cache_shapes=(shape,),
                cache_dtype=jnp.bfloat16)
    findings = NoDtypePromotionDrift().check(t)
    assert findings and "float32" in str(findings[0].detail)

    def clean(x):
        return x + jnp.bfloat16(1.0)

    t2 = _target(jaxpr=jax.make_jaxpr(clean)(k), cache_shapes=(shape,),
                 cache_dtype=jnp.bfloat16)
    assert NoDtypePromotionDrift().check(t2) == []


def test_no_dequantized_pool_buffer_fires_on_fp32_shadow():
    """A paged_q8 program that materializes ``pool.astype(f32)`` — the
    convenience bug the rule exists for — must fire; tile-bounded or
    gathered-row dequant (different shapes) must not."""
    shape = (6, 8, 2, 4)  # (n_blocks, block, heads, d_head) int8 pool
    pool = jnp.zeros(shape, jnp.int8)
    scale = jnp.zeros((6, 2), jnp.float32)

    def bad(p, s):  # a full-precision shadow of the whole pool
        return (p.astype(jnp.float32) * s[:, None, :, None]).sum()

    t = _target(cache_kind="paged_q8", jaxpr=jax.make_jaxpr(bad)(pool, scale),
                cache_shapes=(shape,), cache_dtype=jnp.int8)
    findings = NoDequantizedPoolBuffer().check(t)
    assert findings and findings[0].rule == "NoDequantizedPoolBuffer"
    assert "float32" in str(findings[0].detail)

    def clean(p, s):  # gathered-rows dequant: NOT pool-shaped
        rows = p[jnp.array([0, 2])].astype(jnp.float32)
        return (rows * s[jnp.array([0, 2])][:, None, :, None]).sum()

    t2 = _target(cache_kind="paged_q8",
                 jaxpr=jax.make_jaxpr(clean)(pool, scale),
                 cache_shapes=(shape,), cache_dtype=jnp.int8)
    assert NoDequantizedPoolBuffer().check(t2) == []
    # int32 would be just as fatal as float32 — itemsize is the test
    def bad_int(p):
        return p.astype(jnp.int32).sum()
    t3 = _target(cache_kind="paged_q8",
                 jaxpr=jax.make_jaxpr(bad_int)(pool),
                 cache_shapes=(shape,), cache_dtype=jnp.int8)
    assert NoDequantizedPoolBuffer().check(t3)
    # rule is scoped to paged_q8 programs only
    assert not NoDequantizedPoolBuffer().applies(
        _target(cache_kind="paged", cache_shapes=(shape,)))


def test_host_transfer_fires_on_debug_print_in_step():
    def leaky(x):
        jax.debug.print("tok {}", x[0])
        return x * 2

    jx = jax.make_jaxpr(leaky)(jnp.zeros((3,)))
    # the primitive jax.debug.print lowers to is on the denylist
    assert set(walker.primitive_names(jx)) & HOST_TRANSFER_PRIMITIVES
    findings = NoHostTransferInStepLoop().check(_target(jaxpr=jx))
    assert findings and "host" in findings[0].message
    clean = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros((3,)))
    assert NoHostTransferInStepLoop().check(_target(jaxpr=clean)) == []


def test_obs_hooks_rule_fires_on_instrumentation_staged_into_program():
    """A program that consults the active observer and stages a
    debug_print when obs is on: the count DIFF between the plain and
    instrumented traces is what must fire, not mere presence."""
    from repro.obs import Observer, activated, get_active

    def f(x):
        if get_active().enabled:  # the forbidden temptation
            jax.debug.print("tok {}", x[0])
        return x * 2

    plain = jax.make_jaxpr(f)(jnp.zeros((3,)))
    with activated(Observer(trace_capacity=16)):
        # fresh lambda: defeat jax's (fn identity, avals) trace cache,
        # exactly as the sweep's _instrumented_jaxpr must
        instr = jax.make_jaxpr(lambda x: f(x))(jnp.zeros((3,)))
    t = _target(jaxpr=plain, instrumented_jaxpr=instr)
    findings = NoHostTransferInObsHooks().check(t)
    assert findings and findings[0].rule == "NoHostTransferInObsHooks"
    assert findings[0].detail["new"], findings[0].detail
    assert "host-side" in findings[0].message


def test_obs_hooks_rule_quiet_on_identical_and_preexisting_transfers():
    # identical traces: clean
    clean = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros((3,)))
    assert NoHostTransferInObsHooks().check(
        _target(jaxpr=clean, instrumented_jaxpr=clean)) == []

    # a host transfer present in BOTH traces is NoHostTransferInStepLoop's
    # business — the count diff is zero, so this rule stays quiet
    def leaky(x):
        jax.debug.print("tok {}", x[0])
        return x * 2

    jx = jax.make_jaxpr(leaky)(jnp.zeros((3,)))
    assert NoHostTransferInObsHooks().check(
        _target(jaxpr=jx, instrumented_jaxpr=jx)) == []

    # no instrumented trace recorded -> rule does not apply
    assert not NoHostTransferInObsHooks().applies(_target(jaxpr=clean))


def test_run_rules_scopes_by_applies():
    jx = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros((2,)))
    ran, findings = run_rules(_target(phase="prefill", jaxpr=jx))
    assert "NoHostTransferInStepLoop" not in ran  # decode-only rule
    assert findings == []


# ---------------------------------------------------------------------------
# the registry sweep
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep_report():
    return sweep()


def test_sweep_covers_every_registered_backend(sweep_report):
    rep = sweep_report
    assert rep.n_decode_targets == len(backends.registered_backends())
    assert rep.n_prefill_targets == len(backends.registered_prefill_backends())
    assert rep.ok, [str(f) for f in rep.findings]
    by_key = {t.key: t for t in rep.targets}
    assert len(by_key) == len(rep.targets)  # no duplicate targets
    for t in rep.targets:
        if t.style == "merged":
            assert "NoForbiddenMatmul" in t.rules_run, t.key
        if t.phase == "prefill" and t.cache_kind in ("paged", "paged_q8"):
            assert "NoOversizedBuffer" in t.rules_run, t.key
        if t.cache_kind == "paged_q8":
            assert "NoDequantizedPoolBuffer" in t.rules_run, t.key
        if t.phase == "decode":
            assert "NoHostTransferInStepLoop" in t.rules_run, t.key
        assert "NoDtypePromotionDrift" in t.rules_run, t.key
        # every builder re-traces under an active observer, so the obs
        # host-transfer diff must have run everywhere
        assert "NoHostTransferInObsHooks" in t.rules_run, t.key
        if t.impl in ("xla", "pallas_interpret") and (
                t.phase == "decode" or t.cache_kind == "paged"):
            # production donates the cache/pools; the sweep must prove
            # the donation survives lowering wherever lowering works
            assert "DonationEffective" in t.rules_run, t.key


def test_sweep_flags_unregisterable_combo_loudly():
    """A registered backend the sweep has no builder/model for must be a
    loud SweepCoverage ERROR, never a silently-unlinted combo."""
    step = backends.get_backend("dense", "generic", "xla").step
    backends.register_backend("quantized9", "generic", step, impls=("xla",))
    try:
        rep = sweep()
        assert not rep.ok
        cov = [f for f in rep.findings if f.rule == "SweepCoverage"]
        assert cov and "quantized9" in cov[0].target
        # still covers the whole (now larger) registry
        assert rep.n_decode_targets == len(backends.registered_backends())
    finally:
        from repro.models.backends import _REGISTRY
        _REGISTRY.pop(("quantized9", "generic", "xla"), None)


# ---------------------------------------------------------------------------
# host-aliasing detector
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(small_model, kind):
    cfg, params = small_model
    return Engine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                  cache=kind)


def test_hostbufs_are_aligned_and_zero_copy_certain():
    buf = aligned_zeros((7, 3), np.int32)
    assert buf.ctypes.data % ALIGN == 0
    assert buf.flags.c_contiguous and buf.flags.writeable
    # the whole point: ingestion of an aligned buffer is zero-copy, so a
    # missing .copy() always aliases (never "only on lucky mallocs")
    assert np.shares_memory(np.asarray(jnp.asarray(buf)), buf)


@pytest.mark.parametrize("kind", ["dense", "paged", "paged_q8"])
def test_audit_clean_on_real_engines(small_model, kind):
    findings = aliasing.audit_engine(_engine(small_model, kind))
    assert findings == [], [str(f) for f in findings]


def test_audit_flags_noncopying_device_cache(small_model):
    """Reintroduce the PR 5 bug (block table handed to the device without
    a copy) — the jit-boundary spy must flag it."""
    eng = _engine(small_model, "paged")

    def bad(self):
        return PagedDecodeCache(k=self.k, v=self.v,
                                block_tables=jnp.asarray(self.tables),
                                length=jnp.asarray(self.lengths))

    eng.kv.pm.device_cache = types.MethodType(bad, eng.kv.pm)
    findings = aliasing.audit_engine(eng)
    assert any(f.rule == aliasing.RULE_JIT_INPUT for f in findings)
    assert any("pm.tables" in f.message for f in findings)


def test_audit_flags_zero_copy_ingestion(small_model):
    """Drop the copy at the host->device seam (the submit/step ingestion
    fix) — both the seam check and the jit-boundary spy must fire."""
    eng = _engine(small_model, "dense")
    eng.host_to_device = lambda x, dtype=None: jnp.asarray(
        np.asarray(x, dtype))
    rules = {f.rule for f in aliasing.audit_engine(eng)}
    assert aliasing.RULE_INGEST in rules
    assert aliasing.RULE_JIT_INPUT in rules  # the prompt reached the jit


def test_check_host_views_flags_device_backed_numpy():
    view = np.asarray(jnp.zeros((2,), jnp.uint32))  # read-only, pins buffer
    findings = aliasing.check_host_views({"request[0].key_state": view}, "t")
    assert findings and findings[0].rule == aliasing.RULE_HOST_VIEW
    owned = np.array(jnp.zeros((2,), jnp.uint32))
    assert aliasing.check_host_views({"k": owned}, "t") == []


def test_preempted_key_state_owns_its_memory(small_model):
    """Regression for the engine._preempt fix: the resume key handed back
    to a request must be an OWNED copy, not a read-only device view."""
    from repro.serving import Request
    eng = _engine(small_model, "paged")
    p = aligned_empty((8,), np.int32)
    p[:] = np.arange(8) % eng.cfg.vocab_size
    assert eng.submit(Request(prompt=p, max_new_tokens=4))
    slot = next(iter(eng.active))
    eng._preempt(slot)
    req = eng.preempted[0]
    assert req.key_state is not None
    assert req.key_state.base is None and req.key_state.flags.writeable
    # and the preempted request still resumes to completion
    assert eng.submit(req)
    while eng.active:
        eng.step()
    assert len(req.out_tokens) >= 4


def test_engine_declares_its_host_mutable_buffers(small_model):
    named = _engine(small_model, "paged").host_mutable_buffers()
    assert {"engine._last_token", "pm.tables", "pm.lengths",
            "pm.allocator.ref"} <= set(named)
    for buf in named.values():
        assert isinstance(buf, np.ndarray)
    assert _engine(small_model, "dense").host_mutable_buffers().keys() == \
        {"engine._last_token"}


def test_findings_serialize():
    f = Finding(rule="R", target="t", message="m", detail={"n": 1})
    d = f.to_dict()
    assert d["rule"] == "R" and d["detail"] == {"n": 1}
    assert "error" in str(f)


# ---------------------------------------------------------------------------
# retention audit (NoWriteIntoHeldPage)
# ---------------------------------------------------------------------------

def test_retention_audit_clean_on_real_managers():
    """The full audit — fp absolute + ring + q8 managers AND the
    sabotaged positive control — must come back empty."""
    from repro.lint import retention
    findings = retention.audit_retention()
    assert findings == [], [str(f) for f in findings]


def test_retention_audit_flags_write_into_shared_page(small_model):
    """Strip detach-on-shared from ensure_appendable (the PR 5-era bug
    class, now also covering tree-retained pages) — the append seam must
    fire with the refcount evidence."""
    from repro.lint import retention
    from repro.serving.paged_kv_cache import PagedCacheManager
    cfg, _ = small_model
    pm = PagedCacheManager(cfg, n_slots=4, max_len=64, block_size=8,
                           n_blocks=24)

    def bad(self, slot):
        info = self._slots[slot]
        li = int(self.lengths[slot]) // self.bs
        if self.ring or li >= len(info.blocks):
            return PagedCacheManager.ensure_appendable(self, slot)
        return True  # append in place even when the page is held

    pm.ensure_appendable = types.MethodType(bad, pm)
    findings = retention.audit_manager(pm, "sabotaged")
    assert findings, "stripped detach-on-shared must fire the audit"
    assert all(f.rule == retention.RULE_RETENTION for f in findings)
    assert any(f.detail and f.detail.get("seam") == "ensure_appendable"
               and f.detail.get("ref", 0) > 1 for f in findings)


def test_retention_audit_flags_eviction_of_live_page(small_model):
    """Evict with the refcount guard stripped while a live slot is
    re-sharing the retained chain — the eviction seam must flag every
    victim a request still reads."""
    from repro.lint import retention
    from repro.serving.paged_kv_cache import PagedCacheManager
    cfg, _ = small_model
    pm = PagedCacheManager(cfg, n_slots=4, max_len=64, block_size=8,
                           n_blocks=10)
    findings = []
    with retention._armed(pm, findings, "sabotaged-evict"):
        prompt = (np.arange(27, dtype=np.int32) * 3 + 1) % cfg.vocab_size
        assert pm.admit(0, prompt) == 0
        pm.release(0)                      # chain retained by the tree
        assert pm.admit(1, prompt.copy()) == 4  # warm hit: retained+live
        # the sabotage: evict regardless of refcount (the manager's real
        # call sites always pass the ref==1 guard)
        assert pm.tree.evict(4, lambda p: True)
    assert any(f.detail and f.detail.get("seam") == "tree.evict"
               and f.detail.get("ref", 0) != 1 for f in findings), findings
