"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,B,Sq,Sk,Hq,Hkv,D,causal,win", [
    (jnp.float32, 2, 128, 128, 4, 2, 64, True, 0),
    (jnp.bfloat16, 2, 128, 128, 4, 2, 64, True, 0),
    (jnp.float32, 1, 256, 256, 8, 1, 32, True, 0),   # MQA
    (jnp.float32, 2, 128, 128, 4, 4, 64, True, 48),  # MHA + sliding window
    (jnp.bfloat16, 1, 128, 128, 2, 2, 16, False, 0),  # encoder
    (jnp.float32, 1, 64, 64, 6, 3, 128, True, 16),   # GQA + window, d=128
])
def test_flash_attention(B, Sq, Sk, Hq, Hkv, D, causal, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, sliding_window=win,
                              block_q=64, block_k=64, interpret=True)
    want = ref.ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        sliding_window=win).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(
    bq=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    win=st.sampled_from([0, 8, 40]),
    seed=st.integers(0, 100),
)
def test_flash_attention_block_invariance(bq, bk, win, seed):
    """Output must not depend on the BlockSpec tiling (property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, sliding_window=win,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.ref_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             sliding_window=win).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("dtype,B,S,Hq,Hkv,D,causal,win", [
    (jnp.float32, 2, 128, 4, 2, 64, True, 0),
    (jnp.bfloat16, 2, 128, 4, 2, 64, True, 0),
    (jnp.float32, 1, 256, 8, 1, 32, True, 0),   # MQA
    (jnp.float32, 2, 128, 4, 4, 64, True, 48),  # MHA + sliding window
    (jnp.float32, 1, 64, 6, 3, 128, True, 16),  # GQA + window, d=128
])
def test_flash_attention_merged(B, S, Hq, Hkv, D, causal, win, dtype):
    """Stream-as-query merged flash PREFILL kernel vs its oracle: the
    stream (B, S, d) is the query, K*/V* are read in native layout."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    u = jax.random.normal(ks[0], (B, S, Hq * D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention_merged(u, k, v, n_kv_heads=Hkv, causal=causal,
                                     sliding_window=win, block_q=64,
                                     block_k=64, interpret=True)
    want = ref.ref_flash_attention_merged(u, k, v, n_kv_heads=Hkv,
                                          causal=causal, sliding_window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_merged_matches_generic():
    """The merged kernel is the generic kernel in a different layout: on
    the bitcast head view the two must agree to float tolerance."""
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    u = jax.random.normal(ks[0], (B, S, Hq * D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    merged = ops.flash_attention_merged(u, k, v, n_kv_heads=Hkv,
                                        sliding_window=8, block_q=32,
                                        block_k=32, interpret=True)
    generic = ops.flash_attention(u.reshape(B, S, Hq, D), k, v,
                                  sliding_window=8, block_q=32, block_k=32,
                                  interpret=True).reshape(B, S, Hq * D)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(generic),
                               atol=3e-6)


@settings(max_examples=8, deadline=None)
@given(
    bq=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    win=st.sampled_from([0, 8, 40]),
    seed=st.integers(0, 100),
)
def test_flash_attention_merged_block_invariance(bq, bk, win, seed):
    """Output must not depend on the BlockSpec tiling (property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u = jax.random.normal(ks[0], (1, 64, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    out = ops.flash_attention_merged(u, k, v, n_kv_heads=2, causal=True,
                                     sliding_window=win, block_q=bq,
                                     block_k=bk, interpret=True)
    want = ref.ref_flash_attention_merged(u, k, v, n_kv_heads=2, causal=True,
                                          sliding_window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,B,S,Hq,Hkv,D,win,fill", [
    (jnp.float32, 2, 128, 4, 2, 64, 0, 64),
    (jnp.bfloat16, 2, 128, 4, 2, 64, 0, 64),
    (jnp.float32, 2, 64, 8, 8, 32, 24, 40),
    (jnp.bfloat16, 1, 128, 8, 1, 128, 0, 128),  # MQA, full cache
    (jnp.float32, 3, 96, 6, 2, 16, 8, 50),
])
def test_decode_attention(B, S, Hq, Hkv, D, win, fill, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    kv_pos = jnp.where(jnp.arange(S)[None, :] < fill,
                       jnp.arange(S, dtype=jnp.int32)[None, :], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, S))
    q_position = jnp.full((B,), fill - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, kv_positions=kv_pos,
                               q_position=q_position, sliding_window=win,
                               block_k=32, interpret=True)
    want = ref.ref_decode_attention(
        q.reshape(B, Hkv, Hq // Hkv, D), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), kv_pos, q_position[:, None],
        sliding_window=win).reshape(B, Hq, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_ring_layout():
    """Wrapped ring-buffer positions (not monotonically increasing)."""
    B, S, Hq, Hkv, D = 1, 16, 2, 1, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    # ring of window 16 at t=20: slot s holds position 20-16+((s-4)%16)
    t = 20
    kv_pos = jnp.asarray([[(t - 16) + ((s - (t % 16)) % 16) for s in range(S)]],
                         jnp.int32)
    qp = jnp.full((B,), t, jnp.int32)
    out = ops.decode_attention(q, kc, vc, kv_positions=kv_pos, q_position=qp,
                               sliding_window=16, block_k=8, interpret=True)
    want = ref.ref_decode_attention(q.reshape(B, Hkv, 2, D),
                                    kc.transpose(0, 2, 1, 3),
                                    vc.transpose(0, 2, 1, 3), kv_pos,
                                    qp[:, None], sliding_window=16
                                    ).reshape(B, Hq, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# merged (Q/P-removed) decode attention — stream-as-query, native cache layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,B,S,Hq,Hkv,D,win,fill", [
    (jnp.float32, 2, 128, 4, 2, 64, 0, 64),     # GQA
    (jnp.bfloat16, 2, 128, 4, 2, 64, 0, 64),
    (jnp.float32, 2, 64, 8, 8, 32, 24, 40),     # MHA + sliding window
    (jnp.bfloat16, 1, 128, 8, 1, 128, 0, 128),  # MQA, full cache
])
def test_decode_attention_merged(B, S, Hq, Hkv, D, win, fill, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    u = jax.random.normal(ks[0], (B, Hq * D), dtype)  # the residual stream
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    kv_pos = jnp.where(jnp.arange(S)[None, :] < fill,
                       jnp.arange(S, dtype=jnp.int32)[None, :], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, S))
    q_position = jnp.full((B,), fill - 1, jnp.int32)
    out = ops.decode_attention_merged(
        u, kc, vc, kv_positions=kv_pos, q_position=q_position,
        n_kv_heads=Hkv, sliding_window=win, block_k=32, interpret=True)
    want = ref.ref_decode_attention_merged(
        u, kc, vc, kv_pos, q_position[:, None], n_kv_heads=Hkv,
        sliding_window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_merged_matches_generic():
    """Same query/cache -> merged (native-layout) and generic kernels agree."""
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    u = jax.random.normal(ks[0], (B, Hq * D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    qp = jnp.full((B,), S - 1, jnp.int32)
    merged = ops.decode_attention_merged(
        u, kc, vc, kv_positions=kv_pos, q_position=qp, n_kv_heads=Hkv,
        block_k=16, interpret=True)
    generic = ops.decode_attention(
        u.reshape(B, Hq, D), kc, vc, kv_positions=kv_pos, q_position=qp,
        block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(merged),
                               np.asarray(generic.reshape(B, Hq * D)),
                               atol=3e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,B,S,H,P,N,chunk", [
    (jnp.float32, 2, 64, 4, 16, 8, 16),
    (jnp.bfloat16, 2, 64, 4, 16, 8, 16),
    (jnp.float32, 1, 128, 2, 32, 16, 32),
    (jnp.bfloat16, 1, 96, 3, 64, 128, 32),  # mamba2-2.7b head geometry
    (jnp.float32, 2, 32, 1, 16, 8, 32),     # single chunk
])
def test_ssd_scan(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, H, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, H, N), dtype)
    y, fin = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, fin_ref = ref.ref_ssd(x.astype(jnp.float32), dt, dt * A,
                                 Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                               atol=tol, rtol=tol)


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 50))
def test_ssd_chunk_invariance(chunk, seed):
    """Chunk size is a tiling choice — results must be identical."""
    B, S, H, P, N = 1, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y, fin = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, fin_ref = ref.ref_ssd(x, dt, dt * A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# model-level integration: impl="pallas_interpret" == impl="xla"
# ---------------------------------------------------------------------------

def test_model_pallas_path_matches_xla():
    from repro.configs import get_config, reduce_config
    from repro.models import forward_seq, init_params
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a, _, _ = forward_seq(params, cfg, toks, impl="xla")
    b, _, _ = forward_seq(params, cfg, toks, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_mamba_pallas_path_matches_xla():
    from repro.configs import get_config, reduce_config
    from repro.models import forward_seq, init_params
    cfg = reduce_config(get_config("mamba2-2.7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a, _, _ = forward_seq(params, cfg, toks, impl="xla")
    b, _, _ = forward_seq(params, cfg, toks, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
