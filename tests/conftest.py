import os
import sys

# ensure src/ is importable regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — tests must see 1 (host) device;
# only launch/dryrun.py sets the 512-device flag (in a subprocess when
# exercised from tests).


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "property: hypothesis property-based suites; the CI `property` job "
        "re-runs them with a raised example budget (PROPERTY_EXAMPLES), "
        "tier-1 keeps the fast default profile")
