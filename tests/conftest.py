import os
import sys

# ensure src/ is importable regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — tests must see 1 (host) device;
# only launch/dryrun.py sets the 512-device flag (in a subprocess when
# exercised from tests).
