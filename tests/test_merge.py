"""The paper's core claims: exact weight removal + the §3 table.

Property-based (hypothesis) over random skipless models: merging must be
numerically equivalent (Fig 1b/c/d per Table 1), remove exactly the
predicted number of weights, and keep Q invertible (cond audit, §4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.core import (condition_numbers, decode_speedup, merge_skipless,
                        weight_table)
from repro.models import count_params, forward_seq, init_params

jax.config.update("jax_platform_name", "cpu")


def _mk(cfg, seed=0, scale=50.0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    # O(1) streams so logit comparisons are well-conditioned (skipless GLU
    # attenuates small signals quadratically)
    params["embed"]["table"] = params["embed"]["table"] * scale
    return params


def _inputs(cfg, key, B=2, S=12):
    if cfg.family == "audio":
        x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        x = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    vision = None
    if cfg.family == "vlm":
        vision = jax.random.normal(jax.random.fold_in(key, 7),
                                   (B, cfg.n_vision_tokens, cfg.d_model))
    return x, vision


def _assert_equiv(cfg, variant, seed=0):
    params = _mk(cfg, seed)
    x, vision = _inputs(cfg, jax.random.PRNGKey(seed + 1))
    base, _, _ = forward_seq(params, cfg, x, vision=vision)
    mparams, mcfg = merge_skipless(params, cfg, variant)
    merged, _, _ = forward_seq(mparams, mcfg, x, vision=vision)
    denom = float(np.max(np.abs(np.asarray(base)))) + 1e-9
    rel = float(np.max(np.abs(np.asarray(base) - np.asarray(merged)))) / denom
    assert rel < 3e-4, (cfg.name, variant, rel)
    return params, mparams


# ---- per assigned arch ----------------------------------------------------

@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).qp_removal_applicable])
def test_qp_merge_equivalence(arch):
    cfg = reduce_config(get_config(arch)).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))  # no drops
    params, mparams = _assert_equiv(cfg, "qp")
    # removed weights: Q (d*ad) + P (ad*d) per layer for non-hybrid serial
    d, ad, L = cfg.d_model, cfg.attn_dim, cfg.n_layers
    removed = count_params(params) - count_params(mparams)
    if cfg.family == "hybrid":
        expect = L * d * ad  # Q only
    elif cfg.family == "audio":
        expect = L * (d * ad + ad * d) - d * d  # input_proj retained
    else:
        expect = L * (d * ad + ad * d)
    if cfg.qkv_bias:
        # bq (L·ad) removed, but b_out (L·d) and embed_bias (d) are added
        expect += L * ad - L * d - d
    if cfg.tie_embeddings:
        expect -= cfg.padded_vocab * d  # merge unties the embeddings
    assert removed == expect, (arch, removed, expect)


@pytest.mark.parametrize("variant", ["kp", "vp"])
@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "hubert-xlarge"])
def test_kp_vp_merge_mha_only(arch, variant):
    cfg = reduce_config(get_config(arch)).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    assert cfg.kp_vp_removal_applicable
    _assert_equiv(cfg, variant)


def test_kp_variant_rejected_for_gqa():
    cfg = reduce_config(get_config("llama3.2-1b")).with_(block_style="skipless")
    with pytest.raises(ValueError):
        merge_skipless(init_params(jax.random.PRNGKey(0), cfg), cfg, "kp")


def test_mamba2_inapplicable():
    cfg = get_config("mamba2-2.7b")
    assert not cfg.qp_removal_applicable
    with pytest.raises(ValueError):
        cfg.with_(block_style="skipless_merged").validate_style()


# ---- property-based: random dense skipless models -------------------------

@settings(max_examples=10, deadline=None)
@given(
    n_layers=st.integers(1, 3),
    n_heads=st.sampled_from([2, 4]),
    kv_ratio=st.sampled_from([1, 2]),
    bias=st.booleans(),
    ffn_type=st.sampled_from(["swiglu", "gelu_mlp"]),
    seed=st.integers(0, 2**16),
)
def test_merge_property(n_layers, n_heads, kv_ratio, bias, ffn_type, seed):
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(
        name="prop", family="dense", n_layers=n_layers, d_model=n_heads * 8,
        n_heads=n_heads, n_kv_heads=n_heads // kv_ratio, d_head=8,
        d_ff=24, vocab_size=64, qkv_bias=bias, ffn_type=ffn_type,
        block_style="skipless", dtype="float32", param_dtype="float32")
    _assert_equiv(cfg, "qp", seed=seed % 97)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_merge_invertibility_audit(seed):
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = _mk(cfg, seed % 31)
    conds = condition_numbers(params, cfg, "qp")
    assert len(conds) == cfg.n_layers
    assert np.all(np.isfinite(conds)), "paper §4: all Q must be invertible"


# ---- paper §3 table (the reproduction gate) --------------------------------

@pytest.mark.parametrize("arch,exp", [
    ("pythia-6.9b", dict(qp=33_554_432, kv=33_554_432, ffn=134_217_728,
                         embed=412_876_800, total_b=6.9, wo_b=5.8,
                         savings=16, speedup=1.19)),
    ("mistral-7b", dict(qp=33_554_432, kv=8_388_608, ffn=176_160_768,
                        embed=262_144_000, total_b=7.2, wo_b=6.2,
                        savings=15, speedup=1.17)),
])
def test_paper_table(arch, exp):
    t = weight_table(get_config(arch))
    assert t["qp_per_layer"] == exp["qp"]
    assert t["kv_per_layer"] == exp["kv"]
    assert t["ffn_per_layer"] == exp["ffn"]
    assert t["embed"] == exp["embed"]
    assert round(t["total"] / 1e9, 1) == exp["total_b"]
    assert round(t["total_without_qp"] / 1e9, 1) == exp["wo_b"]
    assert round(t["savings_frac"] * 100) == exp["savings"]
    assert round(t["speedup"], 2) == exp["speedup"]
    assert abs(decode_speedup(get_config(arch)) - t["speedup"]) < 1e-9
