"""Model-based property suite for the continuous-batching scheduler.

Two layers, same shape as ``test_paged_properties``:

PLANNER — hypothesis drives random job mixes (chunked + monolithic,
arbitrary totals) through iterated ``plan_iteration`` calls with
arbitrary per-iteration decode loads, checking after EVERY iteration:

  * the token budget is never exceeded: whenever any chunk is planned,
    ``budget_used <= token_budget`` (pure decode load may exceed it —
    active slots are already admitted and cannot be un-budgeted);
  * strict FCFS: the planned chunks are exactly a PREFIX of the
    unfinished job queue — never a skip-ahead (that starves the head);
  * one chunk per job per iteration, starting AT the job's cursor,
    advancing it by at most ``chunk_tokens`` (monolithic: to the total,
    charged ``min(total, budget)`` so it can EVER fit);
  * cursors are monotone non-decreasing and never overshoot the total;
  * no starvation: with zero decode load and work outstanding, the head
    job is always scheduled — so a drain loop terminates in exactly the
    chunk-arithmetic number of iterations.

MANAGER — random admit-chunked / chunk / finish / decode-step / release
traces against a real ``PagedCacheManager`` with a tiny pool (windowed
ring mode included), checking page CONSERVATION after every op: free +
used == total, no double-free, live pages never on the free list,
refcounts == live holders, ``chunk_block_ids`` never routes a chunk
write at a freed page, shields only on live slots — and a drained pool
holds zero used pages and an empty prefix registry.

Marked ``property``: CI's property job raises ``PROPERTY_EXAMPLES``;
tier-1 runs the fast default and skips cleanly without hypothesis
(tests/_hypothesis_stub.py).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config, reduce_config
from repro.serving.engine import Request
from repro.serving.paged_kv_cache import (PagedCacheManager,
                                          PagedQ8CacheManager)
from repro.serving.sched import PrefillJob, SchedConfig, plan_iteration
# scale-lockstep model shared with the decode-path property suite
from test_paged_properties import (_absorb_page_delta, _check_scales,
                                   _live_pages)

pytestmark = pytest.mark.property

MAX_EXAMPLES = int(os.environ.get("PROPERTY_EXAMPLES", "25"))

MAX_LEN = 64
BLOCK = 8
N_BLOCKS = 10
N_SLOTS = 4


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def _mk_jobs(spec):
    jobs = []
    for i, (total, monolithic) in enumerate(spec):
        r = Request(prompt=np.zeros((total,), np.int32), max_new_tokens=2)
        j = PrefillJob(req=r, toks=np.zeros((total,), np.int32),
                       monolithic=monolithic)
        j.slot = i
        jobs.append(j)
    return jobs


def _check_schedule(scfg, n_decode, jobs, s):
    unfinished = [j for j in jobs if not j.done]
    planned = [c.job for c in s.chunks]
    assert planned == unfinished[:len(planned)], \
        "chunks must be an FCFS PREFIX of the unfinished queue"
    assert len(set(map(id, planned))) == len(planned), \
        "at most one chunk per job per iteration"
    assert s.budget == scfg.token_budget and s.n_decode == n_decode
    cost = n_decode
    for c in s.chunks:
        assert c.start == c.job.cursor
        if c.job.monolithic:
            assert c.end == c.job.total
            assert c.cost == min(c.job.total, scfg.token_budget)
        else:
            assert c.end == min(c.start + scfg.chunk_tokens, c.job.total)
            assert c.cost == scfg.chunk_tokens
        assert c.final == (c.end >= c.job.total)
        cost += c.cost
    assert s.budget_used == cost
    if s.chunks:
        assert s.budget_used <= scfg.token_budget, \
            "token budget exceeded by planned chunks"
    if n_decode == 0 and unfinished:
        assert s.chunks, "no starvation: an idle iteration must " \
                         "schedule the queue head"


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(spec=st.lists(st.tuples(st.integers(min_value=1, max_value=40),
                               st.booleans()),
                     min_size=1, max_size=6),
       chunk=st.sampled_from([4, 8]),
       budget_mult=st.integers(min_value=1, max_value=4),
       decode_loads=st.lists(st.integers(min_value=0, max_value=40),
                             min_size=0, max_size=12))
def test_planner_iterated_invariants(spec, chunk, budget_mult,
                                     decode_loads):
    scfg = SchedConfig(token_budget=budget_mult * chunk,
                       chunk_tokens=chunk)
    jobs = _mk_jobs(spec)
    cursors = {id(j): 0 for j in jobs}

    def run_iteration(n_decode):
        s = plan_iteration(scfg, n_decode, jobs)
        _check_schedule(scfg, n_decode, jobs, s)
        for c in s.chunks:  # "execute": cursor advances to the chunk end
            c.job.cursor = c.end
            assert c.job.cursor >= cursors[id(c.job)], "cursor regressed"
            assert c.job.cursor <= c.job.total, "cursor overshot"
            cursors[id(c.job)] = c.job.cursor
        return s

    for n_decode in decode_loads:  # arbitrary interleaved decode load
        run_iteration(n_decode)

    # drain at zero decode load: termination is pure chunk arithmetic
    expected = sum(
        (1 if j.monolithic else -(-(j.total - j.cursor) // chunk))
        for j in jobs if not j.done)
    n_iters = 0
    while any(not j.done for j in jobs):
        s = run_iteration(0)
        assert s.chunks
        n_iters += 1
        assert n_iters <= expected, "drain exceeded the chunk-count bound"
    assert all(j.cursor == j.total for j in jobs)


def test_planner_runs_without_hypothesis():
    """Tier-1 sanity: one fixed mix exercises the checker even when
    hypothesis is stubbed."""
    scfg = SchedConfig(token_budget=16, chunk_tokens=8)
    jobs = _mk_jobs([(20, False), (40, True), (3, False)])
    for n_decode in (0, 3, 17, 0, 0, 0, 0):
        s = plan_iteration(scfg, n_decode, jobs)
        _check_schedule(scfg, n_decode, jobs, s)
        for c in s.chunks:
            c.job.cursor = c.end
    assert all(j.done for j in jobs)


# ---------------------------------------------------------------------------
# manager: chunked-lifecycle page conservation
# ---------------------------------------------------------------------------

def _conservation(pm: PagedCacheManager) -> None:
    alloc = pm.allocator
    free = list(alloc._free)
    assert len(set(free)) == len(free), "double-free: duplicate free pages"
    assert alloc.n_free + alloc.n_used == alloc.n_blocks
    holders = np.zeros((alloc.n_blocks,), np.int64)
    mapped: set = set()
    for slot, info in pm._slots.items():
        live = [p for p in info.blocks if p >= 0]
        assert len(set(live)) == len(live), "slot maps a page twice"
        assert not set(live) & set(free), "live page on the free list"
        holders[live] += 1
        mapped |= set(live)
    retained = set(pm.tree.retained)
    assert not retained & set(free), "retained page on the free list"
    for p in retained:
        holders[p] += 1
    np.testing.assert_array_equal(
        alloc.ref, holders,
        err_msg="refcounts must equal live holders + tree retention")
    # conservation: slot-mapped + tree-retained + free == pool
    assert mapped | retained | set(free) == set(range(alloc.n_blocks))
    assert pm.shielded <= set(pm._slots), "shield on a dead slot"


def _chunk_trace_strategy():
    # (op, slot selector, length selector); chunk over-weighted so
    # prefills actually complete and decode/release get live slots
    return st.lists(
        st.tuples(
            st.sampled_from(["admit", "chunk", "chunk", "chunk", "step",
                             "step", "release"]),
            st.integers(min_value=0, max_value=N_SLOTS - 1),
            st.integers(min_value=1, max_value=40),
        ),
        min_size=1, max_size=60)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(window=st.sampled_from([0, 5, 16]), trace=_chunk_trace_strategy())
def test_chunked_lifecycle_conserves_pages(window, trace):
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        sliding_window=window)
    pm = PagedCacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                           block_size=BLOCK, n_blocks=N_BLOCKS)
    # chunk width: the scheduler pins ring mode to one block per chunk
    C = BLOCK
    state = {}  # slot -> {"toks", "frontier", "active"}

    for op, sel, n in trace:
        if op == "admit":
            slot = next((s for s in range(N_SLOTS) if s not in state), None)
            if slot is None:
                continue
            toks = (np.arange(n, dtype=np.int32) * (sel % 3 + 1)) % 97
            got = pm.admit_chunked(slot, toks)
            if got is not None:
                assert slot in pm.shielded, "mid-prefill slot unshielded"
                state[slot] = {"toks": toks, "frontier": 0,
                               "active": False}
        elif op == "chunk":
            pre = [s for s, v in state.items() if not v["active"]]
            if not pre:
                continue
            slot = pre[sel % len(pre)]
            v = state[slot]
            start = v["frontier"]
            end = min(start + C, len(v["toks"]))
            if not pm.ensure_chunk(slot, start, end):
                pm.release(slot)  # self-preempt: give pages back
                del state[slot]
                _conservation(pm)
                continue
            ids = pm.chunk_block_ids(slot, start, end, len(v["toks"]))
            live = {p for p in ids if p >= 0}
            assert not live & set(pm.allocator._free), \
                "chunk write routed at a freed page"
            pm.set_frontier(slot, end)
            v["frontier"] = end
            assert int(pm.lengths[slot]) == end
            if end >= len(v["toks"]):
                pm.finish_chunked(slot, v["toks"])
                pm.unshield(slot)  # scheduler: at decode activation
                v["active"] = True
        elif op == "step":
            act = [s for s, v in state.items()
                   if v["active"] and int(pm.lengths[s]) < MAX_LEN]
            if not act:
                continue
            slot = act[sel % len(act)]
            if pm.ensure_appendable(slot):
                pm.advance(slot)
            else:
                pm.release(slot)  # preempt on pool exhaustion
                del state[slot]
        elif op == "release" and state:
            keys = sorted(state)
            slot = keys[sel % len(keys)]
            pm.release(slot)
            del state[slot]
        _conservation(pm)

    for slot in sorted(state):
        pm.release(slot)
        _conservation(pm)
    assert pm.allocator.n_used == len(pm.tree.retained), \
        "drained pool may hold only tree-retained prefix pages"
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0, "drained pool leaks pages"
    assert pm.tree.n_pages == 0, \
        "registry entries must die with their pages"
    assert not pm.shielded


# ---------------------------------------------------------------------------
# manager: paged_q8 — scale rows conserved through the chunked lifecycle
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(window=st.sampled_from([0, 5, 16]), trace=_chunk_trace_strategy())
def test_chunked_q8_scales_conserved(window, trace):
    """Page conservation AND scale lockstep for the quantized manager
    under the chunked lifecycle: every page a slot maps must carry the
    scale marker its write (or its CoW source, via copy_block_q8)
    stamped — through ensure_chunk maps, prefix-shared admits, decode
    CoW/ring-recycle, self-preemption and release.  The q8 manager
    inherits the whole host lifecycle, so _conservation applies as-is."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(
        sliding_window=window)
    pm = PagedQ8CacheManager(cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                             block_size=BLOCK, n_blocks=N_BLOCKS)
    C = BLOCK
    state = {}
    expected = {}
    marker = [1.0]

    def all_mapped():
        return {p for s in pm._slots for p in _live_pages(pm, s)}

    def absorb(before, cow0, rec0):
        marker[0] = _absorb_page_delta(pm, expected, before, all_mapped(),
                                       pm.allocator.n_cow - cow0,
                                       marker[0],
                                       pm.allocator.n_recycled - rec0)
        _conservation(pm)
        _check_scales(pm, expected)

    for op, sel, n in trace:
        before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
        if op == "admit":
            slot = next((s for s in range(N_SLOTS) if s not in state), None)
            if slot is None:
                continue
            toks = (np.arange(n, dtype=np.int32) * (sel % 3 + 1)) % 97
            if pm.admit_chunked(slot, toks) is not None:
                state[slot] = {"toks": toks, "frontier": 0,
                               "active": False}
        elif op == "chunk":
            pre = [s for s, v in state.items() if not v["active"]]
            if not pre:
                continue
            slot = pre[sel % len(pre)]
            v = state[slot]
            start = v["frontier"]
            end = min(start + C, len(v["toks"]))
            if not pm.ensure_chunk(slot, start, end):
                pm.release(slot)
                del state[slot]
                absorb(before, cow0, rec0)
                continue
            pm.chunk_block_ids(slot, start, end, len(v["toks"]))
            pm.set_frontier(slot, end)
            v["frontier"] = end
            if end >= len(v["toks"]):
                pm.finish_chunked(slot, v["toks"])
                pm.unshield(slot)
                v["active"] = True
        elif op == "step":
            act = [s for s, v in state.items()
                   if v["active"] and int(pm.lengths[s]) < MAX_LEN]
            if not act:
                continue
            slot = act[sel % len(act)]
            if pm.ensure_appendable(slot):
                pm.advance(slot)
            else:
                pm.release(slot)
                del state[slot]
        elif op == "release" and state:
            keys = sorted(state)
            slot = keys[sel % len(keys)]
            pm.release(slot)
            del state[slot]
        absorb(before, cow0, rec0)

    for slot in sorted(state):
        pm.release(slot)
        _conservation(pm)
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0
    assert pm.tree.n_pages == 0 and not pm.shielded


def test_chunked_q8_runs_without_hypothesis():
    """Tier-1 sanity: one fixed q8 chunked lifecycle (admit → chunks →
    finish → windowed decode over recycled pages → release) exercises
    the scale-lockstep checker even when hypothesis is stubbed."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(sliding_window=16)
    pm = PagedQ8CacheManager(cfg, n_slots=2, max_len=MAX_LEN,
                             block_size=BLOCK, n_blocks=N_BLOCKS)
    expected = {}
    marker = [1.0]

    def all_mapped():
        return {p for s in pm._slots for p in _live_pages(pm, s)}

    def absorb(before, cow0, rec0):
        marker[0] = _absorb_page_delta(pm, expected, before, all_mapped(),
                                       pm.allocator.n_cow - cow0,
                                       marker[0],
                                       pm.allocator.n_recycled - rec0)
        _conservation(pm)
        _check_scales(pm, expected)

    toks = np.arange(20, dtype=np.int32)
    assert pm.admit_chunked(0, toks) is not None
    f = 0
    while f < len(toks):
        before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
        end = min(f + BLOCK, len(toks))
        assert pm.ensure_chunk(0, f, end)
        pm.chunk_block_ids(0, f, end, len(toks))
        pm.set_frontier(0, end)
        f = end
        absorb(before, cow0, rec0)
    pm.finish_chunked(0, toks)
    pm.unshield(0)
    for _ in range(24):
        before, cow0, rec0 = (all_mapped(), pm.allocator.n_cow,
                              pm.allocator.n_recycled)
        if pm.ensure_appendable(0):
            pm.advance(0)
        absorb(before, cow0, rec0)
    assert pm.allocator.n_recycled > 0, "windowed decode must recycle"
    pm.release(0)
    _conservation(pm)
    assert pm.allocator.n_used == 0


def test_chunked_lifecycle_runs_without_hypothesis():
    """Tier-1 sanity: a fixed chunked lifecycle (admit → chunks → finish
    → steps → release) covers the conservation checker without
    hypothesis, windowed and unwindowed."""
    for window in (0, 16):
        cfg = reduce_config(get_config("llama3.2-1b")).with_(
            sliding_window=window)
        pm = PagedCacheManager(cfg, n_slots=2, max_len=MAX_LEN,
                               block_size=BLOCK, n_blocks=N_BLOCKS)
        toks = np.arange(20, dtype=np.int32)
        assert pm.admit_chunked(0, toks) is not None
        _conservation(pm)
        f = 0
        while f < len(toks):
            end = min(f + BLOCK, len(toks))
            assert pm.ensure_chunk(0, f, end)
            pm.chunk_block_ids(0, f, end, len(toks))
            pm.set_frontier(0, end)
            f = end
            _conservation(pm)
        pm.finish_chunked(0, toks)
        pm.unshield(0)
        for _ in range(24):
            if pm.ensure_appendable(0):
                pm.advance(0)
            _conservation(pm)
        pm.release(0)
        _conservation(pm)
        pm.drop_prefix_cache()
        assert pm.allocator.n_used == 0
