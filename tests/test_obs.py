"""Observability subsystem tests: metrics math, trace invariants, the
Perfetto export, engine integration, and the off-by-default contract.

The trace-invariant block is the load-bearing part: every submitted
request must reach exactly ONE terminal ("finish") event — through
preempt/resume cycles included — spans on a track must be non-overlapping
and time-monotonic, and the bounded ring must drop OLDEST-first without
ever corrupting an open span.  A hypothesis test drives the observer
hooks with the same request-trace generator shape as
``test_paged_properties`` (admit / step×4 / release over a tiny slot
set), and an engine integration test replays ``test_paged``'s
pool-starved preempt/resume recipe with obs on.
"""
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip
    from _hypothesis_stub import given, settings, st

from repro import obs as O

MAX_EXAMPLES = int(os.environ.get("PROPERTY_EXAMPLES", "25"))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    m = O.MetricsRegistry()
    c = m.counter("c", "help text")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert m.counter("c") is c, "get-or-create returns the live object"
    g = m.gauge("g")
    g.set(5)
    g.set(2)
    assert g.value == 2 and g.high_water == 5
    g.set_max(1)
    assert g.value == 2, "set_max is a ratchet, never lowers"
    g.set_max(9)
    assert g.value == 9 and g.high_water == 9


def test_lazy_gauge_reads_at_collect_time():
    m = O.MetricsRegistry()
    box = {"v": 1}
    m.gauge_fn("lazy", lambda: box["v"])
    assert m["lazy"].collect()["value"] == 1
    box["v"] = 7
    assert m["lazy"].collect()["value"] == 7, "evaluated at collect, not set"


def test_histogram_percentiles_and_exclusion():
    m = O.MetricsRegistry()
    h = m.histogram("h", lo=1e-3, hi=1e3)
    values = [0.002, 0.01, 0.05, 0.05, 0.2, 1.0, 5.0, 40.0]
    for v in values:
        h.observe(v)
    h.observe(None)
    h.observe(float("nan"))
    col = h.collect()
    assert col["count"] == len(values) and col["n_excluded"] == 2
    assert col["min"] == 0.002 and col["max"] == 40.0
    assert abs(col["sum"] - sum(values)) < 1e-12
    # quantiles are order-respecting and clamped to the observed range
    p50, p90, p99 = h.percentile(0.5), h.percentile(0.9), h.percentile(0.99)
    assert 0.002 <= p50 <= p90 <= p99 <= 40.0
    assert p50 <= col["mean"] * 5  # same order of magnitude, log buckets
    # empty histogram: everything None, never a crash or a zero
    h2 = O.Histogram("empty")
    assert h2.percentile(0.5) is None and h2.mean is None
    assert h2.collect()["min"] is None


def test_histogram_bucket_edges_are_exclusive_lower_inclusive_upper():
    h = O.Histogram("h", lo=1.0, hi=100.0, per_decade=1)
    # edges are [10, 100]; 10.0 must land in bucket 0 (le=10), 10.1 in 1
    h.observe(10.0)
    h.observe(10.1)
    assert h.buckets[0] == 1 and h.buckets[1] == 1
    h.observe(0.5)  # under lo -> underflow, still counted
    assert h.underflow == 1 and h.count == 3


def test_prometheus_text_format():
    m = O.MetricsRegistry()
    m.counter("reqs", "requests").inc(3)
    m.gauge("depth").set(2)
    h = m.histogram("lat", lo=0.1, hi=10.0, per_decade=1)
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = m.to_prometheus()
    assert "# TYPE reqs counter" in text and "reqs_total 3" in text
    assert "depth 2" in text
    # cumulative le buckets: each line's count >= the previous
    lines = [ln for ln in text.splitlines() if ln.startswith("lat_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts) and counts[-1] == 3
    assert 'le="+Inf"' in lines[-1]
    assert "lat_sum" in text and "lat_count 3" in text


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------

def test_trace_ring_drops_oldest_without_corrupting_open_spans():
    tr = O.TraceBuffer(capacity=4)
    rt = O.request_track(0)
    tr.begin(rt, "decode", t=0.0)  # open span: lives OUTSIDE the ring
    for i in range(10):
        tr.instant(O.engine_track(), f"i{i}", t=1.0 + i)
    assert len(tr) == 4 and tr.n_dropped == 6
    # oldest dropped first: the survivors are the LAST four instants
    assert [e["name"] for e in tr.events()] == ["i6", "i7", "i8", "i9"]
    assert tr.open_spans() == [(rt, "decode")], "drop must not touch opens"
    doc = tr.to_perfetto()
    O.validate_perfetto(doc)
    assert doc["otherData"]["n_dropped"] == 6
    tr.end(rt, "decode", t=20.0)  # still closable after heavy churn
    assert tr.open_spans() == []
    assert tr.events()[-1]["name"] == "decode"
    O.validate_perfetto(tr.to_perfetto())


def test_trace_end_without_begin_is_noop():
    tr = O.TraceBuffer()
    tr.end(O.request_track(1), "never-begun", t=1.0)
    assert len(tr) == 0 and tr.open_spans() == []


def test_trace_nested_spans_close_innermost_first():
    tr = O.TraceBuffer()
    et = O.engine_track()
    tr.begin(et, "outer", t=0.0)
    tr.begin(et, "inner", t=1.0)
    tr.end(et, "inner", t=2.0)
    tr.end(et, "outer", t=3.0)
    evs = tr.events()
    assert [(e["name"], e["t0"], e["dur"]) for e in evs] == [
        ("inner", 1.0, 1.0), ("outer", 0.0, 3.0)]
    O.validate_perfetto(tr.to_perfetto())


def test_perfetto_export_structure():
    tr = O.TraceBuffer()
    tr.complete(O.slot_track(2), "prefill", 0.0, 0.5, rid=7)
    tr.instant(O.request_track(7), "finish", t=0.5)
    tr.counter(O.engine_track(), "pool", 3, t=0.6)
    tr.begin(O.request_track(8), "decode", t=0.7)  # stays open
    doc = tr.to_perfetto()
    counts = O.validate_perfetto(doc)
    assert counts["X"] == 1 and counts["i"] == 1 and counts["C"] == 1
    assert counts["B"] == 1  # the open span exports as unfinished B
    # one lane per family: slots pid != requests pid != engine pid
    pids = {ev["pid"] for ev in doc["traceEvents"] if ev["ph"] != "M"}
    assert len(pids) == 3
    # timestamps are non-negative microseconds from the earliest event
    ts = [ev["ts"] for ev in doc["traceEvents"] if ev["ph"] != "M"]
    assert min(ts) == 0.0 and max(ts) == pytest.approx(0.7e6)


# ---------------------------------------------------------------------------
# observer trace invariants (hypothesis — the test_paged_properties
# request-trace generator shape: admit / step x4 / release)
# ---------------------------------------------------------------------------

N_SLOTS = 4


def _trace_strategy():
    return st.lists(
        st.tuples(
            st.sampled_from(["admit", "step", "step", "step", "step",
                             "release"]),
            st.integers(min_value=0, max_value=7),
            st.integers(min_value=1, max_value=40),
        ),
        min_size=1, max_size=60)


class _FakeClockObserver(O.Observer):
    """Observer with a deterministic strictly-increasing clock, so span
    monotonicity is checkable exactly."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self._t = 0.0

    def clock(self):
        self._t += 0.25
        return self._t


class _Req:
    def __init__(self, rid, t_arrival):
        self.rid = rid
        self.prompt = np.zeros((4,), np.int32)
        self.out_tokens = []
        self.t_arrival = t_arrival
        self.t_first = None
        self.t_last = None


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(trace=_trace_strategy())
def test_observer_request_lifecycle_invariants(trace):
    obs = _FakeClockObserver(trace_capacity=4096)  # big enough: no drops
    free = list(range(N_SLOTS))
    active, preempted, finished = {}, [], set()
    rids = []

    def finish(slot):
        req = active.pop(slot)
        free.append(slot)
        req.t_last = obs.clock()
        obs.request_finished(req, decode_tok_s=None, ttft_s=0.1)
        finished.add(req.rid)

    for op, sel, n in trace:
        if op == "admit" and free:
            resume = bool(preempted) and sel % 2 == 0
            if resume:
                req = preempted.pop(0)
            else:
                req = _Req(len(rids), obs.clock())
                rids.append(req.rid)
            slot = free.pop(0)
            t_p0 = obs.clock()
            if not resume:
                req.t_first = req.t_last = obs.clock()
                req.out_tokens = [1]
            active[slot] = req
            obs.request_admitted(req, slot, n_shared=0, resume=resume,
                                 bucket_len=8, t_prefill0=t_p0)
        elif op == "step" and active:
            t0 = obs.clock()
            for req in active.values():
                req.out_tokens.append(1)
                req.t_last = obs.clock()
            obs.step_done(t0, obs.clock(), n_active=len(active),
                          n_tokens=len(active))
        elif op == "release" and active:
            slot = sorted(active)[sel % len(active)]
            if n % 3 == 0:  # preempt instead of finishing
                req = active.pop(slot)
                free.append(slot)
                obs.request_preempted(req, slot)
                preempted.append(req)
            else:
                finish(slot)
    # drain: resume-then-finish everything still live, as generate() does
    for slot in sorted(active):
        finish(slot)
    while preempted:
        req = preempted.pop(0)
        slot = free.pop(0)
        obs.request_admitted(req, slot, n_shared=0, resume=True,
                             bucket_len=8, t_prefill0=obs.clock())
        active[slot] = req
        finish(slot)

    assert obs.trace.n_dropped == 0  # invariants below need every event
    events = obs.trace.events()
    # 1. exactly one terminal event per finished request, zero for others
    for rid in rids:
        n_fin = sum(1 for e in events
                    if e["track"] == O.request_track(rid)
                    and e["name"] == "finish")
        assert n_fin == (1 if rid in finished else 0), (rid, n_fin)
    assert finished == set(rids)  # the drain finishes everyone
    # 2. every timestamp sits inside the run's clock envelope, and the
    # terminal instant is the LAST event ever recorded on its track
    t_final = obs.clock()
    for e in events:
        assert 0.0 < e["t0"] <= e["t0"] + e.get("dur", 0.0) <= t_final, e
    for rid in rids:
        on_track = [e for e in events
                    if e["track"] == O.request_track(rid)]
        assert on_track[-1]["name"] == "finish", rid
    # 3. spans on ANY track are non-overlapping and monotonic: a request
    # (or slot, or the engine loop) is in exactly one state at a time
    by_track = {}
    for e in events:
        if e["ph"] == "X":
            by_track.setdefault(e["track"], []).append(e)
    for track, spans in by_track.items():
        end = -math.inf
        for e in sorted(spans, key=lambda e: e["t0"]):
            assert e["t0"] >= end, (track, e)
            end = e["t0"] + e["dur"]
    # 4. no span left open, and the export is structurally valid
    assert obs.trace.open_spans() == []
    if events:  # all-no-op traces export an empty document
        counts = O.validate_perfetto(obs.trace.to_perfetto())
        assert counts.get("B", 0) == 0
    # 5. metrics agree with the model
    m = obs.metrics
    assert m["serve_requests_finished"].value == len(finished)
    assert m["serve_ttft_seconds"].count == len(rids)


# ---------------------------------------------------------------------------
# engine integration (slow-ish: real models) + off-mode contract
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_config, reduce_config
    from repro.models import init_params
    cfg = reduce_config(get_config("llama3.2-1b"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_engine_obs_off_by_default(small_model):
    from repro.serving import Engine, ServeConfig
    cfg, params = small_model
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=32))
    assert eng.obs is O.NULL and not eng.obs.enabled
    outs = eng.generate([np.arange(6), np.arange(4)], max_new_tokens=3)
    # stats read through the always-on registry even with obs off
    assert eng.stats == {"peak_active": 2, "n_preempted": 0, "n_deferred": 0}
    assert eng.metrics["serve_peak_active"].high_water == 2
    assert all(o.decode_tok_s is None or o.decode_tok_s > 0 for o in outs)
    snap = O.snapshot(eng)
    assert snap["engine"]["obs_enabled"] is False


def test_engine_obs_preempt_resume_single_terminal(small_model):
    """test_paged's pool-starved recipe, instrumented: preemptions fire,
    every request still reaches exactly one terminal span, and the
    Perfetto export stays structurally valid."""
    from repro.serving import Engine, PagedCacheAdapter, ServeConfig
    cfg, params = small_model
    eng = Engine(cfg, params,
                 ServeConfig(n_slots=3, max_len=64, obs=True),
                 cache=PagedCacheAdapter(block_size=8, n_blocks=7))
    prompts = [np.arange(8) + i for i in range(3)]
    outs = eng.generate(prompts, max_new_tokens=20)
    assert eng.stats["n_preempted"] > 0, "workload sized to force preemption"
    assert all(len(o) == 20 for o in outs)

    tr = eng.obs.trace
    for rid in range(3):
        evs = O.request_events(tr, rid)
        assert sum(e["name"] == "finish" for e in evs) == 1, (rid, evs)
        # preempt instants pair with later resumes: the request's decode
        # spans never overlap
        spans = sorted((e for e in evs if e["ph"] == "X"),
                       key=lambda e: e["t0"])
        end = -math.inf
        for e in spans:
            assert e["t0"] >= end - 1e-9, (rid, e)
            end = e["t0"] + e["dur"]
    n_preempts = sum(1 for e in tr.events() if e["name"] == "preempt")
    assert n_preempts == eng.stats["n_preempted"]
    assert tr.open_spans() == []
    O.validate_perfetto(tr.to_perfetto())

    m = eng.metrics
    assert m["serve_requests_finished"].value == 3
    assert m["serve_requests_resumed"].value > 0
    assert m["serve_decode_step_seconds"].count > 0
    # pool telemetry is lifted as lazy gauges
    assert m["pool_peak_used"].collect()["value"] == \
        eng.pm.allocator.peak_used
    doc = O.serving_obs_doc(eng)
    assert doc["headline"]["preempted"] == eng.stats["n_preempted"]
    assert doc["headline"]["ttft_p99_ms"] > 0


def test_single_token_request_tok_s_is_excluded_not_zero(small_model):
    """A request generating exactly one token has no steady-state decode
    rate: decode_tok_s must be None (not 0.0) and histogram-excluded."""
    from repro.serving import Engine, ServeConfig
    cfg, params = small_model
    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=32, obs=True))
    outs = eng.generate([np.arange(6)], max_new_tokens=1)
    assert outs[0].decode_tok_s is None
    assert outs[0].stats["decode_tok_s"] is None
    h = eng.metrics["serve_decode_tok_s"]
    assert h.count == 0 and h.n_excluded == 1
    assert h.mean is None, "no zero pollution of the aggregate"


def test_engine_source_uses_monotonic_clock_only():
    """Durations must come from time.perf_counter (monotonic) — a
    wall-clock time.time() skews TTFT/tok_s under NTP steps.  Pin the
    engine source."""
    import inspect
    from repro.serving import engine
    src = inspect.getsource(engine)
    assert "time.time(" not in src
    assert "time.perf_counter(" in src


def test_null_observer_is_inert():
    assert O.NULL.clock() == 0.0
    # every hook is the shared no-op and accepts anything
    O.NULL.request_admitted("x", 1, n_shared=0, resume=False,
                            bucket_len=8, t_prefill0=0.0)
    O.NULL.step_done(0, 0, n_active=0, n_tokens=0)
    O.NULL.compile_event("decode", None, 0, 0.0)
    assert O.get_active() is O.NULL


def test_activated_scopes_the_active_observer():
    obs = O.Observer()
    assert O.get_active() is O.NULL
    with O.activated(obs) as got:
        assert got is obs and O.get_active() is obs
        with O.activated(O.NULL):
            assert O.get_active() is O.NULL
        assert O.get_active() is obs
    assert O.get_active() is O.NULL
