"""Training substrate: optimizer math, schedules, checkpoint round-trip +
resume + atomicity, data determinism/host-sharding, straggler/preemption."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — property tests skip (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config, reduce_config
from repro.training import (AdamW, ByteCorpus, DataConfig, StragglerMonitor,
                            SyntheticLM, Trainer, TrainerConfig, checkpoint,
                            make_optimizer)
from repro.training.optimizer import cosine_schedule, global_norm


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference():
    opt = AdamW(lr=lambda s: jnp.float32(0.1), b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0, clip_norm=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = opt.init(p)
    p2, state2 = opt.update(g, state, p)
    # numpy reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.square(np.asarray(g["w"]))
    mh, vh = m / 0.1, v / 0.01
    want = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, atol=1e-6)
    assert int(state2.step) == 1


def test_adamw_weight_decay_only_on_matrices():
    opt = AdamW(lr=lambda s: jnp.float32(0.1), weight_decay=0.5, clip_norm=0.0)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    p2, _ = opt.update(g, opt.init(p), p)
    assert float(jnp.max(jnp.abs(p2["b"] - 1.0))) == 0.0  # bias undecayed
    assert float(jnp.max(jnp.abs(p2["w"] - 1.0))) > 0.0  # matrix decayed


def test_grad_clipping():
    opt = AdamW(lr=lambda s: jnp.float32(1.0), clip_norm=1.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([3.0, 4.0, 0.0])}  # norm 5 -> scaled by 1/5
    _, st1 = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(st1.mu["w"]),
                               0.1 * np.asarray([0.6, 0.8, 0.0]), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000))
def test_cosine_schedule_bounds(step):
    f = cosine_schedule(1e-3, warmup=100, total=10_000, floor_frac=0.1)
    lr = float(f(jnp.int32(step)))
    assert 0.0 <= lr <= 1e-3 + 1e-9
    if step >= 100:
        assert lr >= 1e-4 - 1e-9  # floor


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    checkpoint.save(d, 5, t, metadata={"note": "x"})
    step, restored = checkpoint.restore_latest(d, jax.tree.map(np.zeros_like, t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert checkpoint.read_manifest(d, 5)["metadata"]["note"] == "x"


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        checkpoint.save(d, s, _tree(), keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert checkpoint.latest_step(d) == 4


def test_checkpoint_atomicity_no_partial_dir(tmp_path):
    """A leftover temp dir (simulated crash) must not break restore."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, _tree())
    os.makedirs(os.path.join(d, ".tmp.step_00000002.0"))  # crashed save
    assert checkpoint.latest_step(d) == 1
    _, restored = checkpoint.restore_latest(d, _tree())
    assert int(np.asarray(restored["step"])) == 7


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ac = checkpoint.AsyncCheckpointer(d, keep=2)
    ac.save(3, _tree())
    ac.wait()
    assert checkpoint.latest_step(d) == 3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_data_deterministic_and_restartable():
    dc = DataConfig(global_batch=4, seq_len=16, seed=3)
    ds = SyntheticLM(dc, vocab_size=97)
    a = ds.batch_at(11)
    b = ds.batch_at(11)  # same step -> identical (restart-exactness)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = ds.batch_at(12)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_host_sharding_partitions_global_batch():
    full = SyntheticLM(DataConfig(global_batch=8, seq_len=8, seed=1), 61)
    h0 = SyntheticLM(DataConfig(global_batch=8, seq_len=8, seed=1,
                                host_id=0, n_hosts=2), 61)
    h1 = SyntheticLM(DataConfig(global_batch=8, seq_len=8, seed=1,
                                host_id=1, n_hosts=2), 61)
    f, a, b = full.batch_at(0), h0.batch_at(0), h1.batch_at(0)
    np.testing.assert_array_equal(np.concatenate([a["inputs"], b["inputs"]]),
                                  f["inputs"])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"the quick brown fox jumps over the lazy dog " * 50)
    dc = DataConfig(global_batch=4, seq_len=16, seed=0)
    ds = ByteCorpus(dc, str(p))
    b0, b1 = ds.batch_at(0), ds.batch_at(0)
    np.testing.assert_array_equal(b0["inputs"], b1["inputs"])
    assert b0["inputs"].shape == (4, 16)
    # labels are next-byte targets
    np.testing.assert_array_equal(b0["inputs"][:, 1:], b0["labels"][:, :-1])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_monitor_alarm():
    mon = StragglerMonitor(factor=2.0)
    for i in range(6):
        mon.observe(i, 1.0)
    assert mon.observe(6, 5.0) is True
    assert mon.observe(7, 1.1) is False
    assert mon.alarms == [6]


def test_trainer_resume_bitexact(tmp_path):
    """Train 6 steps straight vs 3+checkpoint+restart+3: same params."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    dc = DataConfig(global_batch=4, seq_len=16, seed=0)

    d1 = str(tmp_path / "a")
    tr = Trainer(cfg, TrainerConfig(steps=6, log_every=6, ckpt_every=6,
                                    ckpt_dir=d1, lr=1e-3, warmup=1), dc)
    tr.run()
    straight = jax.device_get(tr.params)

    d2 = str(tmp_path / "b")
    # same 6-step schedule, but stop (simulated preemption) after step 3
    tr_a = Trainer(cfg, TrainerConfig(steps=6, log_every=3, ckpt_every=3,
                                      ckpt_dir=d2, lr=1e-3, warmup=1,
                                      stop_after=3), dc)
    tr_a.run()
    # "restart": new Trainer resumes from step 3 and continues to 6
    tr_b = Trainer(cfg, TrainerConfig(steps=6, log_every=3, ckpt_every=3,
                                      ckpt_dir=d2, lr=1e-3, warmup=1), dc)
    assert tr_b.start_step == 3
    tr_b.run()
    resumed = jax.device_get(tr_b.params)

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg = reduce_config(get_config("llama3.2-1b"))
    d = str(tmp_path / "pre")
    tr = Trainer(cfg, TrainerConfig(steps=50, log_every=50, ckpt_every=50,
                                    ckpt_dir=d, lr=1e-3, warmup=1),
                 DataConfig(global_batch=2, seq_len=8, seed=0))
    tr.preempt.request()  # simulate SIGTERM before the loop starts
    with pytest.raises(SystemExit) as e:
        tr.run()
    assert e.value.code == 143
    assert checkpoint.latest_step(d) == 1  # checkpointed at the boundary


def test_loss_decreases_on_learnable_data(tmp_path):
    cfg = reduce_config(get_config("llama3.2-1b"))
    dc = DataConfig(global_batch=8, seq_len=32, seed=0)
    tr = Trainer(cfg, TrainerConfig(steps=60, log_every=20, ckpt_every=1000,
                                    ckpt_dir=str(tmp_path / "ck"), lr=2e-3,
                                    warmup=5),
                 dc)
    tr.run()
    first, last = tr.metrics_log[0]["loss"], tr.metrics_log[-1]["loss"]
    assert last < first - 0.02, (first, last)
