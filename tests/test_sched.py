"""The continuous-batching scheduler: chunked prefill must be pure
scheduling — never math.

The acceptance grid pins the ScheduledEngine greedy-token-identical to
the synchronous whole-prompt engine's oracle across every (cache_kind ×
style × impl) serving combo, plus the sliding-window row (paged: the
ring pins chunk width == block size; dense: a BINDING window falls back
to monolithic jobs — still asynchronous admission, still identical
tokens) and a pool-starved paged cell where mid-prefill chunks preempt
live decoders and deferred admissions resume.

Around the grid: planner unit semantics (budget accounting, FCFS
head-blocking, monolithic cost clamp), config/build-time validation
errors, the observer integration (sched_iteration / chunk spans land on
the right tracks; NullObserver carries both hooks as no-ops), and the
``NoSyncPrefillInSubmit`` lint audit — clean on the scheduled engines,
FIRING on the synchronous engine it exists to deprecate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs as O
from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.lint import submitpath
from repro.models import forward_seq, init_params
from repro.serving import (Engine, PagedCacheAdapter, PagedQ8CacheAdapter,
                           ServeConfig, SchedConfig, ScheduledEngine)
from repro.serving.engine import Request
from repro.serving.sched import PrefillJob, plan_iteration

MAX_NEW = 4
CHUNK = 8
WIN = 3           # sliding-window row: window smaller than prompt 0
WIN_BLOCK = 2     # paged ring pins chunk width == block size there
WIN_MAX_NEW = 5


def _greedy_oracle(params, cfg, prompt, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward_seq(params, cfg,
                               jnp.asarray(toks, jnp.int32)[None])
        t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        out.append(t)
        toks.append(t)
    return out


@pytest.fixture(scope="module")
def setup():
    """Base model + merged rewrites + full-sequence oracle streams (MHA
    so kp/vp apply; scaled float32 embeddings keep greedy argmax
    well-conditioned — the test_backend_registry recipe)."""
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4, sliding_window=0)  # windowless: dense cells chunk
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    models = {"generic": (cfg, params)}
    for variant in ("qp", "kp", "vp"):
        mp, mc = merge_skipless(params, cfg, variant)
        models[variant] = (mc, mp)
    # prompt 1 longer than one chunk, prompt 0 shorter: both chunk-count
    # classes in one serve
    prompts = [np.arange(5) % cfg.vocab_size,
               (np.arange(11) * 3 + 2) % cfg.vocab_size]
    oracle = [_greedy_oracle(params, cfg, p, MAX_NEW) for p in prompts]
    return models, prompts, oracle


def _sched_engine(cfg, params, cache_kind, impl="xla", n_slots=2,
                  max_len=48, n_blocks=None, block=CHUNK, chunk=CHUNK,
                  budget=None, obs=False):
    cache = PagedCacheAdapter(
        block_size=block,
        n_blocks=n_blocks if n_blocks is not None
        else n_slots * max_len // block) \
        if cache_kind == "paged" else "dense"
    return ScheduledEngine(
        cfg, params, ServeConfig(n_slots=n_slots, max_len=max_len, obs=obs),
        scfg=SchedConfig(token_budget=budget or 4 * chunk,
                         chunk_tokens=chunk),
        impl=impl, cache=cache)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp", "kp", "vp"])
@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_chunked_matches_whole_prompt_oracle(setup, cache_kind, style,
                                             impl):
    """The equivalence grid: chunked prefill + planned iterations emit
    greedy streams identical to the unmerged full-sequence oracle (which
    test_backend_registry already pins to the synchronous whole-prompt
    engine) on every registered serving combo."""
    models, prompts, oracle = setup
    cfg, params = models[style]
    eng = _sched_engine(cfg, params, cache_kind, impl=impl)
    assert eng._chunked, "windowless attn combos must chunk"
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    for p, o, want in zip(prompts, outs, oracle):
        assert o == want, (cache_kind, style, impl, list(p[:3]))
    assert eng.n_iterations > 0 and eng.n_chunks_run >= len(prompts)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("style", ["generic", "qp"])
def test_chunked_q8_matches_synchronous_q8(setup, style, impl):
    """The paged_q8 row of the chunked grid: chunk-by-chunk quantized
    prefill (each chunk masked-quantized into its own pages, scales
    frontier-tracked through ``PagedQ8ChunkDest``) must emit a greedy
    stream bit-identical to the synchronous whole-prompt paged_q8
    engine's — the determinism contract is that chunked and whole
    prefill write the SAME int8 pool bits, so the comparison is
    identity, not closeness (the fp oracle would differ by quantization
    noise; this gate pins the scheduling seam only)."""
    models, prompts, _ = setup
    cfg, params = models[style]
    sync = Engine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                  impl="xla", cache=PagedQ8CacheAdapter(block_size=CHUNK))
    want = sync.generate(prompts, max_new_tokens=MAX_NEW)
    eng = ScheduledEngine(
        cfg, params, ServeConfig(n_slots=2, max_len=48),
        scfg=SchedConfig(token_budget=4 * CHUNK, chunk_tokens=CHUNK),
        impl=impl,
        cache=PagedQ8CacheAdapter(block_size=CHUNK,
                                  n_blocks=2 * 48 // CHUNK))
    assert eng._chunked, "windowless q8 combos must chunk like fp paged"
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    for p, o, w in zip(prompts, outs, want):
        assert o == w, (style, impl, list(p[:3]), o, w)
    assert eng.n_chunks_run >= len(prompts)


@pytest.fixture(scope="module")
def setup_windowed():
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        n_kv_heads=4, sliding_window=WIN)
    params = init_params(jax.random.PRNGKey(1), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    prompts = [np.arange(7) % cfg.vocab_size,
               (np.arange(2) * 7 + 2) % cfg.vocab_size]
    oracle = [_greedy_oracle(params, cfg, p, WIN_MAX_NEW) for p in prompts]
    return cfg, params, prompts, oracle


@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_sliding_window_row(setup_windowed, cache_kind):
    """The window row: paged rings chunk at the block width and stays
    chunked; dense with a BINDING window cannot hold a partial prompt in
    its ring, so jobs fall back to monolithic whole-prompt prefills —
    admission is still queue-only, tokens still match the oracle."""
    cfg, params, prompts, oracle = setup_windowed
    if cache_kind == "paged":
        eng = _sched_engine(cfg, params, "paged", block=WIN_BLOCK,
                            chunk=WIN_BLOCK, budget=8, n_blocks=24)
        assert eng._chunked
    else:
        eng = _sched_engine(cfg, params, "dense", chunk=CHUNK)
        assert not eng._chunked, \
            "binding-window dense must fall back to monolithic jobs"
    outs = eng.generate(prompts, max_new_tokens=WIN_MAX_NEW)
    for o, want in zip(outs, oracle):
        assert o == want, cache_kind


def test_tiny_pool_preemption_and_deferral_identical(setup):
    """Pool-starved paged cell: deferred admissions queue (FCFS), chunks
    evict live decoders when no page maps, preempted requests resume —
    and the streams STILL match the oracle exactly."""
    models, prompts, oracle = setup
    cfg, params = models["generic"]
    many = prompts + [(np.arange(9) * 5 + 1) % cfg.vocab_size,
                      (np.arange(6) * 11 + 4) % cfg.vocab_size]
    want = oracle + [_greedy_oracle(params, cfg, p, MAX_NEW)
                     for p in many[2:]]
    # budget wide enough that all four requests chunk in the SAME
    # iteration and stay alive together: 8 final blocks demanded of 6
    eng = _sched_engine(cfg, params, "paged", n_slots=4, n_blocks=6,
                        budget=64)
    outs = eng.generate(many, max_new_tokens=MAX_NEW)
    assert eng.stats["n_deferred"] + eng.stats["n_preempted"] > 0, \
        "pool sized to starve: deferral or preemption must fire"
    for o, w in zip(outs, want):
        assert o == w
    eng.pm.drop_prefix_cache()
    assert eng.pm.allocator.n_used == 0, "drained pool leaks no pages"


# ---------------------------------------------------------------------------
# planner unit semantics
# ---------------------------------------------------------------------------

def _job(n, monolithic=False, slot=0, cursor=0):
    r = Request(prompt=np.zeros((n,), np.int32), max_new_tokens=4)
    j = PrefillJob(req=r, toks=np.zeros((n,), np.int32),
                   monolithic=monolithic)
    j.slot, j.cursor = slot, cursor
    return j


def test_planner_budget_and_fcfs_head_blocking():
    scfg = SchedConfig(token_budget=16, chunk_tokens=8)
    jobs = [_job(20), _job(8), _job(8)]
    # 10 decode slots leave 6 < 8 budget: the HEAD doesn't fit, and FCFS
    # must not skip ahead to a later job (that is what starves the head)
    s = plan_iteration(scfg, 10, jobs)
    assert s.chunks == [] and s.budget_used == 10
    # 0 decodes: head always fits (budget >= chunk) — no starvation
    s = plan_iteration(scfg, 0, jobs)
    assert [c.job for c in s.chunks] == [jobs[0], jobs[1]]
    assert s.budget_used == 16 and s.budget == 16
    assert (s.chunks[0].start, s.chunks[0].end, s.chunks[0].final) \
        == (0, 8, False)
    assert (s.chunks[1].start, s.chunks[1].end, s.chunks[1].final) \
        == (0, 8, True)


def test_planner_monolithic_cost_clamp_preserves_liveness():
    """A monolithic job longer than the whole budget charges min(total,
    budget) — otherwise it could NEVER fit and the queue would starve
    behind it forever."""
    scfg = SchedConfig(token_budget=16, chunk_tokens=8)
    s = plan_iteration(scfg, 0, [_job(40, monolithic=True)])
    assert len(s.chunks) == 1
    c = s.chunks[0]
    assert (c.start, c.end, c.cost, c.final) == (0, 40, 16, True)
    # with even one decode active it must wait (cost clamp, not zero)
    assert plan_iteration(scfg, 1, [_job(40, monolithic=True)]).chunks == []


def test_planner_skips_done_and_resumes_cursor():
    scfg = SchedConfig(token_budget=32, chunk_tokens=8)
    done = _job(8, cursor=8)
    mid = _job(20, cursor=8)
    s = plan_iteration(scfg, 0, [done, mid])
    assert [c.job for c in s.chunks] == [mid]
    assert (s.chunks[0].start, s.chunks[0].end, s.chunks[0].final) \
        == (8, 16, False)


def test_config_and_build_validation(setup):
    models, _, _ = setup
    cfg, params = models["generic"]
    with pytest.raises(ValueError):
        SchedConfig(token_budget=4, chunk_tokens=8)  # budget < chunk
    with pytest.raises(ValueError):
        SchedConfig(token_budget=8, chunk_tokens=0)
    with pytest.raises(ValueError, match="multiple of"):
        _sched_engine(cfg, params, "dense", max_len=44)  # 44 % 8 != 0
    with pytest.raises(ValueError, match="block size"):
        # paged chunk width must be block-aligned (chunk 4, block 8)
        ScheduledEngine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                        scfg=SchedConfig(token_budget=16, chunk_tokens=4),
                        cache=PagedCacheAdapter(block_size=8))
    eng = _sched_engine(cfg, params, "dense")
    with pytest.raises(ValueError, match="attention-only"):
        eng.submit(Request(prompt=np.arange(4), max_new_tokens=2),
                   vision=np.zeros((2, 2), np.float32))
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(Request(prompt=np.zeros((47,), np.int32),
                           max_new_tokens=8))


# ---------------------------------------------------------------------------
# observer integration + off-mode contract
# ---------------------------------------------------------------------------

def test_scheduler_observability(setup):
    """Scheduler decisions must land in repro.obs: per-iteration spans on
    the engine track with the budget counter track, chunk spans on BOTH
    the request and slot tracks, the always-on counters agreeing with the
    engine's own telemetry — and the export still structurally valid."""
    models, prompts, _ = setup
    cfg, params = models["generic"]
    eng = _sched_engine(cfg, params, "paged", obs=True)
    eng.generate(prompts, max_new_tokens=MAX_NEW)
    assert eng.obs.enabled

    m = eng.obs.metrics
    assert m["sched_iterations"].value == eng.n_iterations > 0
    assert m["sched_chunks"].value == eng.n_chunks_run > 0
    assert m["sched_chunk_tokens"].value >= eng.n_chunks_run * CHUNK
    assert m["sched_chunk_seconds"].count == eng.n_chunks_run

    evs = eng.obs.trace.events()
    iters = [e for e in evs if e["name"] == "sched_iteration"]
    assert len(iters) == eng.n_iterations
    assert all(e["track"] == O.engine_track() for e in iters)
    budget = [e for e in evs if e["name"] == "sched_budget_used"]
    assert budget and all(e["ph"] == "C" and
                          0 < e["args"]["value"] <= 4 * CHUNK
                          for e in budget)
    chunks = [e for e in evs if e["name"] == "chunk"]
    req_tracks = {e["track"] for e in chunks}
    slot_tracks = {e["track"] for e in chunks}
    assert any(t == O.request_track(0) for t in req_tracks)
    assert any(t == O.slot_track(0) or t == O.slot_track(1)
               for t in slot_tracks)
    assert len(chunks) == 2 * eng.n_chunks_run, \
        "each executed chunk spans its request AND its slot track"
    assert eng.obs.trace.open_spans() == []
    O.validate_perfetto(eng.obs.trace.to_perfetto())


def test_null_observer_carries_sched_hooks_as_noops(setup):
    """The zero-overhead contract extends to the new hooks: obs-off
    engines bind the module NULL singleton whose sched hooks are the
    shared no-op (bench_obs_overhead's gate stays meaningful)."""
    models, prompts, _ = setup
    cfg, params = models["generic"]
    eng = _sched_engine(cfg, params, "dense")
    assert eng.obs is O.NULL and not eng.obs.enabled
    noop = type(O.NULL).step_done
    assert type(O.NULL).sched_iteration is noop
    assert type(O.NULL).chunk_done is noop
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    assert all(len(o) == MAX_NEW for o in outs)
    # always-on telemetry still reads through stats with obs off
    assert eng.stats["sched_iterations"] == eng.n_iterations > 0


# ---------------------------------------------------------------------------
# NoSyncPrefillInSubmit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lint_model():
    cfg = reduce_config(get_config("llama3.2-1b"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_submit_audit_clean_on_scheduled_engine(lint_model):
    cfg, params = lint_model
    eng = ScheduledEngine(cfg, params, ServeConfig(n_slots=2, max_len=48),
                          scfg=SchedConfig(token_budget=32,
                                           chunk_tokens=16))
    assert submitpath.audit_submit(eng, "sched") == []


def test_submit_audit_fires_on_synchronous_engine(lint_model):
    """The negative control: the rule must actually DETECT the class it
    bans — the synchronous engine's submit dispatches its prefill through
    the spied seam and the audit reports it."""
    cfg, params = lint_model
    sync = Engine(cfg, params, ServeConfig(n_slots=4, max_len=48))
    findings = submitpath.audit_submit(sync, "sync")
    assert findings, "synchronous submit must trip NoSyncPrefillInSubmit"
    assert all(f.rule == submitpath.RULE_SUBMIT for f in findings)
    assert any(f.detail["seam"] == "kv._prefill" for f in findings)
    # and the positive control recognises the same engine as observable
    assert submitpath.positive_control(
        Engine(cfg, params, ServeConfig(n_slots=2, max_len=48)),
        "sync") == []


def test_positive_control_fails_vacuous_spies(lint_model):
    """If the spied seam observes NO dispatch from the synchronous
    engine, the audit must fail itself rather than certify vacuously."""
    cfg, params = lint_model

    class _Deaf:
        """An 'engine' whose submit never touches the spied seams."""
        def __init__(self, real):
            self.kv = real.kv
            self.cfg = real.cfg
            self._decode = lambda *a, **k: None

        def submit(self, req, vision=None):
            return True

    deaf = _Deaf(Engine(cfg, params, ServeConfig(n_slots=2, max_len=48)))
    findings = submitpath.positive_control(deaf, "deaf")
    assert len(findings) == 1
    assert "positive control FAILED" in findings[0].message
