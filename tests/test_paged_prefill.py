"""Direct-to-page paged prefill: prompt KV lands straight in the mapped
pool blocks — no worst-case-length intermediate buffer, no post-prefill
scatter pass — and stays token-exact under prefix sharing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.lint import walker as lint_walker
from repro.models import forward_prefill, forward_seq, init_params
from repro.serving import Engine, PagedCacheAdapter, Request, ServeConfig

# chosen so MAX_LEN collides with NO model/pool dimension (reduced shapes
# use 2/4/16/64/96/128; the pool uses N_BLOCKS/BLOCK): any max_len-sized
# array in the prefill program would be the worst-case intermediate the
# direct path is supposed to have deleted
MAX_LEN = 160
BLOCK = 8
N_BLOCKS = 21


def _all_avals(jaxpr):
    """Every var aval anywhere in the program — the shared repro.lint
    walker (one recursion for the whole repo, not a per-test copy)."""
    return list(lint_walker.iter_avals(jaxpr))


def test_paged_prefill_allocates_no_worst_case_buffer():
    """The engine's ACTUAL paged prefill program (as wired through the
    adapter) must contain no max_len-sized array anywhere: the prompt's
    KV goes straight to its mapped pages, so the program's sequence
    extents are bounded by the prompt bucket, never by max_len."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(n_slots=2, max_len=MAX_LEN)
    eng = Engine(cfg, params, sc,
                 cache=PagedCacheAdapter(block_size=BLOCK, n_blocks=N_BLOCKS))
    bucket = 16
    assert bucket < MAX_LEN
    adapter = eng.kv
    nbk = bucket // BLOCK
    jaxpr = jax.make_jaxpr(
        lambda p, tk, tl, kp, vp, b: forward_prefill(
            p, cfg, tk, impl=eng.impl, true_len=tl, pages=(kp, vp, b)))(
        params, jnp.zeros((1, bucket), jnp.int32), jnp.full((1,), 5, jnp.int32),
        adapter.pm.k, adapter.pm.v, jnp.zeros((nbk,), jnp.int32))
    offending = [a for a in _all_avals(jaxpr)
                 if hasattr(a, "shape") and MAX_LEN in tuple(a.shape)]
    assert not offending, (
        f"paged prefill materialized max_len({MAX_LEN})-sized buffers: "
        f"{[a.shape for a in offending[:5]]}")
    # and the engine really serves through that program
    out = eng.generate([np.arange(5) % cfg.vocab_size], max_new_tokens=3)
    assert len(out[0]) == 3


def _greedy_oracle(params, cfg, prompt, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward_seq(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        t = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        out.append(t)
        toks.append(t)
    return out


def test_direct_prefill_tokens_match_dense_oracle():
    """Mixed buckets, shared prefixes, sliding window: the direct-to-page
    engine must emit the dense engine's (and the oracle's) exact greedy
    streams."""
    cfg = reduce_config(get_config("mistral-7b"))  # GQA + sliding window
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, size=(int(n),)).astype(np.int32)
               for n in (3, 9, 17, 17, 26)]
    prompts[3] = prompts[2].copy()  # identical pair -> shared prefix pages
    paged = Engine(cfg, params, ServeConfig(n_slots=5, max_len=64),
                   cache=PagedCacheAdapter(block_size=8, n_blocks=40))
    outs = paged.generate(prompts, max_new_tokens=5)
    assert paged.pm.allocator.n_shared_hits > 0
    for p, o in zip(prompts, outs):
        assert o == _greedy_oracle(params, cfg, p, 5), len(p)


def test_direct_prefill_skips_shared_pages_of_live_requests():
    """STAGGERED prefix sharing: request A decodes into its partial tail
    page, then request B with an identical prompt prefills direct-to-page.
    B's prefill must NOT rewrite the shared pages (its block ids are -1
    there) — rewriting would clobber A's decoded KV with B's bucket
    padding and corrupt A's stream mid-flight."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(12) * 5 + 1) % cfg.vocab_size  # 1 full + partial page
    want = _greedy_oracle(params, cfg, prompt, 6)

    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64),
                 cache=PagedCacheAdapter(block_size=8, n_blocks=32))
    ra = Request(prompt=prompt, max_new_tokens=6)
    assert eng.submit(ra)
    for _ in range(3):  # A writes positions 12..14 into the shared tail
        eng.step()
    rb = Request(prompt=prompt.copy(), max_new_tokens=6)
    assert eng.submit(rb)
    assert eng.pm.allocator.n_shared_hits >= 2, "B must share A's pages"
    while eng.active:
        eng.step()
    assert ra.out_tokens == want, "A's stream was corrupted by B's prefill"
    assert rb.out_tokens == want
    assert eng.pm.allocator.n_cow >= 1, "B's first append must CoW the tail"


def test_window_rollover_detaches_shared_page_from_live_peer():
    """Ring-phase regression on the PAGED side (the paged sibling of PR 2's
    dense ring-phase family): two prefix-SHARING windowed streams decode
    staggered, and the faster one's window rolls over a ring slot that
    still holds a page the slower peer reads.  The recycle must DETACH
    (CoW-without-copy: release our reference, take a fresh page) — reusing
    the shared page in place would overwrite the peer's live window and
    corrupt its stream mid-flight.  Both streams must stay oracle-exact
    through detaches, in-place recycles, and the shared-tail CoW."""
    cfg = reduce_config(get_config("llama3.2-1b")).with_(sliding_window=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(12) * 5 + 1) % cfg.vocab_size  # 1 full + partial page
    want = _greedy_oracle(params, cfg, prompt, 24)

    eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64),
                 cache=PagedCacheAdapter(block_size=8, n_blocks=32))
    pm = eng.pm
    assert pm.ring == 3, "window 16 / block 8 must ring at 3 table slots"
    ra = Request(prompt=prompt, max_new_tokens=24)
    assert eng.submit(ra)
    for _ in range(3):  # A gets a head start (the stagger)
        eng.step()
    rb = Request(prompt=prompt.copy(), max_new_tokens=16)
    assert eng.submit(rb)
    assert pm.allocator.n_shared_hits >= 2, "B must share A's prompt pages"
    while eng.active:
        eng.step()

    assert ra.out_tokens == want, (
        "the faster stream's rollover corrupted its own window")
    assert rb.out_tokens == want[:16], (
        "peer's stream changed when the faster stream's window rolled "
        "over their shared page — recycle must detach, not reuse")
    # the scenario actually exercised all three recycle flavors:
    # B's tail CoW + A's shared-page detach …
    assert pm.allocator.n_cow >= 2, "expected tail CoW + rollover detach"
    # … and at least one solely-owned page recycled in place
    assert pm.allocator.n_recycled >= 1
    # the headline bound: no windowed request ever held more pages than
    # ceil(window/block) + 1
    assert pm.request_page_hwm.count == 2 and \
        pm.request_page_hwm.max <= pm.ring_bound == 3
    pm.drop_prefix_cache()
    assert pm.allocator.n_used == 0, "drained engine must free the pool"
