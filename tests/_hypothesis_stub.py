"""Fallback for the optional ``hypothesis`` dev dependency.

Tier-1 must collect and run without hypothesis installed (see
requirements-dev.txt); test modules import this stub when the real package
is missing, so only the property-based tests skip — everything else in the
module still runs.
"""
import pytest


def settings(**_kw):
    return lambda f: f


def given(*_a, **_kw):
    def deco(f):
        # replace the test with an argument-free skip stub: pytest must not
        # try to resolve the @given parameters as fixtures
        @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
        def stub():
            pass

        stub.__name__ = f.__name__
        stub.__doc__ = f.__doc__
        return stub

    return deco


class _Strategies:
    """Accepts any strategy constructor call at module-import time."""

    def __getattr__(self, _name):
        return lambda *a, **kw: None


st = _Strategies()
