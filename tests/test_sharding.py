"""Distribution layer: spec rules, uneven-sharding downgrades, and a
subprocess mini dry-run on 8 fake host devices (multi-pod mesh in
miniature). The full 512-device dry-run is exercised by launch/dryrun.py."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduce_config
from repro.distribution import sharding as shd
from repro.launch import steps as steps_lib

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_cover_all_leaves():
    for arch in ["qwen2.5-32b", "mamba2-2.7b", "phi3.5-moe-42b-a6.6b",
                 "llama3.2-vision-11b", "hymba-1.5b"]:
        cfg = get_config(arch)
        pshape = steps_lib.param_specs(cfg)
        rules = shd.ShardingRules(dp=("data",), tp="model")
        specs = shd.param_pspecs(pshape, rules)
        n_leaves = len(jax.tree.leaves(pshape))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch
        # every spec rank matches its leaf rank
        for (path, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(pshape)[0],
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(leaf.shape), (arch, path)


def test_ffn_and_embed_sharded():
    cfg = get_config("llama3.2-1b")
    pshape = steps_lib.param_specs(cfg)
    rules = shd.ShardingRules(dp=("data",), tp="model")
    specs = shd.param_pspecs(pshape, rules)
    assert specs["embed"]["table"] == P("model", None)
    assert specs["layers"]["ffn"]["w_gate"] == P(None, None, "model")
    assert specs["layers"]["ffn"]["w_down"] == P(None, "model", None)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")


def test_evenly_downgrades_uneven_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # sizes 1: all divide

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    # fake a 16-way model axis via a mesh dict stub is complex; instead test
    # the predicate directly through check_divisibility on a real mesh by
    # reusing mesh sizes of 1 (all even) and asserting no downgrades
    spec = {"a": P("model", None)}
    fixed = shd.evenly(spec, {"a": Leaf((7, 3))}, mesh)
    assert fixed["a"] == P("model", None)  # size-1 axis always divides


def test_make_rules_drops_dp_for_tiny_batch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = shd.make_rules(mesh, batch=0)
    assert r.dp == ("data",)
    # batch smaller than dp size -> replicate
    r2 = shd.make_rules(mesh, batch=0)
    assert r2.tp == "model"


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {src!r})
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduce_config, SHAPES
from repro.distribution import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh

assert jax.device_count() == 8
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduce_config(get_config({arch!r}))
rules = shd.make_rules(mesh, batch=4)
pshape = steps_lib.param_specs(cfg)
ppspec = shd.evenly(shd.param_pspecs(pshape, rules), pshape, mesh)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), ppspec)
fn, _ = steps_lib.build_step(cfg, "train")
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import _opt_pspecs
opt = make_optimizer("adamw", 1e-3, 2, 10)
oshape = jax.eval_shape(opt.init, pshape)
osh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                   shd.evenly(_opt_pspecs(oshape, ppspec, mesh), oshape, mesh))
import jax.numpy as jnp
ispec = {{"inputs": jax.ShapeDtypeStruct((4, 16), jnp.int32),
          "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32)}}
bsh = {{k: NamedSharding(mesh, P(rules.dp, None)) for k in ispec}}
jfn = jax.jit(fn, in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None),
              donate_argnums=(0, 1))
lowered = jfn.lower(pshape, oshape, ispec)
compiled = lowered.compile()
from repro.launch.dryrun import cost_dict  # list-vs-dict cost_analysis compat
cost = cost_dict(compiled)
print(json.dumps({{"ok": True, "flops": float(cost.get("flops", -1))}}))
"""


@pytest.mark.parametrize("arch", ["llama3.2-1b", "moonshot-v1-16b-a3b"])
def test_mini_multipod_dryrun_subprocess(arch):
    """End-to-end lower+compile on a (2,2,2) pod×data×model mesh."""
    code = MINI_DRYRUN.format(src=os.path.abspath(SRC), arch=arch)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] > 0


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag.1 = bf16[64,512]{1,0} all-gather(bf16[64,32]{1,0} %y), dimensions={1}
  ROOT %t = (f32[2]{0}) tuple(f32[2]{0} %z)
"""
    r = collective_bytes(hlo)
    # the fake ROOT tuple op must not be counted as a collective
    assert r["counts"]["all-reduce"] == 1
    assert r["counts"]["all-gather"] == 1
    assert r["per_op_bytes"]["all-reduce"] == 128 * 256 * 4
    assert r["per_op_bytes"]["all-gather"] == 64 * 32 * 2


def test_paged_cache_pspecs_structure():
    """Paged pool specs: block axis TP-split (flash-split-K over pages),
    per-slot bookkeeping batch-sharded, and `evenly` keeps the block-axis
    split whenever the pool size divides the mesh."""
    from repro.models import paged_cache_spec
    from repro.models.transformer import PagedDecodeCache

    cfg = reduce_config(get_config("mistral-7b"))
    rules = shd.ShardingRules(dp=("data",), tp="model")
    specs = shd.paged_cache_pspecs(cfg, rules)
    assert isinstance(specs, PagedDecodeCache)
    assert specs.k == P(None, "model", None, None, None)
    assert specs.v == specs.k
    assert specs.block_tables == P(("data",), None)
    assert specs.length == P(("data",))

    spec = paged_cache_spec(cfg, n_blocks=16, block_size=8, n_slots=4,
                            max_len=32)
    assert spec["k"][0][1] == 16 and spec["k"][0][2] == 8
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    leaves = PagedDecodeCache(*[Leaf(spec[f][0])
                                for f in PagedDecodeCache._fields])
    kept = shd.evenly(specs, leaves, mesh)
    assert kept.k == specs.k, "1-way mesh must not downgrade the pool spec"
