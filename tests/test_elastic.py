"""Elastic scaling: checkpoint on one mesh, resume on a different one.

Checkpoints are host-layout (mesh-free) numpy trees and the data pipeline
is a pure function of (seed, step), so a restart on a different pod count
reshards transparently and consumes the exact same token stream. Runs in a
subprocess with 4 fake host devices (the main test process must keep 1)."""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, {src!r})
import jax
import numpy as np
from repro.configs import get_config, reduce_config
from repro.launch.mesh import make_mesh
from repro.training import DataConfig, Trainer, TrainerConfig

cfg = reduce_config(get_config("llama3.2-1b"))
dc = DataConfig(global_batch=4, seq_len=16, seed=0)
ck = {ck!r}

# phase 1: dp=2 x tp=2 mesh, stop after 2 of 4 steps
tc = TrainerConfig(steps=4, log_every=2, ckpt_every=2, ckpt_dir=ck,
                   lr=1e-3, warmup=1, stop_after=2)
tr1 = Trainer(cfg, tc, dc, mesh=make_mesh((2, 2), ("data", "model")))
tr1.run()
p1 = jax.device_get(tr1.params)

# phase 2: "pod shrink" -> dp=1 x tp=4 mesh, resume from step 2
tc2 = TrainerConfig(steps=4, log_every=2, ckpt_every=2, ckpt_dir=ck,
                    lr=1e-3, warmup=1)
tr2 = Trainer(cfg, tc2, dc, mesh=make_mesh((1, 4), ("data", "model")))
assert tr2.start_step == 2, tr2.start_step
m = tr2.run()

# reference: same 4 steps straight on the shrunk mesh from scratch ckpt-free
import shutil
shutil.rmtree(ck)
tc3 = TrainerConfig(steps=4, log_every=4, ckpt_every=100, ckpt_dir=ck,
                    lr=1e-3, warmup=1)
tr3 = Trainer(cfg, tc3, dc, mesh=make_mesh((1, 4), ("data", "model")))
tr3.run()
a = jax.device_get(tr2.params)
b = jax.device_get(tr3.params)
err = max(float(np.max(np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32))))
          for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
print(json.dumps({{"ok": True, "loss": m["loss"], "resharded_vs_straight_err": err}}))
"""


def test_elastic_mesh_resume(tmp_path):
    code = SCRIPT.format(src=os.path.abspath(SRC), ck=str(tmp_path / "ck"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    # elastic resume must match the same schedule trained straight (fp32
    # reductions differ slightly across mesh layouts)
    assert out["resharded_vs_straight_err"] < 5e-2, out
