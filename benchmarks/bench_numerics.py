"""§Numerics: runtime precision of the merged form (beyond the paper).

The merge is computed in float64 (exact); at runtime the merged model
evaluates (u·Q)(Q⁻¹K) where the vanilla model evaluates u·K, so the logit
discrepancy scales like cond(Q)·eps·L.  This benchmark measures that for
lecun-normal vs orthogonal Q at fp32/bf16 runtime — the deployment guidance
the paper doesn't give (its §4 experiment is fp32, shallow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import condition_numbers, merge_skipless
from repro.models import forward_seq, init_params


def _case(init_style: str, runtime_dtype: str, n_layers: int = 4,
          d_model: int = 256):
    cfg = ModelConfig(
        name="numerics", family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=512,
        ffn_type="gelu_mlp", block_style="skipless", init_style=init_style,
        dtype=runtime_dtype, param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    base, _, _ = forward_seq(params, cfg, toks)
    mparams, mcfg = merge_skipless(params, cfg, "qp")
    merged, _, _ = forward_seq(mparams, mcfg, toks)
    rel = (float(np.max(np.abs(np.asarray(base, np.float32)
                               - np.asarray(merged, np.float32))))
           / (float(np.max(np.abs(np.asarray(base, np.float32)))) + 1e-12))
    conds = condition_numbers(params, cfg, "qp")
    return dict(init=init_style, dtype=runtime_dtype, layers=n_layers,
                cond_med=float(np.median(conds)), rel_err=rel)


def run():
    rows = []
    for init in ("normal", "orthogonal"):
        for dt in ("float32", "bfloat16"):
            rows.append(_case(init, dt))
    # depth scaling at the worst combination
    for L in (2, 8):
        rows.append(_case("normal", "bfloat16", n_layers=L))
    return rows


def main():
    rows = run()
    print(f"{'init':12s} {'runtime':9s} {'L':>2s} {'cond(Q) med':>12s} "
          f"{'rel logit err':>14s}")
    for r in rows:
        print(f"{r['init']:12s} {r['dtype']:9s} {r['layers']:>2d} "
              f"{r['cond_med']:>12.1f} {r['rel_err']:>14.2e}")
    # the deployment rule: orthogonal-init (or well-conditioned) Q keeps the
    # merged runtime faithful even in bf16
    ortho_bf16 = next(r for r in rows if r["init"] == "orthogonal"
                      and r["dtype"] == "bfloat16")
    normal_bf16 = next(r for r in rows if r["init"] == "normal"
                       and r["dtype"] == "bfloat16" and r["layers"] == 4)
    assert ortho_bf16["rel_err"] < normal_bf16["rel_err"], \
        "conditioning must dominate the merged-runtime error"
    print("guidance: audit cond(Q) before deploying the merged form in bf16")


if __name__ == "__main__":
    main()
