"""Paper §4: numerical equivalence of the merged forms + invertibility audit.

Builds a Mistral-7B-shaped (reduced) skipless model, merges per Fig 1(b),
and reports max |Δlogit| plus the condition-number distribution of all
square Q matrices (the paper audits Mistral-7B the same way)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.core import condition_numbers, merge_skipless
from repro.models import count_params, forward_seq, init_params


def run():
    rows = []
    for arch in ["mistral-7b"] + [a for a in ASSIGNED_ARCHS
                                  if get_config(a).qp_removal_applicable]:
        cfg = reduce_config(get_config(arch)).with_(
            block_style="skipless", dtype="float32", param_dtype="float32")
        if cfg.n_experts:
            cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
        params = init_params(jax.random.PRNGKey(0), cfg)
        params["embed"]["table"] = params["embed"]["table"] * 50.0
        B, S = 2, 16
        if cfg.family == "audio":
            x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        else:
            x = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                   cfg.vocab_size)
        vision = None
        if cfg.family == "vlm":
            vision = jax.random.normal(jax.random.PRNGKey(2),
                                       (B, cfg.n_vision_tokens, cfg.d_model))
        base, _, _ = forward_seq(params, cfg, x, vision=vision)

        # kp/vp on MoE: the K/V basis change (cond ~1e3) amplifies router
        # logit rounding enough to flip near-tied top-k choices, which makes
        # logit-level comparison meaningless (both routings are valid).
        # kp/vp are exercised on the dense/audio MHA archs instead.
        variants = ["qp"] + (["kp", "vp"] if cfg.kp_vp_removal_applicable
                             and cfg.family not in ("vlm", "moe") else [])
        for variant in variants:
            t0 = time.perf_counter()
            mparams, mcfg = merge_skipless(params, cfg, variant)
            merge_ms = (time.perf_counter() - t0) * 1e3
            merged, _, _ = forward_seq(mparams, mcfg, x, vision=vision)
            abs_err = float(np.max(np.abs(np.asarray(base) - np.asarray(merged))))
            rel_err = abs_err / (float(np.max(np.abs(np.asarray(base)))) + 1e-12)
            conds = condition_numbers(params, cfg, variant)
            rows.append(dict(arch=arch, variant=variant, rel_err=rel_err,
                             removed=count_params(params) - count_params(mparams),
                             cond_max=float(conds.max()),
                             cond_med=float(np.median(conds)),
                             merge_ms=merge_ms))
            # MoE: router logits in the merged basis differ by ~1 ulp; a
            # near-tied top-k can flip for a token, which is a property of
            # top-k routing (both routings are "correct"), not of the merge.
            tol = 2e-3 if cfg.n_experts else 3e-4
            assert rel_err < tol, (arch, variant, rel_err)
            assert np.all(np.isfinite(conds)), "singular projection found"
    return rows


def main():
    rows = run()
    print(f"{'arch':26s} {'var':>4s} {'rel_err':>9s} {'removed':>9s} "
          f"{'cond_max':>9s} {'cond_med':>9s} {'merge_ms':>9s}")
    for r in rows:
        print(f"{r['arch']:26s} {r['variant']:>4s} {r['rel_err']:>9.2e} "
              f"{r['removed']:>9,d} {r['cond_max']:>9.1f} "
              f"{r['cond_med']:>9.1f} {r['merge_ms']:>9.1f}")
    print("all merges equivalent (rel_err < 3e-4); all Q invertible  OK")


if __name__ == "__main__":
    main()
