"""Per-kernel micro-benchmarks.

On this CPU container the Pallas kernels execute under interpret=True
(Python), so wall-times are NOT TPU-meaningful; what we report per kernel:
  * correctness vs the ref.py oracle at a production-relevant shape,
  * analytic FLOPs and HBM bytes, arithmetic intensity, and the v5e
    roofline-bound µs (the number the TPU run would be judged against),
  * the XLA-path wall time (the path the dry-run lowers) as a CPU sanity
    check.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

PEAK_FLOPS = 197e12
HBM = 819e9


def _time(fn, *args, n=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_flash():
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=256, block_k=256,
                              interpret=True)
    want = ref.ref_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(want))))
    flops = 4 * B * Hq * S * S * D * 0.5  # causal half
    bytes_ = (q.size + k.size + v.size + out.size) * 4
    bound_us = max(flops / PEAK_FLOPS, bytes_ / HBM) * 1e6
    return dict(name="flash_attention", err=err, flops=flops,
                intensity=flops / bytes_, v5e_bound_us=bound_us)


def bench_decode():
    B, S, Hq, Hkv, D = 8, 4096, 32, 8, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    qp = jnp.full((B,), S - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, kv_positions=kv_pos, q_position=qp,
                               block_k=512, interpret=True)
    want = ref.ref_decode_attention(q.reshape(B, Hkv, Hq // Hkv, D),
                                    kc.transpose(0, 2, 1, 3),
                                    vc.transpose(0, 2, 1, 3), kv_pos,
                                    qp[:, None]).reshape(B, Hq, D)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(want))))
    flops = 4 * B * Hq * S * D
    bytes_ = (kc.size + vc.size) * 4  # cache streaming dominates
    bound_us = max(flops / PEAK_FLOPS, bytes_ / HBM) * 1e6
    return dict(name="decode_attention", err=err, flops=flops,
                intensity=flops / bytes_, v5e_bound_us=bound_us)


def bench_ssd():
    B, S, H, P, N, L = 1, 2048, 80, 64, 128, 256  # mamba2-2.7b geometry
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y, fin = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=L, interpret=True)
    y_ref, fin_ref = ref.ref_ssd(x, dt, dt * A, Bm, Cm)
    # relative error: |y| grows with state accumulation over S=2048 steps
    err = float(np.max(np.abs(np.asarray(y) - np.asarray(y_ref)))
                / np.max(np.abs(np.asarray(y_ref))))
    nc = S // L
    flops = B * H * nc * (2 * L * L * N + 2 * L * L * P + 2 * L * P * N * 2)
    bytes_ = (x.size + Bm.size + Cm.size + y.size) * 4
    bound_us = max(flops / PEAK_FLOPS, bytes_ / HBM) * 1e6
    return dict(name="ssd_scan", err=err, flops=flops,
                intensity=flops / bytes_, v5e_bound_us=bound_us)


def run():
    return [bench_flash(), bench_decode(), bench_ssd()]


def main():
    rows = run()
    print(f"{'kernel':18s} {'max_err':>9s} {'GFLOPs':>8s} {'AI':>7s} "
          f"{'v5e bound us':>13s}")
    for r in rows:
        print(f"{r['name']:18s} {r['err']:>9.2e} {r['flops'] / 1e9:>8.2f} "
              f"{r['intensity']:>7.1f} {r['v5e_bound_us']:>13.1f}")
        assert r["err"] < 1e-3
    print("kernels validated vs oracles (interpret mode)  OK")


if __name__ == "__main__":
    main()
