"""§Roofline table from dry-run artifacts (artifacts/dryrun/*.json).

Per (arch × shape) on the single-pod 16×16 mesh:
  compute_s    = HLO_FLOPs_per_chip / 197e12        (v5e bf16 peak)
  memory_s     = HLO_bytes_per_chip / 819e9         (HBM)
  collective_s = collective_bytes_per_chip / 50e9   (ICI link)
plus the dominant term, MODEL_FLOPS/HLO_FLOPs ratio, and per-chip memory.
"""
from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_records(mesh="single", style="default"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}__{style}.json"))):
        r = json.load(open(f))
        if not r.get("skipped") and "error" not in r:
            recs.append(r)
    return recs


def run():
    rows = []
    for r in load_records():
        rf = r.get("roofline", {})
        if not rf:
            continue
        total = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append(dict(
            arch=r["arch"], shape=r["shape"],
            compute_s=rf["compute_s"], memory_s=rf["memory_s"],
            collective_s=rf["collective_s"], dominant=rf["dominant"],
            compute_frac=rf["compute_s"] / total if total else 0.0,
            mfr=r.get("model_flops_ratio", float("nan")),
            peak_gb=(r["memory"]["peak_bytes"] or 0) / 1e9))
    return rows


def main():
    rows = run()
    if not rows:
        print("no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --out "
              "artifacts/dryrun")
        return
    print(f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collect_s':>10s} {'dominant':>10s} {'cmp_frac':>8s} "
          f"{'MFR':>6s} {'peakGB':>7s}")
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:>10.4f} "
              f"{r['memory_s']:>10.4f} {r['collective_s']:>10.4f} "
              f"{r['dominant']:>10s} {r['compute_frac']:>8.3f} "
              f"{r['mfr']:>6.2f} {r['peak_gb']:>7.1f}")
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    print(f"cells={len(rows)} dominant breakdown: {n_dom}")


if __name__ == "__main__":
    main()
