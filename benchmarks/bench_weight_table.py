"""Paper §3 table: weights + savings + batch-1 decode speedup.

Reproduces the exact numbers for Pythia-6.9B and Mistral-7B and extends the
table to every assigned architecture.  The paper's claimed values are
asserted (reproduction gate)."""
from __future__ import annotations

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import decode_speedup, weight_table

PAPER_CLAIMS = {
    "pythia-6.9b": dict(qp=33_554_432, kv=33_554_432, ffn=134_217_728,
                        embed=412_876_800, savings_pct=16, speedup=1.19),
    "mistral-7b": dict(qp=33_554_432, kv=8_388_608, ffn=176_160_768,
                       embed=262_144_000, savings_pct=15, speedup=1.17),
}


def run():
    rows = []
    for arch in list(PAPER_CLAIMS) + list(ASSIGNED_ARCHS):
        cfg = get_config(arch)
        t = weight_table(cfg)
        row = dict(arch=arch, total=t["total"], removed=t["removed"],
                   savings_pct=100 * t["savings_frac"],
                   speedup=t["speedup"],
                   speedup_active=decode_speedup(cfg, active_only=True))
        rows.append(row)
        if arch in PAPER_CLAIMS:
            c = PAPER_CLAIMS[arch]
            assert t["qp_per_layer"] == c["qp"], arch
            assert t["kv_per_layer"] == c["kv"], arch
            assert t["ffn_per_layer"] == c["ffn"], arch
            assert t["embed"] == c["embed"], arch
            assert round(t["savings_frac"] * 100) == c["savings_pct"], arch
            assert round(t["speedup"], 2) == c["speedup"], arch
    return rows


def main():
    rows = run()
    print(f"{'arch':26s} {'total':>15s} {'removed':>14s} {'save%':>6s} "
          f"{'speedup':>8s} {'speedup(active)':>15s}")
    for r in rows:
        print(f"{r['arch']:26s} {r['total']:>15,d} {r['removed']:>14,d} "
              f"{r['savings_pct']:>6.1f} {r['speedup']:>8.3f} "
              f"{r['speedup_active']:>15.3f}")
    print("paper claims asserted: pythia 16%/1.19x, mistral 15%/1.17x  OK")


if __name__ == "__main__":
    main()
