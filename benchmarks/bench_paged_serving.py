"""Paged vs dense serving at EQUAL HBM: concurrent streams and tokens/s.

The dense DecodeCache sizes every slot for the worst case, so at a fixed
cache-HBM budget the slot count is ``budget / (L · max_len · Hkv · Dh)``
— tiny, and it is the batch size that amortizes the merged fast path's
per-token K*/V* weight stream.  The paged pool spends the SAME bytes on
fixed-size pages that requests map on demand, so a mixed-length traffic
mix runs strictly more concurrent streams per HBM byte.

Grid (reduced Mistral shape, the paper's GQA example):
  cache   ∈ {dense, paged}   — same cache HBM budget on both sides
  weights ∈ {skipless, merged(qp)}  — generic vs merged decode route

reporting measured tokens/s, peak concurrent streams, and the pool
telemetry (prefix-shared pages, copy-on-writes, deferrals).  Greedy
streams are asserted identical across all four cells (the merge is exact
and paging is layout, not math).  CPU timings are illustrative; the
stream-count ratio is the TPU-relevant part.

  PYTHONPATH=src python -m benchmarks.bench_paged_serving
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.models import init_params
from repro.serving import Engine, ServeConfig

# equal cache-HBM budget: dense gets DENSE_SLOTS worst-case slots, paged
# gets the same bytes as a pool (DENSE_SLOTS·max_len / block_size pages)
MAX_LEN = 64
DENSE_SLOTS = 4
BLOCK = 8
MAX_NEW = 8
N_REQ = 16


def _workload(vocab: int):
    """Mixed prompt lengths (4..28 tokens) — realistic ragged traffic,
    including two identical prompts so prefix sharing is exercised."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, vocab, size=(int(n),)).astype(np.int32)
               for n in rng.randint(4, 28, size=N_REQ)]
    prompts[1] = prompts[0].copy()  # identical pair -> shared prefix pages
    return prompts


def _serve(cfg, params, cache_kind: str):
    n_blocks = DENSE_SLOTS * MAX_LEN // BLOCK
    if cache_kind == "paged":
        # same bytes, but slots are just batch rows: admission is by pages
        sc = ServeConfig(n_slots=N_REQ, max_len=MAX_LEN, cache_kind="paged",
                         block_size=BLOCK, n_blocks=n_blocks)
    else:
        sc = ServeConfig(n_slots=DENSE_SLOTS, max_len=MAX_LEN)
    eng = Engine(cfg, params, sc)
    prompts = _workload(cfg.vocab_size)
    eng.generate(prompts[:1], max_new_tokens=2)  # warm the jit caches
    eng2 = Engine(cfg, params, sc)
    t0 = time.perf_counter()
    outs = eng2.generate(prompts, max_new_tokens=MAX_NEW)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    row = dict(cache=cache_kind, tok_s=n_tok / dt,
               peak_streams=eng2.stats["peak_active"],
               deferred=eng2.stats["n_deferred"],
               preempted=eng2.stats["n_preempted"])
    if cache_kind == "paged":
        row.update(cache_bytes=eng2.pm.pool_bytes,
                   shared_pages=eng2.pm.allocator.n_shared_hits,
                   cow=eng2.pm.allocator.n_cow,
                   peak_pages=eng2.pm.allocator.peak_used)
    else:
        row.update(cache_bytes=int(eng2.cache.k.size + eng2.cache.v.size)
                   * eng2.cache.k.dtype.itemsize)
    return row, outs


def run():
    # window off: the dense cache is then max_len-sized per slot (with a
    # window it is a ring ≤ window and the HBM budgets aren't comparable —
    # paged keeps absolute positions and does not yet recycle out-of-window
    # pages; see ROADMAP follow-up)
    base = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), base)
    # O(1) streams so merged/unmerged logits compare well-conditioned
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, base, "qp")

    rows, streams = [], {}
    for wname, (c, p) in (("skipless", (base, params)),
                          ("merged_qp", (mcfg, mparams))):
        for kind in ("dense", "paged"):
            row, outs = _serve(c, p, kind)
            row["weights"] = wname
            rows.append(row)
            streams[(wname, kind)] = outs

    # paging is layout and the merge is exact: all four greedy streams match
    ref = streams[("skipless", "dense")]
    for key, outs in streams.items():
        assert outs == ref, f"greedy stream diverged for {key}"
    # equal HBM must buy strictly more concurrency on ragged traffic
    for wname in ("skipless", "merged_qp"):
        d = next(r for r in rows if r["weights"] == wname and r["cache"] == "dense")
        p = next(r for r in rows if r["weights"] == wname and r["cache"] == "paged")
        assert p["cache_bytes"] == d["cache_bytes"], (p["cache_bytes"], d["cache_bytes"])
        assert p["peak_streams"] > d["peak_streams"], (
            "paged pool must sustain more concurrent streams than the dense "
            f"cache at equal HBM: {p['peak_streams']} vs {d['peak_streams']}")
    return rows


def main():
    rows = run()
    print(f"{N_REQ} requests, prompts 4..28 tok, +{MAX_NEW} new; equal "
          f"cache HBM ({rows[0]['cache_bytes']/1e6:.2f} MB)")
    hdr = ("weights", "cache", "peak_streams", "tok_s", "deferred",
           "preempted", "shared_pages", "cow")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in rows:
        print(" ".join(
            f"{r.get(h, '-'):>12.1f}" if isinstance(r.get(h), float)
            else f"{str(r.get(h, '-')):>12}" for h in hdr))
    print("all four greedy streams token-identical; paged > dense streams OK")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
