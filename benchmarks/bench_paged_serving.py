"""Paged vs dense serving at EQUAL HBM: streams, tokens/s, prefill traffic.

The dense DecodeCache sizes every slot for the worst case, so at a fixed
cache-HBM budget the slot count is ``budget / (L · max_len · Hkv · Dh)``
— tiny, and it is the batch size that amortizes the merged fast path's
per-token K*/V* weight stream.  The paged pool spends the SAME bytes on
fixed-size pages that requests map on demand, so a mixed-length traffic
mix runs strictly more concurrent streams per HBM byte.

Grid (reduced Mistral shape, the paper's GQA example):
  cache   ∈ {dense, paged}   — same cache HBM budget on both sides
  weights ∈ {skipless, merged(qp)}  — generic vs merged decode route

reporting measured tokens/s, per-request TTFT (from Engine.generate's
RequestResults), peak concurrent streams, and the pool telemetry
(prefix-shared pages, copy-on-writes, deferrals).  Greedy streams are
asserted identical across all four cells (the merge is exact and paging
is layout, not math).  CPU timings are illustrative; the stream-count
ratio and the HLO byte counts are the TPU-relevant parts.

A second section measures the PREFILL path per prompt bucket:
``cost_analysis`` bytes of the compiled prefill program, paged
direct-to-page (``forward_prefill(dest=PagedPrefillDest(…))`` — prompt
KV lands straight in the mapped blocks) vs the LEGACY paged path it
replaced (dense worst-case-``max_len`` intermediate cache + post-prefill
page scatter) vs the dense engine's prefill.  Direct-to-page must move
strictly fewer bytes than the legacy path — the intermediate buffer and
the second scatter pass are simply not in the program.

A third section measures MERGED vs GENERIC prefill per bucket (the
PrefillBackend registry's style axis, same delta style as the direct-to-
page one): compiled prefill bytes of the qp-merged rewrite vs its
unmerged source, for both cache kinds, plus the measured TTFT delta from
the serve rows.  Merged must move strictly fewer bytes — the wq/wp reads
are simply not in the program (stream-as-query fast path).

A fourth section re-runs the equal-HBM stream comparison WITH the
config's sliding window (the dense cache is then a window-sized ring per
slot, and the paged cache is a bounded RING of ceil(window/bs)+1 recycled
table slots per request — serving/paged_kv_cache).  It asserts all four
greedy streams identical, the windowed paged page high-water ≤ the ring
bound for EVERY request, and reports the admitted-streams and
pages-per-request deltas (ring vs the unbounded absolute tables the paged
cache used before recycling).

A fifth section re-runs the paged serve INSTRUMENTED (``repro.obs``) and
emits ``BENCH_serving_obs.json`` — p50/p99 TTFT, the decode-step latency
histogram, pool-occupancy high-water, and the recycle/CoW/preempt
counters — the first entry of the run-to-run perf trajectory.

A sixth section compares the QUANTIZED pool (``paged_q8``: int8 pages +
per-(page, kv-head) f32 scales, dequantized inside the attention
kernels) against the fp paged pool at the SAME cache-HBM budget on
long-skewed traffic: the int8 bytes buy ~4x the pages, so peak
concurrent streams must rise by at least ``Q8_STREAM_GAIN``.  The
numerics gate rides along — greedy streams must match the fp pool
exactly on well-conditioned weights, and full-shape page-crossing
prefill logits must stay within 10% relative error — and the whole
payload lands in ``BENCH_quant_numerics.json``.

A seventh section measures the PREFIX CACHE (the radix tree with
cross-request retention — ``serving.radix_tree``) on a multi-tenant
Zipf workload: a few Zipf-popular tenant heads with nested few-shot
prefixes, served in WAVES on one engine so every wave's requests are
released before the next arrives — cross-request retention is then the
only way a later wave hits an earlier wave's pages.  The retention
engine's token hit-rate must be STRICTLY above the no-retention
baseline (entries die with their last sharer — the old flat-registry
lifecycle), with both engines' greedy streams token-identical (a warm
hit is bytes already computed, never different bytes).  The payload
lands in ``BENCH_prefix_cache.json``.

  PYTHONPATH=src python -m benchmarks.bench_paged_serving
  PYTHONPATH=src python -m benchmarks.bench_paged_serving --quant   # only
                                           the sixth section (CI artifact)
  PYTHONPATH=src python -m benchmarks.bench_paged_serving --prefix  # only
                                         the seventh section (CI artifact)
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import merge_skipless
from repro.core.analysis import cost_dict
from repro.models import DensePrefillDest, forward_prefill, init_params
from repro.serving import (Engine, PagedCacheAdapter, PagedQ8CacheAdapter,
                           ServeConfig)
from repro.serving.paged_kv_cache import scatter_prefill_blocks

# equal cache-HBM budget: dense gets DENSE_SLOTS worst-case slots, paged
# gets the same bytes as a pool (DENSE_SLOTS·max_len / block_size pages)
MAX_LEN = 64
DENSE_SLOTS = 4
BLOCK = 8
MAX_NEW = 8
N_REQ = 16
# windowed section: the reduced-mistral window; smaller pages so the ring
# bound (ceil(16/4)+1 = 5 pages/request) bites visibly on long requests
WIN = 16
WIN_BLOCK = 4
# quantized section: equal HBM must buy at least this peak-stream factor
Q8_STREAM_GAIN = 1.8


def _workload(vocab: int):
    """Mixed prompt lengths (4..28 tokens) — realistic ragged traffic,
    including two identical prompts so prefix sharing is exercised."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, vocab, size=(int(n),)).astype(np.int32)
               for n in rng.randint(4, 28, size=N_REQ)]
    prompts[1] = prompts[0].copy()  # identical pair -> shared prefix pages
    return prompts


def _workload_windowed(vocab: int):
    """Short-skewed ragged traffic (where a window-sized dense slot still
    over-reserves) plus window-ROLLING long requests (where the ring bound
    bites: 24+8 = 32 tokens would need 8 absolute pages, the ring holds
    them at ≤ 5)."""
    rng = np.random.RandomState(1)
    lens = [4, 8] * 6 + [24] * 4
    prompts = [rng.randint(0, vocab, size=(n,)).astype(np.int32)
               for n in lens]
    prompts[1] = prompts[0].copy()  # identical pair -> shared prefix pages
    return prompts


def _make_engine(cfg, params, cache_kind: str, obs: bool = False) -> Engine:
    # equal HBM on both sides of each section: a dense slot costs a
    # max_len (windowless) or window-sized (windowed) KV stretch, and the
    # paged pool gets exactly the same bytes as fixed-size pages
    sc_dense = min(MAX_LEN, cfg.sliding_window) if cfg.sliding_window \
        else MAX_LEN
    bs = WIN_BLOCK if cfg.sliding_window else BLOCK
    n_blocks = DENSE_SLOTS * sc_dense // bs
    if cache_kind == "paged":
        # same bytes, but slots are just batch rows: admission is by pages
        sc = ServeConfig(n_slots=N_REQ, max_len=MAX_LEN, obs=obs)
        cache = PagedCacheAdapter(block_size=bs, n_blocks=n_blocks)
    else:
        sc = ServeConfig(n_slots=DENSE_SLOTS, max_len=MAX_LEN, obs=obs)
        cache = "dense"
    return Engine(cfg, params, sc, cache=cache)


def _serve(cfg, params, cache_kind: str):
    eng = _make_engine(cfg, params, cache_kind)
    prompts = _workload_windowed(cfg.vocab_size) if cfg.sliding_window \
        else _workload(cfg.vocab_size)
    eng.generate(prompts[:1], max_new_tokens=2)  # warm the jit caches
    eng2 = _make_engine(cfg, params, cache_kind)
    t0 = time.perf_counter()
    outs = eng2.generate(prompts, max_new_tokens=MAX_NEW)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    row = dict(cache=cache_kind, tok_s=n_tok / dt,
               ttft_ms=1e3 * float(np.mean([o.ttft_s for o in outs])),
               peak_streams=eng2.stats["peak_active"],
               deferred=eng2.stats["n_deferred"],
               preempted=eng2.stats["n_preempted"],
               cache_bytes=eng2.kv.cache_bytes)
    if cache_kind == "paged":
        row.update(shared_pages=eng2.pm.allocator.n_shared_hits,
                   cow=eng2.pm.allocator.n_cow,
                   peak_pages=eng2.pm.allocator.peak_used,
                   recycled=eng2.pm.allocator.n_recycled,
                   ring_bound=eng2.pm.ring_bound,
                   page_hwm=eng2.pm.request_page_hwm.max)
        if cfg.sliding_window:
            # pages the same requests would pin WITHOUT ring recycling
            # (absolute tables hold every block until the request ends)
            row["pages_unbounded"] = max(
                -(-(len(p) + MAX_NEW - 1) // eng2.pm.bs) for p in prompts)
    return row, outs


def _serve_obs(cfg, params):
    """Instrumented paged serve over the mixed workload: the
    ``BENCH_serving_obs.json`` payload (p50/p99 TTFT, decode-step latency
    histogram, pool high-water, recycle/CoW/preempt counters), with the
    Perfetto export validated structurally on the way out."""
    from repro.obs import serving_obs_doc, validate_perfetto
    eng = _make_engine(cfg, params, "paged", obs=True)
    eng.generate(_workload(cfg.vocab_size)[:1], max_new_tokens=2)  # warm
    eng = _make_engine(cfg, params, "paged", obs=True)
    prompts = _workload(cfg.vocab_size)
    outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
    doc = serving_obs_doc(eng, extra={
        "workload": {"n_requests": N_REQ, "max_new": MAX_NEW,
                     "block_size": BLOCK, "max_len": MAX_LEN,
                     "n_tokens": sum(len(o) for o in outs)}})
    validate_perfetto(eng.obs.trace.to_perfetto())
    for key in ("ttft_p50_ms", "ttft_p99_ms", "decode_step_p50_ms",
                "decode_step_p99_ms", "pool_peak_used", "pool_recycled",
                "pool_cow", "preempted"):
        assert doc["headline"].get(key) is not None, key
    return doc


def write_obs_doc(doc, path: str = "") -> str:
    """Persist the obs payload (default: benchmarks/BENCH_serving_obs.json
    next to this module) — the file the perf trajectory accumulates."""
    path = path or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_serving_obs.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def _workload_quant(vocab: int):
    """Long-skewed ragged traffic for the quantized-pool comparison:
    alternating 40- and 24-token prompts (plus one identical pair for
    prefix sharing).  Power-of-two bucketing pins a 40-token prompt to a
    full 64-token stretch of pages, so the fp pool saturates at a
    handful of streams while the SAME bytes as int8 pages keep every
    slot busy."""
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, vocab, size=(n,)).astype(np.int32)
               for n in [40, 24] * (N_REQ // 2)]
    prompts[1] = prompts[0].copy()
    return prompts


def _serve_quant(cfg, params):
    """Equal-HBM fp-paged vs paged_q8 serve: the q8 pool gets exactly the
    fp pool's byte budget spent on int8 pages + f32 scale rows."""
    prompts = _workload_quant(cfg.vocab_size)
    n_blocks_fp = DENSE_SLOTS * MAX_LEN // BLOCK

    def fp_engine():
        return Engine(cfg, params,
                      ServeConfig(n_slots=N_REQ, max_len=MAX_LEN),
                      cache=PagedCacheAdapter(block_size=BLOCK,
                                              n_blocks=n_blocks_fp))

    budget = fp_engine().kv.cache_bytes
    probe = Engine(cfg, params, ServeConfig(n_slots=1, max_len=MAX_LEN),
                   cache=PagedQ8CacheAdapter(block_size=BLOCK, n_blocks=2))
    n_blocks_q8 = int(budget // (probe.kv.cache_bytes / 2))

    def q8_engine():
        return Engine(cfg, params,
                      ServeConfig(n_slots=N_REQ, max_len=MAX_LEN),
                      cache=PagedQ8CacheAdapter(block_size=BLOCK,
                                                n_blocks=n_blocks_q8))

    rows = {}
    for name, mk in (("paged", fp_engine), ("paged_q8", q8_engine)):
        mk().generate(prompts[:1], max_new_tokens=2)  # warm the jit caches
        eng = mk()
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=MAX_NEW)
        dt = time.perf_counter() - t0
        rows[name] = dict(
            cache=name, tok_s=sum(len(o) for o in outs) / dt,
            ttft_ms=1e3 * float(np.mean([o.ttft_s for o in outs])),
            peak_streams=eng.stats["peak_active"],
            deferred=eng.stats["n_deferred"],
            preempted=eng.stats["n_preempted"],
            cache_bytes=eng.kv.cache_bytes,
            shared_pages=eng.pm.allocator.n_shared_hits,
            cow=eng.pm.allocator.n_cow)
    fp_row, q8_row = rows["paged"], rows["paged_q8"]
    assert q8_row["cache_bytes"] <= budget, (
        "q8 pool must fit the fp pool's byte budget",
        q8_row["cache_bytes"], budget)
    gain = q8_row["peak_streams"] / fp_row["peak_streams"]
    assert gain >= Q8_STREAM_GAIN, (
        f"equal HBM as int8 pages must buy >= {Q8_STREAM_GAIN}x peak "
        f"streams: {q8_row['peak_streams']} vs {fp_row['peak_streams']}")
    return dict(budget_bytes=budget, n_blocks_fp=n_blocks_fp,
                n_blocks_q8=n_blocks_q8, stream_gain=gain,
                fp=fp_row, q8=q8_row)


def _quant_numerics(base, params):
    """The numerics gate behind the q8 row, per weight style: greedy
    streams of a short serve must MATCH the fp pool exactly (weights at
    init scale are well-conditioned), and a full page-crossing 48-token
    prefill must keep the q8 logits within 10% relative error of the fp
    paged logits with the argmax intact."""
    from repro.models import (PagedPrefillDest, PagedQ8PrefillDest,
                              init_paged_cache, init_paged_q8_cache)
    import jax.numpy as jnp
    mparams, mcfg = merge_skipless(params, base, "qp")
    styles = {}
    for wname, (c, p) in (("skipless", (base, params)),
                          ("merged_qp", (mcfg, mparams))):
        S, bs = 48, 8
        nbk = S // bs
        toks = jnp.asarray(np.arange(S) * 5 % c.vocab_size,
                           jnp.int32)[None]
        ids = jnp.arange(nbk, dtype=jnp.int32)
        pc = init_paged_cache(c, n_blocks=nbk, block_size=bs, n_slots=1,
                              max_len=S)
        lg_fp, _ = forward_prefill(p, c, toks,
                                   PagedPrefillDest(pc.k, pc.v, ids))
        qc = init_paged_q8_cache(c, n_blocks=nbk, block_size=bs,
                                 n_slots=1, max_len=S)
        lg_q8, _ = forward_prefill(
            p, c, toks, PagedQ8PrefillDest(qc.k, qc.v, qc.k_scale,
                                           qc.v_scale, ids))
        rel = float(jnp.max(jnp.abs(lg_q8 - lg_fp))) \
            / float(jnp.max(jnp.abs(lg_fp)))
        argmax_ok = int(jnp.argmax(lg_q8[0, :c.vocab_size])) \
            == int(jnp.argmax(lg_fp[0, :c.vocab_size]))

        # 4 new tokens: int8 noise compounds per decode step through the
        # skipless stack, and past ~4 steps a near-tie argmax can flip —
        # the bounded-rel-err gate above covers the longer horizon
        prompts = [np.arange(5, dtype=np.int32) % c.vocab_size + 3 * i
                   for i in range(2)]
        streams = {}
        for kind, cls in (("paged", PagedCacheAdapter),
                          ("paged_q8", PagedQ8CacheAdapter)):
            eng = Engine(c, p, ServeConfig(n_slots=2, max_len=48),
                         cache=cls(block_size=8, n_blocks=12))
            streams[kind] = eng.generate(prompts, max_new_tokens=4)
        greedy_match = streams["paged"] == streams["paged_q8"]
        assert rel <= 0.10, (wname, rel)
        assert argmax_ok and greedy_match, (wname, argmax_ok, greedy_match)
        styles[wname] = dict(logit_rel_err=rel, argmax_match=argmax_ok,
                             greedy_match=bool(greedy_match),
                             prefill_tokens=S, pages=nbk)
    return styles


def quant_section():
    """The whole sixth section (equal-HBM serve + numerics gate) — the
    ``BENCH_quant_numerics.json`` payload.  Runs on its own windowless
    config at init weight scale, so ``--quant`` can skip everything
    else."""
    base = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), base)
    return dict(equal_hbm=_serve_quant(base, params),
                numerics=_quant_numerics(base, params),
                workload=dict(n_requests=N_REQ, prompt_lens=[40, 24],
                              max_new=MAX_NEW, block_size=BLOCK,
                              max_len=MAX_LEN))


def write_quant_doc(doc, path: str = "") -> str:
    """Persist the q8 payload (default: benchmarks/BENCH_quant_numerics
    .json next to this module) — the CI analysis artifact."""
    path = path or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_quant_numerics.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def print_quant(doc) -> None:
    hbm, num = doc["equal_hbm"], doc["numerics"]
    print(f"\nquantized pool (paged_q8) at equal cache HBM "
          f"({hbm['budget_bytes'] / 1e6:.2f} MB: {hbm['n_blocks_fp']} fp "
          f"pages -> {hbm['n_blocks_q8']} int8 pages):")
    hdr = ("cache", "peak_streams", "tok_s", "ttft_ms", "deferred",
           "shared_pages", "cow")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in (hbm["fp"], hbm["q8"]):
        print(" ".join(
            f"{r.get(h, '-'):>12.1f}" if isinstance(r.get(h), float)
            else f"{str(r.get(h, '-')):>12}" for h in hdr))
    print(f"  stream gain {hbm['stream_gain']:.2f}x >= "
          f"{Q8_STREAM_GAIN}x floor OK")
    for wname, n in num.items():
        print(f"  numerics[{wname}]: greedy streams fp==q8 OK | "
              f"{n['prefill_tokens']}-token prefill rel err "
              f"{100 * n['logit_rel_err']:.2f}% <= 10% (argmax intact)")


ZIPF_WAVES = 3
ZIPF_REQ_PER_WAVE = 6


def _workload_prefix(vocab: int):
    """Multi-tenant Zipf waves: each request is a Zipf-popular tenant
    HEAD (a shared system prompt), a nested stack of few-shot examples
    (prefix-of-each-other, so deeper requests extend shallower ones'
    chains), and a unique user suffix.  Returned as WAVES — the caller
    serves each wave to completion before the next, so a later wave can
    only hit pages the tree RETAINED across request lifetimes."""
    rng = np.random.RandomState(3)
    heads = [rng.randint(0, vocab, size=(16,)).astype(np.int32)
             for _ in range(4)]
    shots = [rng.randint(0, vocab, size=(8,)).astype(np.int32)
             for _ in range(3)]
    zipf = 1.0 / np.arange(1, len(heads) + 1)
    zipf /= zipf.sum()
    waves = []
    for _ in range(ZIPF_WAVES):
        wave = []
        for _ in range(ZIPF_REQ_PER_WAVE):
            h = rng.choice(len(heads), p=zipf)
            depth = rng.randint(0, len(shots) + 1)
            sfx = rng.randint(0, vocab,
                              size=(rng.randint(2, 6),)).astype(np.int32)
            wave.append(np.concatenate([heads[h]] + shots[:depth] + [sfx]))
        waves.append(wave)
    return waves


def _serve_prefix(cfg, params):
    """The same Zipf waves on a retention engine vs a no-retention
    baseline (the old registry lifecycle: entries die with their page's
    last sharer): token hit-rate must be strictly higher WITH retention,
    greedy streams identical on both."""
    waves = _workload_prefix(cfg.vocab_size)
    n_tokens = sum(len(p) for wave in waves for p in wave)

    def mk(retention: bool) -> Engine:
        return Engine(cfg, params,
                      ServeConfig(n_slots=ZIPF_REQ_PER_WAVE, max_len=MAX_LEN),
                      cache=PagedCacheAdapter(
                          block_size=BLOCK,
                          n_blocks=DENSE_SLOTS * MAX_LEN // BLOCK,
                          prefix_retention=retention))

    rows, streams = {}, {}
    for name, retention in (("retained", True), ("baseline", False)):
        mk(retention).generate(waves[0][:1], max_new_tokens=2)  # warm jit
        eng = mk(retention)
        outs, ttfts = [], []
        t0 = time.perf_counter()
        for wave in waves:
            res = eng.generate(wave, max_new_tokens=MAX_NEW)
            outs.append([list(o) for o in res])
            ttfts += [o.ttft_s for o in res]
        dt = time.perf_counter() - t0
        pm = eng.pm
        rows[name] = dict(
            retention=retention,
            hit_tokens=pm.tree.hit_tokens,
            hit_rate=pm.tree.hit_tokens / n_tokens,
            shared_pages=pm.allocator.n_shared_hits,
            retained_pages=len(pm.tree.retained),
            tree_nodes=pm.tree.n_nodes,
            evicted=pm.tree.n_evicted,
            ttft_ms=1e3 * float(np.mean(ttfts)),
            tok_s=sum(len(o) for w in outs for o in w) / dt)
        streams[name] = outs
        # the drained pool holds exactly the retained prefixes, and
        # dropping them returns it to empty — conservation end to end
        assert pm.allocator.n_used == len(pm.tree.retained)
        pm.drop_prefix_cache()
        assert pm.allocator.n_used == 0 and pm.tree.n_pages == 0
    assert streams["retained"] == streams["baseline"], (
        "a warm prefix hit must be byte-identical to recompute: greedy "
        "streams diverged between retention on and off")
    warm, cold = rows["retained"], rows["baseline"]
    assert warm["hit_rate"] > cold["hit_rate"], (
        "cross-request retention must lift the Zipf-trace token hit-rate "
        f"strictly above the die-with-last-sharer baseline: "
        f"{warm['hit_rate']:.3f} vs {cold['hit_rate']:.3f}")
    return dict(n_prompt_tokens=n_tokens,
                hit_rate_gain=warm["hit_rate"] - cold["hit_rate"],
                ttft_delta_ms=warm["ttft_ms"] - cold["ttft_ms"],
                retained=warm, baseline=cold)


def prefix_section():
    """The whole seventh section — the ``BENCH_prefix_cache.json``
    payload.  Runs on its own windowless config, so ``--prefix`` can
    skip everything else."""
    base = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), base)
    return dict(zipf=_serve_prefix(base, params),
                workload=dict(waves=ZIPF_WAVES,
                              requests_per_wave=ZIPF_REQ_PER_WAVE,
                              heads=4, shots=3, max_new=MAX_NEW,
                              block_size=BLOCK, max_len=MAX_LEN))


def write_prefix_doc(doc, path: str = "") -> str:
    """Persist the prefix-cache payload (default: benchmarks/
    BENCH_prefix_cache.json next to this module) — the CI artifact."""
    path = path or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_prefix_cache.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def print_prefix(doc) -> None:
    z = doc["zipf"]
    w = doc["workload"]
    print(f"\nprefix cache (radix tree, cross-request retention) on "
          f"{w['waves']}x{w['requests_per_wave']} Zipf multi-tenant "
          f"waves ({z['n_prompt_tokens']} prompt tokens):")
    hdr = ("mode", "hit_rate", "hit_tokens", "shared_pages",
           "retained_pages", "evicted", "tree_nodes", "ttft_ms")
    print(" ".join(f"{h:>14}" for h in hdr))
    for name in ("retained", "baseline"):
        r = dict(z[name], mode=name)
        print(" ".join(
            f"{r[h]:>14.3f}" if isinstance(r[h], float)
            else f"{str(r[h]):>14}" for h in hdr))
    print(f"  hit-rate gain +{z['hit_rate_gain']:.3f} (strictly above the "
          f"no-retention baseline) | TTFT delta "
          f"{z['ttft_delta_ms']:+.1f} ms (CPU, illustrative — sharing "
          f"saves pages/HBM; prefill compute is not skipped yet)")
    print("greedy streams token-identical with retention on and off OK")


def _prefill_traffic(dense: Engine, paged: Engine, bucket: int):
    """``cost_analysis`` bytes of the compiled prefill program for one
    prompt bucket: dense engine, paged direct-to-page, and the legacy
    paged path (dense ``max_len`` intermediate + page scatter) that
    direct-to-page deleted."""
    cfg, params = dense.cfg, dense.params
    b_dense = cost_dict(dense.compiled_prefill(bucket)).get("bytes accessed", 0.0)
    b_paged = cost_dict(paged.compiled_prefill(bucket)).get("bytes accessed", 0.0)

    # the legacy before-path, lowered exactly as PR 2's engine ran it:
    # (1) dense prefill into a full max_len cache, (2) scatter its pages
    pshape = jax.eval_shape(lambda: params)
    tk = jax.ShapeDtypeStruct((1, bucket), jax.numpy.int32)
    tl = jax.ShapeDtypeStruct((1,), jax.numpy.int32)
    legacy_pf = jax.jit(lambda p, t, l: forward_prefill(
        p, cfg, t, DensePrefillDest(MAX_LEN, full_cache=True), true_len=l))
    b_legacy = cost_dict(
        legacy_pf.lower(pshape, tk, tl).compile()).get("bytes accessed", 0.0)
    nb = -(-bucket // BLOCK)
    pool = jax.eval_shape(lambda: paged.pm.k)
    blocks = jax.ShapeDtypeStruct(
        (pool.shape[0], nb, BLOCK, *pool.shape[3:]), pool.dtype)
    ids = jax.ShapeDtypeStruct((nb,), jax.numpy.int32)
    b_legacy += cost_dict(
        jax.jit(scatter_prefill_blocks).lower(
            pool, pool, blocks, blocks, ids).compile()
    ).get("bytes accessed", 0.0)
    return dict(bucket=bucket, dense_bytes=b_dense, paged_bytes=b_paged,
                paged_legacy_bytes=b_legacy)


def _serve_grid(base, params, mcfg, mparams):
    """The four-cell equal-HBM serve comparison (cache × weights) for one
    config; returns the rows with every greedy stream cross-asserted."""
    rows, streams = [], {}
    for wname, (c, p) in (("skipless", (base, params)),
                          ("merged_qp", (mcfg, mparams))):
        for kind in ("dense", "paged"):
            row, outs = _serve(c, p, kind)
            row["weights"] = wname
            rows.append(row)
            streams[(wname, kind)] = outs

    # paging is layout and the merge is exact: all four greedy streams match
    ref = streams[("skipless", "dense")]
    for key, outs in streams.items():
        assert outs == ref, f"greedy stream diverged for {key}"
    for wname in ("skipless", "merged_qp"):
        d = next(r for r in rows if r["weights"] == wname and r["cache"] == "dense")
        p = next(r for r in rows if r["weights"] == wname and r["cache"] == "paged")
        assert p["cache_bytes"] == d["cache_bytes"], (p["cache_bytes"], d["cache_bytes"])
    return rows


def run():
    # windowless first: the dense cache is max_len-sized per slot — the
    # baseline absolute-table paged comparison
    base = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), base)
    # O(1) streams so merged/unmerged logits compare well-conditioned
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, base, "qp")

    rows = _serve_grid(base, params, mcfg, mparams)
    # equal HBM must buy strictly more concurrency on ragged traffic
    for wname in ("skipless", "merged_qp"):
        d = next(r for r in rows if r["weights"] == wname and r["cache"] == "dense")
        p = next(r for r in rows if r["weights"] == wname and r["cache"] == "paged")
        assert p["peak_streams"] > d["peak_streams"], (
            "paged pool must sustain more concurrent streams than the dense "
            f"cache at equal HBM: {p['peak_streams']} vs {d['peak_streams']}")

    dense_eng = _make_engine(base, params, "dense")
    paged_eng = _make_engine(base, params, "paged")
    prefill = [_prefill_traffic(dense_eng, paged_eng, b) for b in (8, 16)]
    for pr in prefill:
        assert pr["paged_bytes"] < pr["paged_legacy_bytes"], (
            "direct-to-page prefill must move strictly fewer bytes than "
            "the legacy dense-intermediate + scatter path", pr)

    # merged vs generic prefill (the PrefillBackend style axis), per
    # bucket and per cache kind — the engine must actually route merged
    # configs through the fast path, and the fast path must move fewer
    # bytes (no wq/wp reads in the prompt forward)
    dense_m = _make_engine(mcfg, mparams, "dense")
    paged_m = _make_engine(mcfg, mparams, "paged")
    assert dense_m.merged_prefill_fast_path and paged_m.merged_prefill_fast_path
    assert not dense_eng.merged_prefill_fast_path
    merged_prefill = []
    for b in (8, 16):
        row = dict(
            bucket=b,
            dense_generic=cost_dict(dense_eng.compiled_prefill(b)).get(
                "bytes accessed", 0.0),
            dense_merged=cost_dict(dense_m.compiled_prefill(b)).get(
                "bytes accessed", 0.0),
            paged_generic=cost_dict(paged_eng.compiled_prefill(b)).get(
                "bytes accessed", 0.0),
            paged_merged=cost_dict(paged_m.compiled_prefill(b)).get(
                "bytes accessed", 0.0))
        for kind in ("dense", "paged"):
            assert row[f"{kind}_merged"] < row[f"{kind}_generic"], (
                "merged prefill must move strictly fewer bytes than the "
                "generic prefill (no wq/wp reads)", kind, row)
        merged_prefill.append(row)

    # windowed section: the SAME equal-HBM grid with the model's sliding
    # window restored — dense slots shrink to window-sized rings, paged
    # tables become bounded rings of ceil(window/bs)+1 recycled slots, so
    # the two sides are finally HBM-comparable with sliding_window > 0
    base_w = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=WIN)
    params_w = init_params(jax.random.PRNGKey(0), base_w)
    params_w["embed"]["table"] = params_w["embed"]["table"] * 50.0
    mparams_w, mcfg_w = merge_skipless(params_w, base_w, "qp")
    rows_w = _serve_grid(base_w, params_w, mcfg_w, mparams_w)
    bound = -(-WIN // WIN_BLOCK) + 1
    for r in rows_w:
        if r["cache"] != "paged":
            continue
        d = next(x for x in rows_w if x["weights"] == r["weights"]
                 and x["cache"] == "dense")
        assert r["ring_bound"] == bound, (r["ring_bound"], bound)
        assert 0 < r["page_hwm"] <= bound, (
            "windowed paged page high-water must stay within the ring "
            f"bound ceil(window/block)+1 = {bound}", r)
        assert r["recycled"] > 0, (
            "the window-rolling requests must actually recycle pages", r)
        assert r["pages_unbounded"] > bound, (
            "workload must contain requests the ring bound genuinely caps")
        assert r["peak_streams"] > d["peak_streams"], (
            "windowed paged pool must sustain more concurrent streams than "
            f"window-sized dense slots at equal HBM: {r['peak_streams']} "
            f"vs {d['peak_streams']}")

    # fifth section: the instrumented serve the perf trajectory records
    obs_doc = _serve_obs(base, params)

    # sixth section: the quantized pool at equal HBM + its numerics gate
    quant_doc = quant_section()

    # seventh section: the prefix cache on the multi-tenant Zipf waves
    prefix_doc = prefix_section()
    return (rows, prefill, merged_prefill, rows_w, obs_doc, quant_doc,
            prefix_doc)


def main():
    (rows, prefill, merged_prefill, rows_w, obs_doc, quant_doc,
     prefix_doc) = run()
    print(f"{N_REQ} requests, prompts 4..28 tok, +{MAX_NEW} new; equal "
          f"cache HBM ({rows[0]['cache_bytes']/1e6:.2f} MB)")
    hdr = ("weights", "cache", "peak_streams", "tok_s", "ttft_ms",
           "deferred", "preempted", "shared_pages", "cow")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in rows:
        print(" ".join(
            f"{r.get(h, '-'):>12.1f}" if isinstance(r.get(h), float)
            else f"{str(r.get(h, '-')):>12}" for h in hdr))
    print("all four greedy streams token-identical; paged > dense streams OK")
    print("\nprefill traffic per prompt bucket (cost_analysis bytes of the "
          "compiled prefill program):")
    for pr in prefill:
        saved = 1.0 - pr["paged_bytes"] / pr["paged_legacy_bytes"]
        print(f"  bucket {pr['bucket']:>3}: dense {pr['dense_bytes']/1e6:.2f} "
              f"MB | paged direct-to-page {pr['paged_bytes']/1e6:.2f} MB | "
              f"legacy paged (max_len intermediate + scatter) "
              f"{pr['paged_legacy_bytes']/1e6:.2f} MB "
              f"({100 * saved:.1f}% fewer bytes direct)")
    print("direct-to-page < legacy paged prefill bytes OK")
    print("\nmerged vs generic prefill per bucket (PrefillBackend style "
          "axis; compiled prefill bytes):")
    for mp in merged_prefill:
        sd = 1.0 - mp["dense_merged"] / mp["dense_generic"]
        sp = 1.0 - mp["paged_merged"] / mp["paged_generic"]
        print(f"  bucket {mp['bucket']:>3}: dense "
              f"{mp['dense_generic']/1e6:.2f} -> {mp['dense_merged']/1e6:.2f} "
              f"MB ({100 * sd:.1f}% fewer) | paged "
              f"{mp['paged_generic']/1e6:.2f} -> {mp['paged_merged']/1e6:.2f} "
              f"MB ({100 * sp:.1f}% fewer)")
    for kind in ("dense", "paged"):
        g = next(r for r in rows if r["weights"] == "skipless"
                 and r["cache"] == kind)
        m = next(r for r in rows if r["weights"] == "merged_qp"
                 and r["cache"] == kind)
        print(f"  measured TTFT ({kind}): generic {g['ttft_ms']:.1f} ms -> "
              f"merged {m['ttft_ms']:.1f} ms (CPU, illustrative)")
    print("merged < generic prefill bytes OK (both cache kinds)")

    bound = -(-WIN // WIN_BLOCK) + 1
    print(f"\nsliding window {WIN} (block {WIN_BLOCK}, ring bound "
          f"{bound} pages/request; equal cache HBM "
          f"{rows_w[0]['cache_bytes']/1e6:.2f} MB):")
    hdr_w = ("weights", "cache", "peak_streams", "deferred", "preempted",
             "page_hwm", "recycled", "cow")
    print(" ".join(f"{h:>12}" for h in hdr_w))
    for r in rows_w:
        print(" ".join(f"{str(r.get(h, '-')):>12}" for h in hdr_w))
    pw = next(r for r in rows_w if r["cache"] == "paged")
    dw = next(r for r in rows_w if r["cache"] == "dense")
    print(f"  admitted-streams delta: paged {pw['peak_streams']} vs dense "
          f"{dw['peak_streams']} at equal HBM")
    print(f"  pages-per-request delta: ring high-water {pw['page_hwm']} "
          f"<= bound {bound}, vs {pw['pages_unbounded']} pages the longest "
          f"request would pin without recycling")
    print("all four windowed greedy streams token-identical; page "
          "high-water <= ring bound OK")

    h = obs_doc["headline"]
    path = write_obs_doc(obs_doc)
    print(f"\ninstrumented serve (repro.obs) -> {path}:")
    print(f"  TTFT p50/p99 {h['ttft_p50_ms']:.1f}/{h['ttft_p99_ms']:.1f} ms"
          f" | decode step p50/p99 {h['decode_step_p50_ms']:.2f}/"
          f"{h['decode_step_p99_ms']:.2f} ms")
    print(f"  pool peak {h['pool_peak_used']} pages, recycled "
          f"{h['pool_recycled']}, cow {h['pool_cow']}, prefix hits "
          f"{h['pool_prefix_hits']}, preempted {h['preempted']}, "
          f"deferred {h['deferred']}")
    print("Perfetto export validated; BENCH_serving_obs.json written")

    print_quant(quant_doc)
    qpath = write_quant_doc(quant_doc)
    print(f"BENCH_quant_numerics.json written -> {qpath}")

    print_prefix(prefix_doc)
    ppath = write_prefix_doc(prefix_doc)
    print(f"BENCH_prefix_cache.json written -> {ppath}")


def main_quant():
    """``--quant``: only the sixth section — the fast CI-artifact path."""
    doc = quant_section()
    print_quant(doc)
    path = write_quant_doc(doc)
    print(f"BENCH_quant_numerics.json written -> {path}")


def main_prefix():
    """``--prefix``: only the seventh section — the fast CI-artifact
    path."""
    doc = prefix_section()
    print_prefix(doc)
    path = write_prefix_doc(doc)
    print(f"BENCH_prefix_cache.json written -> {path}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    if "--quant" in sys.argv[1:]:
        main_quant()
    elif "--prefix" in sys.argv[1:]:
        main_prefix()
    else:
        main()
